//! Quickstart: compress one matrix with the default pipeline in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::bruteforce::brute_force;
use intdecomp::cost::{compression_ratio, BinMatrix};
use intdecomp::greedy::greedy;
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::solvers::sa::SimulatedAnnealing;

fn main() {
    // An 8x100 target with a VGG-like spectrum, decomposed at K = 3.
    let problem = generate(&InstanceConfig::default(), 0);
    println!(
        "W is {}x{}, K={}  ->  {:.1}% of the original size",
        problem.n(),
        problem.d(),
        problem.k,
        100.0 * compression_ratio(problem.n(), problem.d(), problem.k, 32)
    );

    // Baselines.
    let g = greedy(&problem, 0);
    let exact = brute_force(&problem);
    println!("greedy cost {:.6}   exact cost {:.6}", g.cost_refit,
             exact.best_cost);

    // BBO: normal-prior BOCS + simulated annealing (the paper's winner).
    let run = bbo::run(
        &problem,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &SimulatedAnnealing::default(),
        &BboConfig::smoke_scale(problem.n_bits(), 800),
        &Backends::default(),
        42,
    );
    println!(
        "BBO cost {:.6} after {} evaluations ({} of exact)",
        run.best_y,
        run.ys.len(),
        if run.found_exact(exact.best_cost, 1e-7) { "HIT" } else { "miss" }
    );

    // The decomposition itself: W ≈ M C.
    let m = BinMatrix::from_spins(problem.n(), problem.k, &run.best_x);
    let c = problem.solve_c(&m);
    println!(
        "M ({}x{}, ±1) · C ({}x{}, f32) — residual {:.4} of ||W||",
        m.n,
        m.k,
        c.rows,
        c.cols,
        problem.normalised_error(run.best_y)
    );
}
