//! The paper's generalisation claim in action: solve a *different* MINLP —
//! subset-selection least squares (cardinality-penalised regression) —
//! with the same BBO machinery, by eliminating the real coefficients with
//! least squares exactly as the integer decomposition eliminates C.
//!
//! ```bash
//! cargo run --release --example minlp_feature_select
//! ```

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::linalg::Matrix;
use intdecomp::minlp::LinearLsqMinlp;
use intdecomp::solvers::sa::SimulatedAnnealing;
use intdecomp::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(2024);
    let (m, n) = (60, 16);
    let truth: Vec<usize> = vec![2, 7, 11];

    // Planted sparse regression: b = A z*, z* supported on `truth`.
    let a = Matrix::from_vec(m, n, rng.normals(m * n));
    let z: Vec<f64> = (0..n)
        .map(|i| if truth.contains(&i) { 1.0 + 0.5 * i as f64 } else { 0.0 })
        .collect();
    let mut b = a.matvec(&z);
    for v in b.iter_mut() {
        *v += 0.01 * rng.normal(); // observation noise
    }
    let problem = LinearLsqMinlp::new(a, b, 0.05);

    println!(
        "subset-selection MINLP: {m} observations, {n} candidate \
         features, true support {truth:?}"
    );

    for (label, algo) in [
        ("RS   ", Algorithm::Rs),
        ("nBOCS", Algorithm::Nbocs { sigma2: 10.0 }), // prior matched to this y scale
        ("FMQA8", Algorithm::Fmqa { k_fm: 8 }),
    ] {
        let run = bbo::run(
            &problem,
            &algo,
            &SimulatedAnnealing::default(),
            &BboConfig::smoke_scale(n, 150),
            &Backends::default(),
            1,
        );
        let support: Vec<usize> = (0..n)
            .filter(|&i| run.best_x[i] == 1)
            .collect();
        println!(
            "{label}: cost {:.4}  support {:?}  ({} evals, {:.2}s)",
            run.best_y,
            support,
            run.ys.len(),
            run.time_total
        );
    }

    // Report the recovered real coefficients for the nBOCS winner.
    let run = bbo::run(
        &problem,
        &Algorithm::Nbocs { sigma2: 10.0 },
        &SimulatedAnnealing::default(),
        &BboConfig::smoke_scale(n, 150),
        &Backends::default(),
        1,
    );
    if let Some((active, coef)) = problem.solve_real(&run.best_x) {
        println!("\nrecovered model:");
        for (i, c) in active.iter().zip(&coef) {
            println!("  feature {i:>2}: z = {c:+.3}");
        }
    }
    println!(
        "\n(The reduction is exactly the paper's: the objective is linear \
         in z given x, so z is eliminated by least squares and BBO \
         optimises the remaining pseudo-Boolean function.)"
    );
}
