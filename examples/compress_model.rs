//! Multi-layer model compression through the parallel batched engine —
//! the edge-computing scenario: every layer matrix of a (synthetic)
//! network is compressed concurrently by `Engine::compress_all`, each
//! layer an independent BBO job with its own seed, with memoised cost
//! evaluations and an aggregated report at the end.
//!
//! ```bash
//! cargo run --release --example compress_model
//! ```

use intdecomp::bbo::Algorithm;
use intdecomp::engine::{self, CompressionJob, Engine, EngineConfig};
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::util::threadpool::default_workers;
use intdecomp::util::timer::Timer;

fn main() {
    // Four layers of a toy network, each with its own shape and rank —
    // the same VGG-like spectrum the paper's instances use.
    let shapes: [(usize, usize, usize); 4] =
        [(8, 100, 3), (8, 64, 3), (6, 40, 2), (6, 32, 2)];
    let workers = default_workers();

    let jobs: Vec<CompressionJob> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(n, d, k))| {
            let cfg = InstanceConfig { n, d, k, gamma: 0.7, seed: 5005 };
            let problem = generate(&cfg, i);
            // A quarter of the paper's 2n² budget is plenty for a demo.
            let iters = problem.n_bits() * problem.n_bits() / 2;
            CompressionJob::new(
                format!("fc{}", i + 1),
                problem,
                iters,
                42 + i as u64,
            )
            .with_algo(Algorithm::Nbocs { sigma2: 0.1 })
            // Batched acquisition: one surrogate fit per 4 candidates.
            .with_batch_size(4)
        })
        .collect();

    println!(
        "compressing {} layers concurrently on {workers} workers \
         (batch size 4)...",
        jobs.len()
    );
    let t = Timer::start();
    let results = Engine::new(EngineConfig {
        workers,
        restart_workers: 1,
        batch_size: 1, // per-job batch size above wins
    })
    .compress_all(jobs);
    let wall = t.seconds();

    print!("{}", engine::summary_table(&results));
    let serial: f64 = results.iter().map(|r| r.run.time_total).sum();
    println!(
        "wall {wall:.2}s vs per-job sum {serial:.2}s ({:.2}x concurrency)",
        serial / wall.max(1e-9)
    );
    println!(
        "whole model: {:.1}% of the original size",
        100.0 * engine::overall_ratio(&results)
    );

    // The engine is deterministic: same seeds, any worker count.
    assert!(results.iter().all(|r| r.run.best_y.is_finite()));
    println!("compress_model OK");
}
