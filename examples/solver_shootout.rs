//! Ising-solver shootout (the paper's Fig. 2 question in isolation):
//! SA vs simulated-QA vs quenching vs exact, on random dense spin glasses
//! and on actual BOCS surrogate models, reporting optimality gaps and
//! wall-clock.
//!
//! ```bash
//! cargo run --release --example solver_shootout
//! ```

use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::solvers::{self, IsingSolver, QuadModel};
use intdecomp::surrogate::{blr::{Blr, Prior}, Dataset, Surrogate};
use intdecomp::util::{rng::Rng, timer::Timer};

fn random_glass(rng: &mut Rng, n: usize) -> QuadModel {
    let mut m = QuadModel::new(n);
    for i in 0..n {
        m.h[i] = rng.normal();
        for j in (i + 1)..n {
            m.set_pair(i, j, rng.normal() / (n as f64).sqrt());
        }
    }
    m
}

fn surrogate_model(rng: &mut Rng) -> QuadModel {
    // A model the BBO loop would actually hand to the solver.
    let p = generate(&InstanceConfig::default(), 0);
    let mut data = Dataset::new(p.n_bits());
    for _ in 0..150 {
        let x = rng.spins(p.n_bits());
        let y = p.cost_spins(&x);
        data.push(x, y);
    }
    let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
    blr.fit_model(&data, rng)
}

fn shoot(label: &str, models: &[QuadModel]) {
    println!("== {label} ({} models, n = {}) ==", models.len(),
             models[0].n);
    let mut rng = Rng::new(123);
    // Ground truth by exhaustive enumeration.
    let exact: Vec<f64> = models
        .iter()
        .map(|m| {
            let x = solvers::exhaustive::Exhaustive.solve(m, &mut rng);
            m.energy(&x)
        })
        .collect();
    for name in ["sa", "sqa", "sq"] {
        let solver = solvers::by_name(name).unwrap();
        let mut gaps = Vec::new();
        let mut hits = 0;
        let t = Timer::start();
        for (m, &e0) in models.iter().zip(&exact) {
            let (_, e) = solver.solve_best(m, &mut rng, 10);
            let spread = models
                .iter()
                .map(|mm| mm.energy(&vec![1i8; mm.n]))
                .fold(1.0f64, f64::max);
            gaps.push((e - e0) / spread.abs().max(1.0));
            if (e - e0).abs() < 1e-9 {
                hits += 1;
            }
        }
        println!(
            "{name:>4}: ground-state hits {hits}/{}  mean gap {:.2e}  \
             ({:.3}s)",
            models.len(),
            intdecomp::util::mean(&gaps),
            t.seconds()
        );
    }
    println!();
}

fn main() {
    let mut rng = Rng::new(777);
    let glasses: Vec<QuadModel> =
        (0..20).map(|_| random_glass(&mut rng, 20)).collect();
    shoot("random dense spin glasses", &glasses);

    let surrogates: Vec<QuadModel> =
        (0..5).map(|_| surrogate_model(&mut rng)).collect();
    shoot("BOCS surrogate models (the BBO workload)", &surrogates);

    println!(
        "Expected shape (paper Fig. 2): on surrogate models all three \
         solvers find the optimum — the landscape is simple, so even SQ \
         suffices."
    );
}
