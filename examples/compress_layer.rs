//! End-to-end validation driver (EXPERIMENTS.md "end-to-end" entry):
//! compress a synthetic NN layer through the FULL three-layer stack —
//! rust coordinator → PJRT artifacts (Pallas cost kernel) → BBO — and
//! compare greedy vs BBO on the paper's headline metric (residual error /
//! exact-solution hits), plus wall-clock for each stage.
//!
//! ```bash
//! make artifacts && cargo run --release --example compress_layer
//! ```

use std::sync::Arc;

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::bruteforce::brute_force;
use intdecomp::cost::compression_ratio;
use intdecomp::greedy::greedy;
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::minlp::Oracle;
use intdecomp::runtime::{XlaCostOracle, XlaRuntime};
use intdecomp::solvers::sa::SimulatedAnnealing;
use intdecomp::util::timer::Timer;

fn main() {
    let cfg = InstanceConfig::default();
    let n_instances = 3;
    let rt = XlaRuntime::load_default().map(Arc::new);
    match &rt {
        Some(r) => println!(
            "PJRT artifacts: {} ({}) — cost evaluations run the Pallas \
             kernel",
            r.dir.display(),
            r.platform()
        ),
        None => println!(
            "no artifacts/ — run `make artifacts`; falling back to native \
             cost"
        ),
    }

    let mut greedy_errs = Vec::new();
    let mut bbo_errs = Vec::new();
    let mut hits = 0;

    for idx in 0..n_instances {
        let problem = generate(&cfg, idx);
        println!(
            "\n== layer {idx} ({}x{} -> K={}, {:.1}% size) ==",
            problem.n(),
            problem.d(),
            problem.k,
            100.0
                * compression_ratio(problem.n(), problem.d(), problem.k, 32)
        );

        let t = Timer::start();
        let exact = brute_force(&problem);
        println!(
            "exact:  cost {:.6}  ({} canonical evals, {:.2}s)",
            exact.best_cost,
            exact.evaluated,
            t.seconds()
        );

        let t = Timer::start();
        let g = greedy(&problem, 7);
        let g_err = problem.residual_error(g.cost_refit, exact.best_cost);
        println!(
            "greedy: cost {:.6}  residual error {:.4}  ({:.4}s)",
            g.cost_refit,
            g_err,
            t.seconds()
        );
        greedy_errs.push(g_err);

        let bcfg = BboConfig::smoke_scale(problem.n_bits(), 400);
        let algo = Algorithm::Nbocs { sigma2: 0.1 };
        let sa = SimulatedAnnealing::default();
        let run = match &rt {
            Some(rt) => {
                let oracle = XlaCostOracle {
                    rt: rt.clone(),
                    problem: problem.clone(),
                };
                bbo::run(&oracle, &algo, &sa, &bcfg, &Backends::default(),
                         idx as u64)
            }
            None => bbo::run(&problem, &algo, &sa, &bcfg,
                             &Backends::default(), idx as u64),
        };
        let b_err = problem.residual_error(run.best_y, exact.best_cost);
        let hit = run.found_exact(exact.best_cost, 1e-6);
        if hit {
            hits += 1;
        }
        println!(
            "BBO:    cost {:.6}  residual error {:.4}  ({} evals, \
             {:.2}s: surrogate {:.2}s solver {:.2}s eval {:.2}s)  exact \
             hit: {hit}",
            run.best_y,
            b_err,
            run.ys.len(),
            run.time_total,
            run.time_surrogate,
            run.time_solver,
            run.time_eval
        );
        bbo_errs.push(b_err);

        // Sanity: re-evaluate the winner natively.
        let native = problem.eval(&run.best_x);
        assert!(
            (native - run.best_y).abs() < 1e-4 * (1.0 + native),
            "XLA/native cost disagreement"
        );
    }

    println!("\n== summary over {n_instances} layers ==");
    println!(
        "mean residual error: greedy {:.4}  vs  BBO {:.4}",
        intdecomp::util::mean(&greedy_errs),
        intdecomp::util::mean(&bbo_errs)
    );
    println!("BBO exact-solution hits: {hits}/{n_instances}");
    assert!(
        intdecomp::util::mean(&bbo_errs)
            <= intdecomp::util::mean(&greedy_errs) + 1e-9,
        "BBO should not lose to greedy on average"
    );
    println!("end-to-end OK");
}
