//! The full cross-process sharding pipeline — plan, work, merge —
//! driven in-process through the library API.
//!
//! In production each `run_shard` call below is its own OS process on
//! its own host (`intdecomp shard work --manifest <file>`); here they
//! run sequentially so the example is self-contained.  The second pass
//! demonstrates crash recovery: the first shard's result log is torn
//! mid-record, and the resumed run recomputes only the lost job while
//! reproducing the original log byte for byte.
//!
//! Run with: `cargo run --release --example shard_pipeline`

use intdecomp::shard::{self, ModelSpec};

fn main() -> anyhow::Result<()> {
    let spec = ModelSpec {
        n: 4,
        d: 12,
        k: 2,
        gamma: 0.8,
        instance_seed: 7,
        layers: 4,
        iters: 8,
        restarts: 4,
        batch_size: 2,
        augment: false,
        restart_workers: 1,
        algo: "nbocs".into(),
        solver: "sa".into(),
        seed: 42,
        cache_key_raw: false,
    };
    let dir = std::env::temp_dir().join("intdecomp_shard_pipeline");
    let _ = std::fs::remove_dir_all(&dir);

    // Plan: shape-only partition into 2 shard manifests.
    let paths = shard::write_plan(&spec, 2, &dir)?;
    println!("planned {} layers into {} shards:", spec.layers, paths.len());
    for p in &paths {
        println!("  {}", p.display());
    }

    // Work: one engine run per shard, checkpointing every finished job.
    for p in &paths {
        let m = shard::Manifest::load(p)?;
        let log = shard::default_result_path(p);
        let run = shard::run_shard(&m, &log, 2, |rec| {
            println!(
                "  shard {}: {} cost {}",
                m.shard,
                rec.name,
                intdecomp::report::fmt(rec.best_y)
            );
        })?;
        println!("shard {} finished: {} ran", m.shard, run.ran);
    }

    // Crash recovery: tear the first shard's log mid-record and resume.
    let log0 = shard::default_result_path(&paths[0]);
    let intact = std::fs::read(&log0)?;
    std::fs::write(&log0, &intact[..intact.len() - 9])?;
    let m0 = shard::Manifest::load(&paths[0])?;
    let resumed = shard::run_shard(&m0, &log0, 2, |_| {})?;
    println!(
        "resume after torn log: {} skipped, {} recomputed, \
         byte-identical: {}",
        resumed.skipped,
        resumed.ran,
        std::fs::read(&log0)? == intact
    );

    // Merge: validate coverage and print the deterministic report —
    // the same bytes a single-process `compress-model --report` writes.
    let merged = shard::merge_dir(&dir)?;
    print!("{}", shard::deterministic_report(&merged.records));

    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
