//! Replica-major engine contract tests (ISSUE 4).
//!
//! Pins the three guarantees the lockstep rework is built on:
//!
//! 1. **Per-replica bit-identity** — every replica of a lockstep run
//!    produces exactly the spin vector the legacy scalar solver
//!    ([`intdecomp::solvers::reference`]) produces on the same forked
//!    RNG stream, for SA, SQ and SQA alike (seed-pinned, no tolerance).
//! 2. **Worker-count invariance** — `solve_batch` through the engine is
//!    a pure function of `(model, solver, seed)`; the pool fan-out and
//!    the shape-only block partition never change results.
//! 3. **Panel/chain equivalence** — the lockstep local-field panel stays
//!    bit-identical to per-chain `LocalFields` bookkeeping under random
//!    flip sequences (property-tested).

use intdecomp::solvers::{
    self, reference, replica, sa::SimulatedAnnealing,
    sq::SimulatedQuenching, sqa::SimulatedQuantumAnnealing, IsingSolver,
    LocalFields, QuadModel,
};
use intdecomp::util::prop::for_all;
use intdecomp::util::rng::Rng;

/// Forked per-restart streams exactly as `solve_batch` derives them.
fn forked_streams(seed: u64, restarts: usize) -> Vec<Rng> {
    let mut root = Rng::new(seed);
    (0..restarts).map(|i| root.fork(i as u64)).collect()
}

#[test]
fn sa_replicas_are_bit_identical_to_reference() {
    let m = QuadModel::random(13, &mut Rng::new(500));
    let sa = SimulatedAnnealing::default();
    let plan = sa.lockstep_plan(&m, &m.stats()).unwrap();
    let streams = forked_streams(71, 9);
    let got = replica::run_replicas(&m, &plan, streams.clone(), 4);
    assert_eq!(got.len(), 9);
    for (i, ((x, e), stream)) in got.iter().zip(&streams).enumerate() {
        let want = reference::sa(&sa, &m, &mut stream.clone());
        assert_eq!(x, &want, "SA replica {i} diverged");
        assert_eq!(*e, m.energy(x));
    }
}

#[test]
fn sq_replicas_are_bit_identical_to_reference() {
    let m = QuadModel::random(12, &mut Rng::new(501));
    let sq = SimulatedQuenching::default();
    let plan = sq.lockstep_plan(&m, &m.stats()).unwrap();
    let streams = forked_streams(72, 7);
    let got = replica::run_replicas(&m, &plan, streams.clone(), 3);
    for (i, ((x, _), stream)) in got.iter().zip(&streams).enumerate() {
        let want = reference::sq(&sq, &m, &mut stream.clone());
        assert_eq!(x, &want, "SQ replica {i} diverged");
    }
}

#[test]
fn sqa_replicas_are_bit_identical_to_reference() {
    let m = QuadModel::random(10, &mut Rng::new(502));
    let sqa = SimulatedQuantumAnnealing {
        slices: 8,
        sweeps: 30,
        ..Default::default()
    };
    let plan = sqa.lockstep_plan(&m, &m.stats()).unwrap();
    let streams = forked_streams(73, 6);
    let got = replica::run_replicas(&m, &plan, streams.clone(), 4);
    for (i, ((x, _), stream)) in got.iter().zip(&streams).enumerate() {
        let want = reference::sqa(&sqa, &m, &mut stream.clone());
        assert_eq!(x, &want, "SQA replica {i} (8 Trotter rows) diverged");
    }
}

#[test]
fn trait_solve_matches_reference_and_keeps_streams_in_sync() {
    // The thin drivers route through the engine; both the output and
    // the caller's post-solve RNG state must match the legacy scalar
    // path, so sequential `solve_best` chains stay bit-identical too.
    let m = QuadModel::random(11, &mut Rng::new(503));
    let sa = SimulatedAnnealing::default();
    let sq = SimulatedQuenching::default();
    let sqa = SimulatedQuantumAnnealing {
        slices: 6,
        sweeps: 20,
        ..Default::default()
    };
    {
        let (mut a, mut b) = (Rng::new(81), Rng::new(81));
        assert_eq!(sa.solve(&m, &mut a), reference::sa(&sa, &m, &mut b));
        assert_eq!(a.next_u64(), b.next_u64(), "SA stream out of sync");
    }
    {
        let (mut a, mut b) = (Rng::new(82), Rng::new(82));
        assert_eq!(sq.solve(&m, &mut a), reference::sq(&sq, &m, &mut b));
        assert_eq!(a.next_u64(), b.next_u64(), "SQ stream out of sync");
    }
    {
        let (mut a, mut b) = (Rng::new(83), Rng::new(83));
        assert_eq!(sqa.solve(&m, &mut a), reference::sqa(&sqa, &m, &mut b));
        assert_eq!(a.next_u64(), b.next_u64(), "SQA stream out of sync");
    }
}

#[test]
fn solve_batch_is_worker_count_invariant_for_all_algorithms() {
    let m = QuadModel::random(10, &mut Rng::new(504));
    let algos: Vec<Box<dyn IsingSolver>> = vec![
        Box::new(SimulatedAnnealing { sweeps: 15, ..Default::default() }),
        Box::new(SimulatedQuenching { sweeps: 15, ..Default::default() }),
        Box::new(SimulatedQuantumAnnealing {
            slices: 6,
            sweeps: 15,
            ..Default::default()
        }),
    ];
    for solver in &algos {
        let run = |workers| {
            solvers::solve_batch(
                solver.as_ref(),
                &m,
                &mut Rng::new(31),
                20,
                5,
                workers,
            )
        };
        let serial = run(1);
        for workers in [2, 4, 8] {
            assert_eq!(
                run(workers),
                serial,
                "{} varies with worker count",
                solver.name()
            );
        }
    }
}

#[test]
fn solve_batch_candidates_come_from_the_replica_set() {
    // Every candidate solve_batch returns must be one of the per-stream
    // reference solutions — the engine changes execution, not results.
    let m = QuadModel::random(9, &mut Rng::new(505));
    let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
    let restarts = 12;
    let top =
        solvers::solve_batch(&sa, &m, &mut Rng::new(41), restarts, 4, 3);
    let pool: Vec<Vec<i8>> = forked_streams(41, restarts)
        .into_iter()
        .map(|mut s| reference::sa(&sa, &m, &mut s))
        .collect();
    assert!(!top.is_empty());
    for (x, e) in &top {
        assert!(
            pool.contains(x),
            "candidate not produced by any reference replica"
        );
        assert_eq!(*e, m.energy(x));
    }
}

#[test]
fn lockstep_field_panel_matches_per_chain_local_fields() {
    // Property: after any random flip sequence, every row of the panel
    // carries exactly the spins and fields of an independently updated
    // per-chain LocalFields (the legacy bookkeeping).
    for_all(25, |rng| {
        let n = 2 + rng.below(9);
        let rows = 1 + rng.below(5);
        let m = QuadModel::random(n, rng);
        let mut spins = Vec::with_capacity(rows * n);
        for _ in 0..rows * n {
            spins.push(rng.spin());
        }
        let mut chains: Vec<(Vec<i8>, LocalFields)> = (0..rows)
            .map(|r| {
                let x = spins[r * n..(r + 1) * n].to_vec();
                let f = LocalFields::new(&m, &x);
                (x, f)
            })
            .collect();
        let mut panel = replica::Panel::new(&m, spins);
        for _ in 0..60 {
            let r = rng.below(rows);
            let i = rng.below(n);
            let (x, f) = &mut chains[r];
            assert_eq!(panel.delta_e(r, i), f.delta_e(x, i));
            panel.flip(&m, r, i);
            f.flip(&m, x, i);
        }
        for (r, (x, f)) in chains.iter().enumerate() {
            assert_eq!(panel.row(r), &x[..], "row {r} spins diverged");
            assert_eq!(
                &panel.fields[r * n..(r + 1) * n],
                &f.f[..],
                "row {r} fields diverged"
            );
        }
    });
}

#[test]
fn hoisted_stats_match_legacy_scans() {
    for seed in [600u64, 601, 602] {
        let m = QuadModel::random(14, &mut Rng::new(seed));
        let s = m.stats();
        let (max_f, min_f) = m.field_bounds();
        assert_eq!(s.max_field, max_f);
        assert_eq!(s.min_field, min_f);
        assert_eq!(s.min_gap, m.min_nonzero_gap());
    }
    // Zero model: the legacy fallbacks.
    let z = QuadModel::new(4);
    let s = z.stats();
    assert_eq!(s.min_gap, 1.0);
    assert_eq!((s.max_field, s.min_field), z.field_bounds());
}

#[test]
fn sweep_plan_row_accounting() {
    let m = QuadModel::random(6, &mut Rng::new(510));
    let stats = m.stats();
    let sa = SimulatedAnnealing { sweeps: 40, ..Default::default() };
    let plan = sa.lockstep_plan(&m, &stats).unwrap();
    assert_eq!(plan.rows_per_unit(), 1);
    assert_eq!(plan.row_sweeps_per_unit(), 40);
    let sqa = SimulatedQuantumAnnealing {
        slices: 8,
        sweeps: 25,
        ..Default::default()
    };
    let plan = sqa.lockstep_plan(&m, &stats).unwrap();
    assert_eq!(plan.rows_per_unit(), 8);
    assert_eq!(plan.row_sweeps_per_unit(), 200);
}
