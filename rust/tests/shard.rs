//! End-to-end contracts of the cross-process shard subsystem
//! (`rust/src/shard`): shape-only planning, crash-safe checkpoint
//! resume, and byte-identical merged output — the in-process twin of
//! the CI `shard-smoke` job (which additionally kills a live worker
//! process).

use std::path::{Path, PathBuf};

use intdecomp::engine::Engine;
use intdecomp::shard::{
    self, deterministic_report, merge_dir, LayerRecord, ModelSpec,
};
use intdecomp::util::prop::for_all;

fn tiny_spec(layers: usize) -> ModelSpec {
    ModelSpec {
        n: 4,
        d: 8,
        k: 2,
        gamma: 0.8,
        instance_seed: 9,
        layers,
        iters: 5,
        restarts: 3,
        batch_size: 1,
        augment: false,
        restart_workers: 1,
        algo: "nbocs".into(),
        solver: "sa".into(),
        seed: 11,
        cache_key_raw: false,
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("intdecomp_shard_it_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The single-process reference: `compress_all` over the same jobs the
/// spec describes, converted to checkpoint records — exactly what
/// `compress-model --report` renders.
fn single_process_records(spec: &ModelSpec) -> Vec<LayerRecord> {
    let jobs = (0..spec.layers)
        .map(|i| spec.job(i).unwrap())
        .collect::<Vec<_>>();
    Engine::with_workers(2)
        .compress_all(jobs)
        .iter()
        .enumerate()
        .map(|(i, r)| LayerRecord::from_result(i, r))
        .collect()
}

/// Plan into `shards`, run every shard in its own log, merge.
fn run_sharded(
    spec: &ModelSpec,
    shards: usize,
    workers: usize,
    dir: &Path,
) -> Vec<LayerRecord> {
    for path in shard::write_plan(spec, shards, dir).unwrap() {
        let m = shard::Manifest::load(&path).unwrap();
        let log = shard::default_result_path(&path);
        shard::run_shard(&m, &log, workers, |_| {}).unwrap();
    }
    merge_dir(dir).unwrap().records
}

#[test]
fn any_shard_count_merges_to_the_single_process_result() {
    let spec = tiny_spec(5);
    let reference = single_process_records(&spec);
    let report = deterministic_report(&reference);
    for shards in [1usize, 2, 3, 5] {
        let dir = tmp_dir(&format!("count{shards}"));
        let merged = run_sharded(&spec, shards, 2, &dir);
        assert_eq!(merged, reference, "shards = {shards}");
        assert_eq!(
            deterministic_report(&merged),
            report,
            "report differs at shards = {shards}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn merged_output_is_shard_and_worker_count_invariant_property() {
    for_all(5, |rng| {
        let layers = 1 + rng.below(5);
        let shards = 1 + rng.below(4);
        let workers = 1 + rng.below(4);
        let mut spec = tiny_spec(layers);
        spec.seed = 20 + layers as u64; // vary the workload per case
        let reference = single_process_records(&spec);
        let dir = tmp_dir(&format!("prop{layers}_{shards}_{workers}"));
        let merged = run_sharded(&spec, shards, workers, &dir);
        assert_eq!(
            merged, reference,
            "layers={layers} shards={shards} workers={workers}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn resumed_worker_completes_a_byte_identical_log() {
    let spec = tiny_spec(3);
    let dir = tmp_dir("resume");
    let path = &shard::write_plan(&spec, 1, &dir).unwrap()[0];
    let manifest = shard::Manifest::load(path).unwrap();
    let log = shard::default_result_path(path);
    let full = shard::run_shard(&manifest, &log, 2, |_| {}).unwrap();
    assert_eq!((full.skipped, full.ran), (0, 3));
    let reference = std::fs::read(&log).unwrap();
    let newlines: Vec<usize> = reference
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == b'\n')
        .map(|(i, _)| i)
        .collect();
    assert_eq!(newlines.len(), 3);

    // Crash scenarios: (truncate-to, expected skipped jobs).
    let torn_tail = reference.len() - 5; // mid third record
    let torn_second = newlines[0] + 10; // first record + torn second
    for (case, keep, skipped) in [
        ("torn tail", torn_tail, 2),
        ("torn second record", torn_second, 1),
        ("empty log", 0, 0),
        ("whole log intact", reference.len(), 3),
    ] {
        std::fs::write(&log, &reference[..keep]).unwrap();
        let resumed = shard::run_shard(&manifest, &log, 2, |_| {}).unwrap();
        assert_eq!(resumed.skipped, skipped, "{case}");
        assert_eq!(resumed.ran, 3 - skipped, "{case}");
        assert_eq!(resumed.records, full.records, "{case}");
        assert_eq!(
            std::fs::read(&log).unwrap(),
            reference,
            "{case}: resumed log is not byte-identical"
        );
    }

    // Garbage appended after a crash-free prefix is dropped and the
    // missing jobs recomputed.
    let mut with_garbage = reference[..newlines[1] + 1].to_vec();
    with_garbage.extend_from_slice(b"{\"half\": tru");
    std::fs::write(&log, &with_garbage).unwrap();
    let resumed = shard::run_shard(&manifest, &log, 2, |_| {}).unwrap();
    assert_eq!((resumed.skipped, resumed.ran), (2, 1));
    assert_eq!(std::fs::read(&log).unwrap(), reference);

    // A corrupt byte in the middle invalidates everything after it;
    // the rerun still converges to the same bytes.
    let mut corrupt = reference.clone();
    corrupt[newlines[0] + 3] = b'!';
    std::fs::write(&log, &corrupt).unwrap();
    let resumed = shard::run_shard(&manifest, &log, 2, |_| {}).unwrap();
    assert_eq!((resumed.skipped, resumed.ran), (1, 2));
    assert_eq!(std::fs::read(&log).unwrap(), reference);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn worker_flag_never_changes_the_log_bytes() {
    let spec = tiny_spec(4);
    let mut logs = Vec::new();
    for workers in [1usize, 4] {
        let dir = tmp_dir(&format!("workers{workers}"));
        let path = &shard::write_plan(&spec, 1, &dir).unwrap()[0];
        let m = shard::Manifest::load(path).unwrap();
        let log = shard::default_result_path(path);
        shard::run_shard(&m, &log, workers, |_| {}).unwrap();
        logs.push(std::fs::read(&log).unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }
    assert_eq!(logs[0], logs[1]);
}

#[test]
fn merge_rejects_incomplete_and_mixed_plans() {
    // Incomplete: only one of two shards ever ran.
    let spec = tiny_spec(4);
    let dir = tmp_dir("incomplete");
    let paths = shard::write_plan(&spec, 2, &dir).unwrap();
    let m0 = shard::Manifest::load(&paths[0]).unwrap();
    let log0 = shard::default_result_path(&paths[0]);
    shard::run_shard(&m0, &log0, 2, |_| {}).unwrap();
    let err = format!("{:#}", merge_dir(&dir).unwrap_err());
    assert!(err.contains("incomplete"), "{err}");

    // Mixed: manifests from a different plan land in the same dir.
    let mut other = spec.clone();
    other.seed += 1;
    shard::write_plan(&other, 3, &dir).unwrap();
    let err = format!("{:#}", merge_dir(&dir).unwrap_err());
    assert!(err.contains("different plan"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn second_worker_on_a_locked_log_fails_fast() {
    let spec = tiny_spec(2);
    let dir = tmp_dir("locked");
    let path = &shard::write_plan(&spec, 1, &dir).unwrap()[0];
    let m = shard::Manifest::load(path).unwrap();
    let log = shard::default_result_path(path);
    let held = intdecomp::util::lockfile::LockFile::acquire(&log).unwrap();
    let err = format!(
        "{:#}",
        shard::run_shard(&m, &log, 2, |_| {}).unwrap_err()
    );
    assert!(err.contains("held by live process"), "{err}");
    drop(held);
    // Released: the same call now runs, and drops its own lock after.
    shard::run_shard(&m, &log, 2, |_| {}).unwrap();
    assert!(
        !intdecomp::util::lockfile::LockFile::path_for(&log).exists()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn progress_sink_reports_only_newly_computed_jobs_in_order() {
    let spec = tiny_spec(3);
    let dir = tmp_dir("progress");
    let path = &shard::write_plan(&spec, 1, &dir).unwrap()[0];
    let m = shard::Manifest::load(path).unwrap();
    let log = shard::default_result_path(path);
    let mut seen = Vec::new();
    shard::run_shard(&m, &log, 4, |rec| seen.push(rec.job)).unwrap();
    assert_eq!(seen, vec![0, 1, 2]);
    // Fully checkpointed: the sink stays silent on resume.
    let mut seen = Vec::new();
    shard::run_shard(&m, &log, 4, |rec| seen.push(rec.job)).unwrap();
    assert!(seen.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}
