//! Property-based tests (in-tree `util::prop` substrate): invariants of
//! the cost function, solvers, surrogate features and clustering under
//! randomly generated inputs.

use std::collections::BTreeMap;

use intdecomp::cost::{BinMatrix, Problem};
use intdecomp::linalg::{cholesky, cho_solve, householder_qr, Matrix};
use intdecomp::solvers::{greedy_descent, QuadModel};
use intdecomp::surrogate::features::{alpha_to_quad, n_features, phi};
use intdecomp::util::json::Json;
use intdecomp::util::prop::for_all;
use intdecomp::util::rng::Rng;

fn rand_problem(rng: &mut Rng) -> Problem {
    let n = 2 + rng.below(6);
    let d = 1 + rng.below(15);
    let k = 1 + rng.below(n.min(4));
    let w = Matrix::from_vec(n, d, rng.normals(n * d));
    Problem::new(w, k)
}

fn rand_bin(rng: &mut Rng, n: usize, k: usize) -> BinMatrix {
    BinMatrix::new(n, k, rng.spins(n * k))
}

#[test]
fn prop_cost_in_bounds_and_matches_explicit() {
    for_all(60, |rng| {
        let p = rand_problem(rng);
        let m = rand_bin(rng, p.n(), p.k);
        let fast = p.cost(&m);
        assert!(fast >= 0.0);
        assert!(fast <= p.w_norm_sq + 1e-9);
        let slow = p.cost_explicit(&m);
        assert!(
            (fast - slow).abs() < 1e-6 * (1.0 + slow),
            "fast {fast} explicit {slow}"
        );
    });
}

#[test]
fn prop_cost_invariant_under_random_orbit_element() {
    for_all(60, |rng| {
        let p = rand_problem(rng);
        let m = rand_bin(rng, p.n(), p.k);
        let mut perm: Vec<usize> = (0..p.k).collect();
        rng.shuffle(&mut perm);
        let signs: Vec<i8> = (0..p.k).map(|_| rng.spin()).collect();
        let t = m.transformed(&perm, &signs);
        let (a, b) = (p.cost(&m), p.cost(&t));
        assert!((a - b).abs() < 1e-9 * (1.0 + a));
        assert_eq!(m.canonical(), t.canonical());
    });
}

#[test]
fn prop_adding_a_column_never_increases_cost() {
    // Monotonicity in K: col(M) ⊆ col([M m']) ⇒ projection residual
    // cannot grow.
    for_all(50, |rng| {
        let n = 3 + rng.below(5);
        let d = 2 + rng.below(10);
        let k = 1 + rng.below(3.min(n - 1));
        let w = Matrix::from_vec(n, d, rng.normals(n * d));
        let pk = Problem::new(w.clone(), k);
        let pk1 = Problem::new(w, k + 1);
        let m = rand_bin(rng, n, k);
        let mut data = m.data.clone();
        data.extend(rng.spins(n));
        let m1 = BinMatrix::new(n, k + 1, data);
        assert!(pk1.cost(&m1) <= pk.cost(&m) + 1e-9);
    });
}

#[test]
fn prop_delta_e_consistency_random_models() {
    for_all(80, |rng| {
        let n = 2 + rng.below(12);
        let mut model = QuadModel::new(n);
        for i in 0..n {
            model.h[i] = rng.normal();
            for j in (i + 1)..n {
                model.set_pair(i, j, rng.normal());
            }
        }
        let x = rng.spins(n);
        let i = rng.below(n);
        let mut xf = x.clone();
        xf[i] = -xf[i];
        let de = model.delta_e(&x, i);
        assert!(
            (de - (model.energy(&xf) - model.energy(&x))).abs() < 1e-9
        );
        // Greedy descent never increases energy.
        let mut y = x.clone();
        let before = model.energy(&y);
        greedy_descent(&model, &mut y);
        assert!(model.energy(&y) <= before + 1e-12);
    });
}

#[test]
fn prop_feature_map_energy_identity() {
    for_all(60, |rng| {
        let n = 2 + rng.below(10);
        let alpha = rng.normals(n_features(n));
        let model = alpha_to_quad(&alpha, n);
        let x = rng.spins(n);
        let via_phi: f64 =
            alpha.iter().zip(phi(&x)).map(|(a, p)| a * p).sum();
        assert!((model.energy(&x) - via_phi).abs() < 1e-9);
    });
}

#[test]
fn prop_cholesky_solve_roundtrip() {
    for_all(40, |rng| {
        let n = 2 + rng.below(12);
        let a = Matrix::from_vec(n + 2, n, rng.normals((n + 2) * n));
        let mut g = a.gram();
        for i in 0..n {
            g[(i, i)] += 0.3;
        }
        let l = cholesky(&g, 1e-12).expect("SPD");
        let x_true = rng.normals(n);
        let b = g.matvec(&x_true);
        let x = cho_solve(&l, &b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-6);
        }
    });
}

#[test]
fn prop_qr_orthonormal_and_reconstructs() {
    for_all(40, |rng| {
        let n = 2 + rng.below(6);
        let m = n + rng.below(20);
        let a = Matrix::from_vec(m, n, rng.normals(m * n));
        let (q, r) = householder_qr(&a);
        let qr = q.matmul(&r);
        for (x, y) in qr.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-7);
        }
        let qtq = q.gram();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq[(i, j)] - want).abs() < 1e-8);
            }
        }
    });
}

#[test]
fn prop_orbit_expansion_size_divides_group_order() {
    for_all(40, |rng| {
        let n = 2 + rng.below(5);
        let k = 1 + rng.below(3);
        let m = rand_bin(rng, n, k);
        let orbit = intdecomp::bruteforce::expand_orbit(&[m]);
        let group = (1..=k).product::<usize>() * (1 << k);
        assert!(group % orbit.len() == 0, "orbit {} group {group}",
                orbit.len());
    });
}

#[test]
fn prop_dataset_moments_track_pushes() {
    for_all(30, |rng| {
        let n = 2 + rng.below(6);
        let mut data = intdecomp::surrogate::Dataset::new(n);
        let rows = 1 + rng.below(25);
        for _ in 0..rows {
            data.push(rng.spins(n), rng.normal());
        }
        let phi_m = data.phi_matrix();
        let g = phi_m.gram();
        for (a, b) in g.data.iter().zip(&data.g.data) {
            assert!((a - b).abs() < 1e-8);
        }
    });
}

/// Characters the JSON escape machinery must survive: quotes and
/// backslashes, every escape-shorthand control, raw controls that need
/// `\uXXXX`, multi-byte BMP scalars, the surrogate-boundary scalars
/// `U+D7FF`/`U+E000`, and astral-plane scalars that serialise through
/// surrogate pairs or raw UTF-8.
const STRING_POOL: &[char] = &[
    'a', 'Z', '7', ' ', '"', '\\', '/', '\n', '\t', '\r',
    '\u{8}', '\u{c}', '\u{0}', '\u{1f}', 'é', 'ß', '中',
    '\u{2028}', '\u{d7ff}', '\u{e000}', '\u{fffd}', '😀', '𝄞',
    '\u{10ffff}',
];

fn rand_string(rng: &mut Rng) -> String {
    let len = rng.below(12);
    (0..len).map(|_| STRING_POOL[rng.below(STRING_POOL.len())]).collect()
}

/// Numbers chosen to sit on the writer's edge cases: the signed zeros,
/// whole values straddling the 1e15 integer-formatting cutoff, large
/// negatives, and ordinary reals at assorted magnitudes.
fn rand_num(rng: &mut Rng) -> f64 {
    match rng.below(8) {
        0 => -0.0,
        1 => 0.0,
        2 => 999_999_999_999_999.0, // largest whole below the cutoff
        3 => 1.0e15,                // at the cutoff: float formatting
        4 => -999_999_999_999_999.0,
        5 => rng.below(2_000_001) as f64 - 1_000_000.0,
        6 => rng.normal() * 1e9,
        _ => rng.normal() * 1e-9,
    }
}

fn rand_json(rng: &mut Rng, depth: usize) -> Json {
    let variants = if depth == 0 { 4 } else { 6 };
    match rng.below(variants) {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num(rand_num(rng)),
        3 => Json::Str(rand_string(rng)),
        4 => Json::Arr(
            (0..rng.below(5)).map(|_| rand_json(rng, depth - 1)).collect(),
        ),
        _ => {
            let mut m = BTreeMap::new();
            for i in 0..rng.below(5) {
                // The index prefix keeps keys distinct even when the
                // random suffixes collide.
                m.insert(
                    format!("{i}{}", rand_string(rng)),
                    rand_json(rng, depth - 1),
                );
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_json_serialise_parse_serialise_is_byte_identical() {
    // The ISSUE 6 round-trip contract, as a property: any value tree —
    // including −0.0, whole floats at the 1e15 formatting boundary and
    // astral-plane strings — survives serialise → parse → serialise
    // with byte-identical output.  (String equality rather than
    // `PartialEq` on the trees: f64 equality would call -0.0 == 0.0.)
    for_all(200, |rng| {
        let tree = rand_json(rng, 3);
        let s1 = tree.to_string();
        let back = Json::parse(&s1).expect("writer output must parse");
        let s2 = back.to_string();
        assert_eq!(s1, s2, "round-trip changed bytes");
    });
}

#[test]
fn prop_smooth_preserves_mean_of_constant_and_range() {
    for_all(30, |rng| {
        let len = 5 + rng.below(200);
        let w = 1 + rng.below(30);
        let xs: Vec<f64> = (0..len).map(|_| rng.f64()).collect();
        let s = intdecomp::util::smooth(&xs, w);
        assert_eq!(s.len(), xs.len());
        let (lo, hi) = xs.iter().fold(
            (f64::INFINITY, f64::NEG_INFINITY),
            |(l, h), &x| (l.min(x), h.max(x)),
        );
        for &v in &s {
            assert!(v >= lo - 1e-12 && v <= hi + 1e-12);
        }
    });
}
