//! Property tests for the blocked numeric core (ISSUE 3): the blocked /
//! row-panel-parallel `matmul`/`gram`/`cholesky` against naive references
//! across shapes (including non-multiples of the block size), bit-identity
//! of the scratch-reusing posterior draw and of rank-k dataset ingestion,
//! and the O(1) running-minimum bookkeeping of `Dataset::best`.

use intdecomp::linalg::{
    cholesky, cholesky_into, cholesky_scaled, Matrix,
};
use intdecomp::surrogate::blr::{
    NativePosterior, PosteriorBackend, PosteriorScratch,
};
use intdecomp::surrogate::Dataset;
use intdecomp::util::rng::Rng;

fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::from_vec(r, c, rng.normals(r * c))
}

fn spd(rng: &mut Rng, n: usize) -> Matrix {
    let a = rand_matrix(rng, n + 4, n);
    let mut g = naive_gram(&a);
    for i in 0..n {
        g[(i, i)] += 1.0 + n as f64 / 8.0;
    }
    g
}

/// Reference jik triple loop, no blocking, no parallelism.
fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut out = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0;
            for k in 0..a.cols {
                s += a[(i, k)] * b[(k, j)];
            }
            out[(i, j)] = s;
        }
    }
    out
}

/// Reference Gram matrix via the naive product.
fn naive_gram(a: &Matrix) -> Matrix {
    naive_matmul(&a.transpose(), a)
}

/// Reference left-looking unblocked Cholesky (the pre-ISSUE-3 kernel).
fn naive_cholesky(a: &Matrix, tol: f64) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        let mut d = a[(j, j)];
        for k in 0..j {
            d -= l[(j, k)] * l[(j, k)];
        }
        if d <= tol {
            return None;
        }
        let dj = d.sqrt();
        l[(j, j)] = dj;
        for i in (j + 1)..n {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            l[(i, j)] = s / dj;
        }
    }
    Some(l)
}

/// Shapes straddling the internal 16-row panels and 48-column blocks.
const DIMS: [usize; 10] = [1, 2, 3, 7, 15, 16, 17, 48, 49, 97];

#[test]
fn blocked_matmul_matches_naive_reference() {
    let mut rng = Rng::new(900);
    for &(r, k, c) in &[
        (1, 1, 1),
        (2, 3, 4),
        (7, 5, 9),
        (16, 16, 16),
        (17, 31, 23),
        (48, 48, 48),
        (64, 65, 63),
        (100, 30, 70),
    ] {
        let a = rand_matrix(&mut rng, r, k);
        let b = rand_matrix(&mut rng, k, c);
        let got = a.matmul(&b);
        let want = naive_matmul(&a, &b);
        let scale = 1.0 + want.frob_norm_sq().sqrt();
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!(
                (x - y).abs() < 1e-12 * scale,
                "matmul {r}x{k}x{c}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn blocked_gram_matches_naive_reference() {
    let mut rng = Rng::new(901);
    for &rows in &[1usize, 5, 33, 64] {
        for &cols in &DIMS {
            let a = rand_matrix(&mut rng, rows, cols);
            let got = a.gram();
            let want = naive_gram(&a);
            let scale = 1.0 + want.frob_norm_sq().sqrt();
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!(
                    (x - y).abs() < 1e-12 * scale,
                    "gram {rows}x{cols}: {x} vs {y}"
                );
            }
            // Exactly symmetric (mirrored, not recomputed).
            for i in 0..cols {
                for j in 0..i {
                    assert_eq!(got[(i, j)].to_bits(), got[(j, i)].to_bits());
                }
            }
        }
    }
}

#[test]
fn blocked_cholesky_matches_naive_reference() {
    let mut rng = Rng::new(902);
    for &n in &DIMS {
        let a = spd(&mut rng, n);
        let got = cholesky(&a, 1e-12)
            .unwrap_or_else(|| panic!("blocked factor failed at n={n}"));
        let want = naive_cholesky(&a, 1e-12).expect("naive factor");
        let scale = 1.0 + a.frob_norm_sq().sqrt();
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!(
                (x - y).abs() < 1e-11 * scale,
                "cholesky n={n}: {x} vs {y}"
            );
        }
        // Round trip L Lᵀ = A.
        let llt = got.matmul(&got.transpose());
        for (x, y) in llt.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-10 * scale, "roundtrip n={n}");
        }
    }
}

#[test]
fn blocked_cholesky_scaled_matches_materialised_matrix() {
    let mut rng = Rng::new(903);
    for &n in &[3usize, 17, 49, 97] {
        let g = spd(&mut rng, n);
        let lam: Vec<f64> =
            rng.normals(n).iter().map(|v| v.abs() + 0.2).collect();
        let scale = 0.7;
        let jitter = 1e-9;
        let mut a = g.scale(scale);
        for i in 0..n {
            // Same addition order as the fused fill:
            // (g·scale + lam) + jitter.
            a[(i, i)] += lam[i];
            a[(i, i)] += jitter;
        }
        let fused = cholesky_scaled(&g, scale, &lam, jitter, 0.0)
            .expect("fused factor");
        let plain = cholesky(&a, 0.0).expect("plain factor");
        for (x, y) in fused.data.iter().zip(&plain.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
        }
    }
}

#[test]
fn blocked_cholesky_rejects_non_spd_past_one_block() {
    // Indefinite matrix whose leading 59×59 minor is still SPD: the
    // failure surfaces in the *second* 48-column block's diagonal
    // factor, exercising the blocked bail-out path.
    let mut rng = Rng::new(904);
    let n = 60;
    let mut a = spd(&mut rng, n);
    a[(n - 1, n - 1)] -= 1e4;
    assert!(cholesky(&a, 1e-12).is_none());
    assert!(naive_cholesky(&a, 1e-12).is_none());
}

#[test]
fn cholesky_into_scratch_reuse_is_bit_identical_to_fresh() {
    let mut rng = Rng::new(905);
    let mut l = Matrix::zeros(0, 0);
    for &n in &[5usize, 49, 33, 97, 16] {
        // Deliberately varying n so the scratch is resized up AND down.
        let a = spd(&mut rng, n);
        assert!(cholesky_into(&a, 1e-12, &mut l));
        let fresh = cholesky(&a, 1e-12).unwrap();
        assert_eq!(l.data.len(), fresh.data.len());
        for (x, y) in l.data.iter().zip(&fresh.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
        }
    }
}

#[test]
fn posterior_scratch_draws_match_fresh_allocation_bit_for_bit() {
    // The acceptance property of the PosteriorScratch plumbing: warm
    // scratch reuse across draws of different hyperparameters equals
    // the allocating draw bit for bit on a fixed seed.
    let mut rng = Rng::new(906);
    let p = 67; // spans one full Cholesky block + remainder
    let a = rand_matrix(&mut rng, p + 6, p);
    let mut g = a.gram();
    for i in 0..p {
        g[(i, i)] += 3.0;
    }
    let gv = rng.normals(p);
    let be = NativePosterior;
    let mut scratch = PosteriorScratch::new();
    for trial in 0..5 {
        let lam: Vec<f64> =
            rng.normals(p).iter().map(|v| v.abs() + 0.05).collect();
        let z = rng.normals(p);
        let s2 = 0.2 + 0.3 * trial as f64;
        let (fresh, hld_fresh) = be.draw(&g, &gv, &lam, s2, &z).unwrap();
        let hld_warm =
            be.draw_into(&g, &gv, &lam, s2, &z, &mut scratch).unwrap();
        assert_eq!(hld_fresh.to_bits(), hld_warm.to_bits(), "trial {trial}");
        for (x, y) in fresh.iter().zip(scratch.draw()) {
            assert_eq!(x.to_bits(), y.to_bits(), "trial {trial}");
        }
    }
}

#[test]
fn push_batch_is_bit_identical_to_sequential_push() {
    let mut rng = Rng::new(907);
    let n = 24; // paper scale: P = 301
    let mut seq = Dataset::new(n);
    let mut bat = Dataset::new(n);
    for kb in [1usize, 2, 5, 8, 17] {
        let pairs: Vec<(Vec<i8>, f64)> = (0..kb)
            .map(|_| (rng.spins(n), rng.normal() * 100.0))
            .collect();
        for (x, y) in pairs.clone() {
            seq.push(x, y);
        }
        bat.push_batch(pairs);
        assert_eq!(seq.len(), bat.len());
        for (a, b) in seq.g.data.iter().zip(&bat.g.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "G diverged at kb={kb}");
        }
        for (a, b) in seq.gv.iter().zip(&bat.gv) {
            assert_eq!(a.to_bits(), b.to_bits(), "gv diverged at kb={kb}");
        }
        assert_eq!(seq.yty.to_bits(), bat.yty.to_bits());
        assert_eq!(seq.xs, bat.xs);
        assert_eq!(seq.ys, bat.ys);
        assert_eq!(seq.best(), bat.best());
    }
}

#[test]
fn dataset_best_tracks_running_minimum_incrementally() {
    // best() is O(1) now; cross-check against a full rescan, including
    // tie handling (first minimiser wins) and batch ingestion.
    let mut rng = Rng::new(908);
    let n = 6;
    let mut data = Dataset::new(n);
    let check = |data: &Dataset| {
        let mut bi = None;
        let mut be = f64::INFINITY;
        for (i, &y) in data.ys.iter().enumerate() {
            if y < be {
                be = y;
                bi = Some(i);
            }
        }
        let want = bi.map(|i| (data.xs[i].as_slice(), be));
        assert_eq!(data.best(), want);
    };
    check(&data);
    for round in 0..30 {
        // Quantised ys force frequent exact ties.
        let y = (rng.normal() * 4.0).round();
        data.push(rng.spins(n), y);
        check(&data);
        if round % 5 == 0 {
            let pairs: Vec<(Vec<i8>, f64)> = (0..3)
                .map(|_| (rng.spins(n), (rng.normal() * 4.0).round()))
                .collect();
            data.push_batch(pairs);
            check(&data);
        }
    }
}
