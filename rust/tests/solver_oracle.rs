//! Solver-correctness tests against the exhaustive oracle: deterministic
//! seeds, random 10–14-spin `QuadModel`s, and a brute-force re-derivation
//! of `QuadModel::energy` itself.

use intdecomp::solvers::exhaustive::Exhaustive;
use intdecomp::solvers::{self, IsingSolver, QuadModel};
use intdecomp::util::rng::Rng;

fn random_model(rng: &mut Rng, n: usize) -> QuadModel {
    let mut m = QuadModel::new(n);
    for i in 0..n {
        m.h[i] = rng.normal();
        for k in (i + 1)..n {
            m.set_pair(i, k, rng.normal());
        }
    }
    m.c = rng.normal();
    m
}

/// Naive 2^n minimisation straight from the energy definition.
fn naive_minimum(m: &QuadModel) -> f64 {
    let n = m.n;
    assert!(n <= 16);
    let mut best = f64::INFINITY;
    for bits in 0..(1u64 << n) {
        let x: Vec<i8> = (0..n)
            .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
            .collect();
        best = best.min(m.energy(&x));
    }
    best
}

#[test]
fn energy_matches_brute_force_evaluation() {
    // E(x) = Σ_{i<j} J_ij x_i x_j + Σ_i h_i x_i + c, re-derived with an
    // independent double loop.
    let mut rng = Rng::new(900);
    for n in [10usize, 13] {
        let m = random_model(&mut rng, n);
        for _ in 0..50 {
            let x = rng.spins(n);
            let mut e = m.c;
            for i in 0..n {
                e += m.h[i] * x[i] as f64;
                for j in (i + 1)..n {
                    e += m.j_at(i, j) * x[i] as f64 * x[j] as f64;
                }
            }
            assert!(
                (m.energy(&x) - e).abs() < 1e-9,
                "n={n}: {} vs {e}",
                m.energy(&x)
            );
        }
    }
}

#[test]
fn exhaustive_oracle_matches_naive_minimum() {
    let mut rng = Rng::new(901);
    for n in [10usize, 12] {
        let m = random_model(&mut rng, n);
        let x = Exhaustive.solve(&m, &mut rng);
        assert!(
            (m.energy(&x) - naive_minimum(&m)).abs() < 1e-9,
            "exhaustive missed the naive minimum at n={n}"
        );
    }
}

/// One stochastic solver vs the oracle on a fresh random model.
fn reaches_oracle(name: &str, n: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let m = random_model(&mut rng, n);
    let exact_e = m.energy(&Exhaustive.solve(&m, &mut rng));
    let solver = solvers::by_name(name).unwrap();
    let (x, e) = solver.solve_best(&m, &mut rng, 40);
    assert!(e >= exact_e - 1e-9, "{name} n={n}: beat the exact oracle?!");
    assert!(
        (e - exact_e).abs() < 1e-9,
        "{name} n={n} seed={seed}: reached {e}, oracle {exact_e}"
    );
    assert!((m.energy(&x) - e).abs() < 1e-9);
}

#[test]
fn sa_reaches_oracle_energy() {
    reaches_oracle("sa", 10, 902);
    reaches_oracle("sa", 14, 903);
}

#[test]
fn sqa_reaches_oracle_energy() {
    reaches_oracle("sqa", 10, 904);
    reaches_oracle("sqa", 12, 905);
}

#[test]
fn sq_reaches_oracle_energy() {
    reaches_oracle("sq", 10, 906);
    reaches_oracle("sq", 12, 907);
}

#[test]
fn parallel_restarts_reach_the_oracle_as_well() {
    // The forked-stream fan-out explores at least as well as the serial
    // loop: with 40 restarts on 10 spins it must also hit the optimum.
    let mut rng = Rng::new(908);
    let m = random_model(&mut rng, 10);
    let exact_e = m.energy(&Exhaustive.solve(&m, &mut rng));
    let sa = solvers::sa::SimulatedAnnealing::default();
    let (_, e) =
        solvers::solve_best_parallel(&sa, &m, &mut Rng::new(1), 40, 4);
    assert!((e - exact_e).abs() < 1e-9, "fan-out missed: {e} vs {exact_e}");
}
