//! PJRT artifact integration tests: every artifact vs its native twin.
//!
//! These need `make artifacts` to have run; when `artifacts/` is missing
//! the tests are skipped (so `cargo test` works in a fresh checkout) —
//! `make test` always builds artifacts first.

use std::sync::Arc;

use intdecomp::cost::BinMatrix;
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::minlp::Oracle;
use intdecomp::runtime::{XlaCostOracle, XlaFmTrainer, XlaPosterior, XlaRuntime};
use intdecomp::surrogate::blr::{NativePosterior, PosteriorBackend};
use intdecomp::surrogate::fm::{FactorizationMachine, FmTrainer};
use intdecomp::surrogate::Dataset;
use intdecomp::util::rng::Rng;

fn runtime() -> Option<Arc<XlaRuntime>> {
    XlaRuntime::load_default().map(Arc::new)
}

macro_rules! need_rt {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => {
                eprintln!("skipping: artifacts/ not built");
                return;
            }
        }
    };
}

#[test]
fn cost_artifact_matches_native_cost() {
    let rt = need_rt!();
    let p = generate(&InstanceConfig::default(), 0);
    let mut rng = Rng::new(1);
    let ms: Vec<BinMatrix> = (0..rt.meta.batch + 7)
        .map(|_| BinMatrix::new(p.n(), p.k, rng.spins(p.n_bits())))
        .collect();
    let xla = rt.cost_batch(&p.w, &ms).expect("cost_batch");
    assert_eq!(xla.len(), ms.len());
    for (m, &xc) in ms.iter().zip(&xla) {
        let nc = p.cost(m);
        assert!(
            (nc - xc).abs() < 1e-4 * (1.0 + nc),
            "native {nc} vs xla {xc}"
        );
    }
}

#[test]
fn cost_artifact_handles_rank_deficient_candidates() {
    let rt = need_rt!();
    let p = generate(&InstanceConfig::default(), 1);
    let mut rng = Rng::new(2);
    let mut ms = Vec::new();
    for _ in 0..8 {
        let mut m = BinMatrix::new(p.n(), p.k, rng.spins(p.n_bits()));
        // Force a duplicate / sign-flipped column.
        let c0: Vec<i8> = m.col(0).to_vec();
        let flip = rng.spin();
        for i in 0..p.n() {
            m.set(i, 2, c0[i] * flip);
        }
        ms.push(m);
    }
    let xla = rt.cost_batch(&p.w, &ms).expect("cost_batch");
    for (m, &xc) in ms.iter().zip(&xla) {
        let nc = p.cost(m);
        assert!((nc - xc).abs() < 1e-4 * (1.0 + nc));
    }
}

#[test]
fn gram_artifact_matches_incremental_moments() {
    let rt = need_rt!();
    let mut rng = Rng::new(3);
    let mut data = Dataset::new(rt.meta.nbits);
    for _ in 0..77 {
        data.push(rng.spins(rt.meta.nbits), rng.normal());
    }
    let phi = data.phi_matrix();
    let (g, gv, yty) = rt.gram(&phi, &data.ys).expect("gram");
    for (a, b) in g.data.iter().zip(&data.g.data) {
        assert!((a - b).abs() < 5e-3, "gram entry {a} vs {b}");
    }
    for (a, b) in gv.iter().zip(&data.gv) {
        assert!((a - b).abs() < 5e-3);
    }
    assert!((yty - data.yty).abs() < 5e-3 * (1.0 + data.yty.abs()));
}

#[test]
fn posterior_artifact_matches_native_backend() {
    let rt = need_rt!();
    let mut rng = Rng::new(4);
    let mut data = Dataset::new(rt.meta.nbits);
    for _ in 0..200 {
        let x = rng.spins(rt.meta.nbits);
        let y = rng.normal();
        data.push(x, y);
    }
    let lam = vec![2.0; rt.meta.p];
    // Deterministic comparison at z = 0 (posterior mean).
    let z = vec![0.0; rt.meta.p];
    let xp = XlaPosterior { rt: rt.clone() };
    let (a_xla, _) = xp.draw(&data.g, &data.gv, &lam, 0.7, &z).unwrap();
    let (a_nat, _) =
        NativePosterior.draw(&data.g, &data.gv, &lam, 0.7, &z).unwrap();
    let max_err = a_xla
        .iter()
        .zip(&a_nat)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_err < 5e-3, "posterior mean disagreement {max_err}");
}

#[test]
fn fm_artifact_trains_comparably_to_native() {
    let rt = need_rt!();
    let mut rng = Rng::new(5);
    let n = rt.meta.nbits;
    let k_fm = rt.meta.kfms[0];
    // Planted FM data.
    let mut truth = FactorizationMachine::new(n, 2, &mut rng);
    truth.w = rng.normals(n);
    truth.v = intdecomp::linalg::Matrix::from_vec(
        n,
        2,
        rng.normals(n * 2),
    );
    let xs: Vec<Vec<i8>> = (0..120).map(|_| rng.spins(n)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| truth.predict(x)).collect();

    let mse = |fm: &FactorizationMachine| -> f64 {
        xs.iter()
            .zip(&ys)
            .map(|(x, &y)| {
                let e = fm.predict(x) - y;
                e * e
            })
            .sum::<f64>()
            / xs.len() as f64
    };

    // Native training.
    let mut fm_native = FactorizationMachine::new(n, k_fm, &mut rng);
    fm_native.steps = 300;
    fm_native.lr = 0.05;
    fm_native.train(&xs, &ys);
    // XLA training (same step budget: 3 bundles x fm_steps=100).
    let mut fm_xla = FactorizationMachine::new(n, k_fm, &mut rng);
    let trainer = XlaFmTrainer { rt: rt.clone(), bundles: 3 };
    let mut w0 = fm_xla.w0;
    let mut w = fm_xla.w.clone();
    let mut v = fm_xla.v.clone();
    trainer
        .train_epoch(&xs, &ys, &mut w0, &mut w, &mut v, 0.05)
        .unwrap();
    fm_xla.w0 = w0;
    fm_xla.w = w;
    fm_xla.v = v;

    let var = {
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        ys.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>()
            / ys.len() as f64
    };
    let (ln, lx) = (mse(&fm_native), mse(&fm_xla));
    assert!(ln < 0.5 * var, "native FM did not learn: {ln} vs var {var}");
    assert!(lx < 0.5 * var, "xla FM did not learn: {lx} vs var {var}");
}

#[test]
fn xla_cost_oracle_equivalents_preserve_cost() {
    let rt = need_rt!();
    let p = generate(&InstanceConfig::default(), 0);
    let oracle = XlaCostOracle { rt, problem: p.clone() };
    let mut rng = Rng::new(6);
    let x = rng.spins(p.n_bits());
    let y = oracle.eval(&x);
    assert!((y - p.cost_spins(&x)).abs() < 1e-4 * (1.0 + y));
    for eq in oracle.equivalents(&x).into_iter().take(5) {
        assert!((oracle.eval(&eq) - y).abs() < 1e-4 * (1.0 + y));
    }
}

#[test]
fn bbo_through_xla_cost_path_runs() {
    let rt = need_rt!();
    let p = generate(&InstanceConfig::default(), 0);
    let oracle = XlaCostOracle { rt, problem: p.clone() };
    let sa = intdecomp::solvers::sa::SimulatedAnnealing {
        sweeps: 10,
        ..Default::default()
    };
    let cfg = intdecomp::bbo::BboConfig::smoke_scale(p.n_bits(), 6);
    let run = intdecomp::bbo::run(
        &oracle,
        &intdecomp::bbo::Algorithm::Nbocs { sigma2: 0.1 },
        &sa,
        &cfg,
        &intdecomp::bbo::Backends::default(),
        9,
    );
    assert_eq!(run.ys.len(), cfg.n_init + cfg.iters);
    // Best-so-far from XLA costs must match a native re-evaluation.
    assert!(
        (p.cost_spins(&run.best_x) - run.best_y).abs()
            < 1e-4 * (1.0 + run.best_y)
    );
}
