//! Fault injection against a live serve daemon: disconnects mid-run,
//! deadlines, slow-loris and oversized lines, per-client quotas, the
//! bounded admission queue, and cache-budget degradation — proving the
//! daemon degrades instead of leaking permits, leaking memory, or
//! crashing, and that every run that completes stays byte-identical.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use intdecomp::serve::{
    self, bare_request, compress_request, compress_request_with_deadline,
    CacheBudget, Endpoint, ServeConfig, Server,
};
use intdecomp::shard::ModelSpec;
use intdecomp::util::json::Json;

fn spec(layers: usize, iters: usize, instance_seed: u64) -> ModelSpec {
    ModelSpec {
        n: 4,
        d: 8,
        k: 2,
        gamma: 0.8,
        instance_seed,
        layers,
        iters,
        restarts: 2,
        batch_size: 1,
        augment: false,
        restart_workers: 1,
        algo: "nbocs".into(),
        solver: "sa".into(),
        seed: 11,
        cache_key_raw: false,
    }
}

/// A request small enough to finish in well under a second.
fn tiny_spec() -> ModelSpec {
    spec(1, 4, 9)
}

/// A request that would grind for a long time if nothing aborted it —
/// the cancellation paths must cut it short at an iteration boundary.
fn slow_spec() -> ModelSpec {
    spec(1, 200_000, 9)
}

type Running = (Arc<Server>, Endpoint, thread::JoinHandle<anyhow::Result<()>>);

fn start(tweak: impl FnOnce(&mut ServeConfig)) -> Running {
    let mut cfg = ServeConfig {
        endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
        max_inflight: 2,
        workers: 2,
        ..Default::default()
    };
    tweak(&mut cfg);
    let server = Arc::new(Server::bind(cfg).expect("bind on a free port"));
    let endpoint = server.local_endpoint().clone();
    let srv = Arc::clone(&server);
    let handle = thread::spawn(move || srv.run());
    (server, endpoint, handle)
}

fn stop(endpoint: &Endpoint, handle: thread::JoinHandle<anyhow::Result<()>>) {
    let bye = serve::request(endpoint, &bare_request("shutdown")).unwrap();
    let last = Json::parse(bye.last().unwrap()).unwrap();
    assert_eq!(last.get("type").and_then(Json::as_str), Some("bye"));
    handle.join().unwrap().unwrap();
}

fn tcp_addr(endpoint: &Endpoint) -> String {
    match endpoint {
        Endpoint::Tcp(addr) => addr.clone(),
        #[cfg(unix)]
        Endpoint::Unix(p) => {
            panic!("test daemon must be TCP, got {}", p.display())
        }
    }
}

fn stats(endpoint: &Endpoint) -> Json {
    let lines = serve::request(endpoint, &bare_request("stats")).unwrap();
    Json::parse(lines.last().unwrap()).unwrap()
}

fn num(s: &Json, key: &str) -> u64 {
    s.get(key)
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("stats missing {key}: {}", s.to_string()))
}

/// Poll the stats endpoint until `pred` holds (the daemon's counters
/// move asynchronously to the fault we injected).
fn poll_stats(
    endpoint: &Endpoint,
    what: &str,
    pred: impl Fn(&Json) -> bool,
) -> Json {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let s = stats(endpoint);
        if pred(&s) {
            return s;
        }
        assert!(
            Instant::now() < deadline,
            "timed out waiting for {what}; last stats: {}",
            s.to_string()
        );
        thread::sleep(Duration::from_millis(20));
    }
}

/// Send `line` on a raw TCP connection without reading the response.
fn raw_send(addr: &str, line: &str) -> TcpStream {
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    conn.flush().unwrap();
    conn
}

fn read_lines(conn: TcpStream) -> Vec<String> {
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut out = Vec::new();
    for l in BufReader::new(conn).lines() {
        match l {
            Ok(l) if l.trim().is_empty() => continue,
            // Raw connections see the v2 greeting first; these tests
            // are about the response lines after it.
            Ok(l) if out.is_empty() && serve::is_hello(&l) => continue,
            Ok(l) => out.push(l),
            Err(_) => break,
        }
    }
    out
}

#[test]
fn mid_stream_disconnect_cancels_the_run_and_releases_the_permit() {
    let (_server, endpoint, handle) = start(|c| c.max_inflight = 1);
    let addr = tcp_addr(&endpoint);
    let conn = raw_send(&addr, &compress_request(&slow_spec()));
    poll_stats(&endpoint, "the slow request to be admitted", |s| {
        num(s, "inflight") == 1
    });
    drop(conn); // the client vanishes mid-run
    let s = poll_stats(&endpoint, "the disconnect to cancel the run", |s| {
        num(s, "cancelled") == 1
    });
    assert_eq!(num(&s, "completed"), 0);
    poll_stats(&endpoint, "the permit to be released", |s| {
        num(s, "inflight") == 0
    });
    // The freed slot serves a normal request to completion.
    let lines =
        serve::request(&endpoint, &compress_request(&tiny_spec())).unwrap();
    let done = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(done.get("type").and_then(Json::as_str), Some("done"));
    stop(&endpoint, handle);
}

#[test]
fn deadline_ms_one_ends_with_a_deadline_line_and_frees_the_slot() {
    let (_server, endpoint, handle) = start(|c| c.max_inflight = 1);
    let lines = serve::request(
        &endpoint,
        &compress_request_with_deadline(&slow_spec(), 1),
    )
    .unwrap();
    let last = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(
        last.get("type").and_then(Json::as_str),
        Some("deadline"),
        "a 1 ms deadline on a long request must abort: {}",
        lines.last().unwrap()
    );
    let s = stats(&endpoint);
    assert_eq!(num(&s, "deadline"), 1);
    assert_eq!(num(&s, "inflight"), 0, "the permit must be released");
    // The slot is free for real work.
    let ok =
        serve::request(&endpoint, &compress_request(&tiny_spec())).unwrap();
    let done = Json::parse(ok.last().unwrap()).unwrap();
    assert_eq!(done.get("type").and_then(Json::as_str), Some("done"));
    stop(&endpoint, handle);
}

#[test]
fn slow_loris_partial_line_times_out_with_400() {
    let (_server, endpoint, handle) = start(|c| c.line_timeout_ms = 200);
    let addr = tcp_addr(&endpoint);
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.write_all(br#"{"type":"pi"#).unwrap(); // never finished
    conn.flush().unwrap();
    let lines = read_lines(conn);
    assert_eq!(lines.len(), 1, "one 400 line then close: {lines:?}");
    let err = Json::parse(&lines[0]).unwrap();
    assert_eq!(err.get("code").and_then(Json::as_u64), Some(400));
    // Other connections are untouched.
    let pong = serve::request(&endpoint, &bare_request("ping")).unwrap();
    let p = Json::parse(&pong[0]).unwrap();
    assert_eq!(p.get("type").and_then(Json::as_str), Some("pong"));
    stop(&endpoint, handle);
}

#[test]
fn oversized_line_gets_400_without_killing_the_accept_loop() {
    let (_server, endpoint, handle) = start(|_| {});
    let addr = tcp_addr(&endpoint);
    let mut conn = TcpStream::connect(&addr).unwrap();
    // 2 MiB of garbage, no newline: the reader must cut it off at the
    // 1 MiB cap rather than buffer forever.
    let chunk = vec![b'x'; 64 * 1024];
    for _ in 0..32 {
        if conn.write_all(&chunk).is_err() {
            break; // daemon already closed on us — also acceptable
        }
    }
    let lines = read_lines(conn);
    if let Some(first) = lines.first() {
        let err = Json::parse(first).unwrap();
        assert_eq!(err.get("code").and_then(Json::as_u64), Some(400));
    }
    // The daemon survives and keeps serving.
    let pong = serve::request(&endpoint, &bare_request("ping")).unwrap();
    let p = Json::parse(&pong[0]).unwrap();
    assert_eq!(p.get("type").and_then(Json::as_str), Some("pong"));
    stop(&endpoint, handle);
}

#[test]
fn garbage_line_gets_400_and_the_connection_survives() {
    let (_server, endpoint, handle) = start(|_| {});
    let addr = tcp_addr(&endpoint);
    let mut conn = raw_send(&addr, "torn {garbage");
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    // First the v2 greeting, then the 400 for the garbage line.
    reader.read_line(&mut line).unwrap();
    assert!(serve::is_hello(line.trim()), "expected hello: {line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    let err = Json::parse(line.trim()).unwrap();
    assert_eq!(err.get("code").and_then(Json::as_u64), Some(400));
    // Same connection, next line: still served.
    conn.write_all(bare_request("ping").as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let p = Json::parse(line.trim()).unwrap();
    assert_eq!(p.get("type").and_then(Json::as_str), Some("pong"));
    stop(&endpoint, handle);
}

#[test]
fn per_client_quota_rejects_while_capacity_remains() {
    let (_server, endpoint, handle) = start(|c| {
        c.max_inflight = 4;
        c.max_per_client = 1;
    });
    let addr = tcp_addr(&endpoint);
    let conn = raw_send(&addr, &compress_request(&slow_spec()));
    poll_stats(&endpoint, "the slow request to be admitted", |s| {
        num(s, "inflight") == 1
    });
    // Same peer IP: over quota despite 3 free global slots.
    let lines =
        serve::request(&endpoint, &compress_request(&tiny_spec())).unwrap();
    assert_eq!(lines.len(), 1);
    let err = Json::parse(&lines[0]).unwrap();
    assert_eq!(err.get("code").and_then(Json::as_u64), Some(429));
    assert!(
        err.get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("client quota"),
        "the rejection must name the quota: {}",
        lines[0]
    );
    drop(conn);
    poll_stats(&endpoint, "the quota holder to be cancelled", |s| {
        num(s, "cancelled") == 1 && num(s, "inflight") == 0
    });
    // Quota freed: the same client is admitted again.
    let ok =
        serve::request(&endpoint, &compress_request(&tiny_spec())).unwrap();
    let done = Json::parse(ok.last().unwrap()).unwrap();
    assert_eq!(done.get("type").and_then(Json::as_str), Some("done"));
    stop(&endpoint, handle);
}

#[test]
fn admission_queue_holds_requests_and_overflow_bounces() {
    let (_server, endpoint, handle) = start(|c| {
        c.max_inflight = 1;
        c.queue = 1;
    });
    let addr = tcp_addr(&endpoint);
    let conn = raw_send(&addr, &compress_request(&slow_spec()));
    poll_stats(&endpoint, "the slow request to be admitted", |s| {
        num(s, "inflight") == 1
    });
    // Second request parks in the queue instead of bouncing.
    let queued_endpoint = endpoint.clone();
    let queued = thread::spawn(move || {
        serve::request(&queued_endpoint, &compress_request(&tiny_spec()))
    });
    poll_stats(&endpoint, "the second request to queue", |s| {
        num(s, "queued") == 1
    });
    // Third request: queue full -> explicit 429.
    let lines =
        serve::request(&endpoint, &compress_request(&tiny_spec())).unwrap();
    let err = Json::parse(&lines[0]).unwrap();
    assert_eq!(err.get("code").and_then(Json::as_u64), Some(429));
    assert!(
        err.get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("at capacity"),
        "overflow rejection: {}",
        lines[0]
    );
    // Disconnect the running request: its cancellation must hand the
    // slot to the queued one, which then completes normally.
    drop(conn);
    let got = queued.join().unwrap().unwrap();
    let done = Json::parse(got.last().unwrap()).unwrap();
    assert_eq!(done.get("type").and_then(Json::as_str), Some("done"));
    let s = poll_stats(&endpoint, "final counters", |s| {
        num(s, "inflight") == 0 && num(s, "queued") == 0
    });
    assert_eq!(num(&s, "completed"), 1);
    assert_eq!(num(&s, "cancelled"), 1);
    assert_eq!(num(&s, "rejected"), 1);
    stop(&endpoint, handle);
}

#[test]
fn zero_cache_budget_is_pass_through_end_to_end() {
    let (_server, endpoint, handle) = start(|c| {
        c.cache_budget = CacheBudget { entries: Some(0), bytes: None };
    });
    let line = compress_request(&tiny_spec());
    let first = serve::request(&endpoint, &line).unwrap();
    let second = serve::request(&endpoint, &line).unwrap();
    let r1 = Json::parse(first.last().unwrap()).unwrap();
    let r2 = Json::parse(second.last().unwrap()).unwrap();
    assert_eq!(r1.get("type").and_then(Json::as_str), Some("done"));
    assert_eq!(
        r1.get("report").and_then(Json::as_str),
        r2.get("report").and_then(Json::as_str),
        "pass-through mode must not change results"
    );
    let s = stats(&endpoint);
    assert_eq!(num(&s, "completed"), 2);
    assert_eq!(num(&s, "cache_caches"), 0, "nothing may be cached");
    assert_eq!(num(&s, "cache_entries"), 0);
    assert_eq!(num(&s, "cache_hits"), 0);
    stop(&endpoint, handle);
}

#[test]
fn eviction_then_recompute_is_byte_identical_end_to_end() {
    // A 1-entry budget forces every request's caches out at the next
    // sweep — the hardest possible eviction schedule.
    let (_server, endpoint, handle) = start(|c| {
        c.cache_budget = CacheBudget { entries: Some(1), bytes: None };
    });
    let line = compress_request(&tiny_spec());
    let first = serve::request(&endpoint, &line).unwrap();
    let s = stats(&endpoint);
    assert!(
        num(&s, "cache_evicted_caches") >= 1,
        "the sweep after the request must evict: {}",
        s.to_string()
    );
    assert!(num(&s, "cache_entries") <= 1, "registry over budget");
    let second = serve::request(&endpoint, &line).unwrap();
    // Streamed record lines are deterministic byte-for-byte; the done
    // line carries a wall-clock elapsed_s, so compare its report field.
    assert_eq!(
        first[..first.len() - 1],
        second[..second.len() - 1],
        "recompute after eviction must stream identical records"
    );
    let rep = |lines: &[String]| {
        Json::parse(lines.last().unwrap())
            .unwrap()
            .get("report")
            .and_then(Json::as_str)
            .map(str::to_owned)
            .expect("done line carries the report")
    };
    assert_eq!(
        rep(&first),
        rep(&second),
        "recompute after eviction must be byte-identical"
    );
    let s = stats(&endpoint);
    assert!(num(&s, "cache_entries") <= 1, "registry over budget");
    assert!(num(&s, "cache_evicted_caches") >= 2);
    stop(&endpoint, handle);
}
