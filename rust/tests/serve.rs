//! End-to-end tests of the serve daemon over real sockets: byte-identity
//! of served reports against the engine, cross-request cache hits,
//! admission rejection, and the state-dir advisory lock.

use std::sync::Arc;
use std::thread;

use intdecomp::engine::Engine;
use intdecomp::serve::{
    self, bare_request, compress_request, Endpoint, ServeConfig, Server,
};
use intdecomp::shard::{self, LayerRecord, ModelSpec};
use intdecomp::util::json::Json;

fn tiny_spec() -> ModelSpec {
    ModelSpec {
        n: 4,
        d: 8,
        k: 2,
        gamma: 0.8,
        instance_seed: 9,
        layers: 2,
        iters: 5,
        restarts: 3,
        batch_size: 1,
        augment: false,
        restart_workers: 1,
        algo: "nbocs".into(),
        solver: "sa".into(),
        seed: 11,
        cache_key_raw: false,
    }
}

type Running = (Arc<Server>, Endpoint, thread::JoinHandle<anyhow::Result<()>>);

fn start(max_inflight: usize) -> Running {
    let server = Arc::new(
        Server::bind(ServeConfig {
            endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
            max_inflight,
            workers: 2,
            ..Default::default()
        })
        .expect("bind on a free port"),
    );
    let endpoint = server.local_endpoint().clone();
    let srv = Arc::clone(&server);
    let handle = thread::spawn(move || srv.run());
    (server, endpoint, handle)
}

fn stop(endpoint: &Endpoint, handle: thread::JoinHandle<anyhow::Result<()>>) {
    let bye = serve::request(endpoint, &bare_request("shutdown")).unwrap();
    let last = Json::parse(bye.last().unwrap()).unwrap();
    assert_eq!(last.get("type").and_then(Json::as_str), Some("bye"));
    handle.join().unwrap().unwrap();
}

#[test]
fn served_compression_is_byte_identical_and_warms_the_shared_cache() {
    let spec = tiny_spec();
    let fp = spec.fingerprint();

    // Reference: the identical workload straight through the engine,
    // exactly as `compress-model --report` builds it.
    let jobs: Vec<_> =
        (0..spec.layers).map(|i| spec.job(i).unwrap()).collect();
    let eng = Engine::new(spec.engine_config(2, false));
    let results = eng.compress_all(jobs);
    let records: Vec<LayerRecord> = results
        .iter()
        .enumerate()
        .map(|(i, r)| LayerRecord::from_result(i, r))
        .collect();
    let expected = shard::deterministic_report(&records);

    let (_server, endpoint, handle) = start(2);
    let lines = serve::request(&endpoint, &compress_request(&spec)).unwrap();
    // One streamed record line per layer plus the terminal done line,
    // each record byte-identical to the shard result-log format.
    assert_eq!(lines.len(), spec.layers + 1);
    for (line, rec) in lines.iter().zip(&records) {
        assert_eq!(line, &rec.to_json_line(&fp).unwrap());
        assert_eq!(
            LayerRecord::parse_line(line, &fp).unwrap().name,
            rec.name
        );
    }
    let done = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(done.get("type").and_then(Json::as_str), Some("done"));
    assert_eq!(
        done.get("fingerprint").and_then(Json::as_str),
        Some(fp.as_str())
    );
    assert_eq!(
        done.get("report").and_then(Json::as_str),
        Some(expected.as_str()),
        "served report must be byte-identical to the engine's"
    );

    // A second identical request: same bytes back, and the daemon's
    // cross-request cache now shows hits for the shared fingerprint.
    let again = serve::request(&endpoint, &compress_request(&spec)).unwrap();
    let done2 = Json::parse(again.last().unwrap()).unwrap();
    assert_eq!(
        done2.get("report").and_then(Json::as_str),
        Some(expected.as_str())
    );
    let stats = serve::request(&endpoint, &bare_request("stats")).unwrap();
    let s = Json::parse(stats.last().unwrap()).unwrap();
    assert_eq!(s.get("type").and_then(Json::as_str), Some("stats"));
    assert_eq!(s.get("completed").and_then(Json::as_u64), Some(2));
    assert_eq!(s.get("admitted").and_then(Json::as_u64), Some(2));
    assert_eq!(s.get("cache_caches").and_then(Json::as_usize), Some(spec.layers));
    let hits = s.get("cache_hits").and_then(Json::as_u64).unwrap();
    assert!(hits > 0, "second identical request must hit the shared cache");
    assert!(s.get("latency_p99_s").and_then(Json::as_f64).is_some());
    stop(&endpoint, handle);
}

#[test]
fn full_daemon_answers_429_and_keeps_serving() {
    // max_inflight = 0: every compress is an over-admission, which
    // makes the rejection path deterministic.
    let (_server, endpoint, handle) = start(0);
    let lines =
        serve::request(&endpoint, &compress_request(&tiny_spec())).unwrap();
    assert_eq!(lines.len(), 1);
    let err = Json::parse(&lines[0]).unwrap();
    assert_eq!(err.get("type").and_then(Json::as_str), Some("error"));
    assert_eq!(err.get("code").and_then(Json::as_u64), Some(429));
    // The daemon survives the rejection: control requests still work
    // and the counters recorded it.
    let pong = serve::request(&endpoint, &bare_request("ping")).unwrap();
    let p = Json::parse(&pong[0]).unwrap();
    assert_eq!(p.get("type").and_then(Json::as_str), Some("pong"));
    let stats = serve::request(&endpoint, &bare_request("stats")).unwrap();
    let s = Json::parse(stats.last().unwrap()).unwrap();
    assert_eq!(s.get("rejected").and_then(Json::as_u64), Some(1));
    assert_eq!(s.get("admitted").and_then(Json::as_u64), Some(0));
    assert_eq!(s.get("max_inflight").and_then(Json::as_u64), Some(0));
    stop(&endpoint, handle);
}

#[test]
fn malformed_requests_get_400() {
    let (_server, endpoint, handle) = start(1);
    for bad in [
        "torn {garbage",
        r#"{"schema":"intdecomp-serve-v2","type":"frobnicate"}"#,
        r#"{"schema":"intdecomp-serve-v2","type":"compress"}"#,
    ] {
        let lines = serve::request(&endpoint, bad).unwrap();
        let err = Json::parse(&lines[0]).unwrap();
        assert_eq!(err.get("type").and_then(Json::as_str), Some("error"));
        assert_eq!(err.get("code").and_then(Json::as_u64), Some(400));
    }
    // A v1 client (no schema member) gets a typed 400 naming the
    // schema this daemon speaks, never a silent accept.
    let lines =
        serve::request(&endpoint, r#"{"type":"ping"}"#).unwrap();
    let err = Json::parse(&lines[0]).unwrap();
    assert_eq!(err.get("code").and_then(Json::as_u64), Some(400));
    assert!(err
        .get("error")
        .and_then(Json::as_str)
        .unwrap()
        .contains("intdecomp-serve-v2"));
    stop(&endpoint, handle);
}

#[test]
fn connection_greets_with_hello_and_capabilities() {
    let (_server, endpoint, handle) = start(1);
    let addr = match &endpoint {
        Endpoint::Tcp(a) => a.clone(),
        #[cfg(unix)]
        Endpoint::Unix(_) => unreachable!("test binds TCP"),
    };
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    // The daemon speaks first: one hello line before any request.
    let mut first = String::new();
    r.read_line(&mut first).unwrap();
    let j = Json::parse(first.trim()).unwrap();
    assert_eq!(j.get("type").and_then(Json::as_str), Some("hello"));
    assert_eq!(
        j.get("schema").and_then(Json::as_str),
        Some("intdecomp-serve-v2")
    );
    let caps: Vec<&str> = j
        .get("capabilities")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_str)
        .collect();
    assert_eq!(caps, vec!["jobs", "resume", "warm"]);
    // The same connection still serves a properly tagged request.
    writeln!(s, "{}", bare_request("ping")).unwrap();
    let mut reply = String::new();
    r.read_line(&mut reply).unwrap();
    let p = Json::parse(reply.trim()).unwrap();
    assert_eq!(p.get("type").and_then(Json::as_str), Some("pong"));
    drop(r);
    drop(s);
    stop(&endpoint, handle);
}

#[test]
fn state_daemon_warm_starts_a_perturbed_respin() {
    let dir = std::env::temp_dir()
        .join(format!("intdecomp_serve_warm_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let server = Arc::new(
        Server::bind(ServeConfig {
            endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
            max_inflight: 1,
            workers: 2,
            state_dir: Some(dir.clone()),
            ..Default::default()
        })
        .unwrap(),
    );
    let endpoint = server.local_endpoint().clone();
    let srv = Arc::clone(&server);
    let handle = thread::spawn(move || srv.run());

    // First contact: cold, but every layer's surrogate state persists.
    let spec = tiny_spec();
    let lines = serve::request(&endpoint, &compress_request(&spec)).unwrap();
    let done = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(done.get("type").and_then(Json::as_str), Some("done"));
    assert_eq!(done.get("warm").and_then(Json::as_bool), Some(false));
    assert!(dir.join("warm").is_dir(), "states persisted under DIR/warm");

    // A perturbed respin: new run seed = new fingerprint, but the same
    // instance keys — every layer warm-starts from the stored states.
    let mut spec2 = tiny_spec();
    spec2.seed = 12;
    assert_ne!(spec2.fingerprint(), spec.fingerprint());
    let lines2 =
        serve::request(&endpoint, &compress_request(&spec2)).unwrap();
    let done2 = Json::parse(lines2.last().unwrap()).unwrap();
    assert_eq!(done2.get("type").and_then(Json::as_str), Some("done"));
    assert_eq!(done2.get("warm").and_then(Json::as_bool), Some(true));
    assert_eq!(
        done2.get("warm_layers").and_then(Json::as_usize),
        Some(spec.layers)
    );
    assert!(done2
        .get("warm_source")
        .and_then(Json::as_str)
        .unwrap()
        .contains("warm"));

    stop(&endpoint, handle);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn state_dir_lock_keeps_a_second_daemon_out() {
    let dir = std::env::temp_dir()
        .join(format!("intdecomp_serve_lock_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = || ServeConfig {
        endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
        max_inflight: 1,
        workers: 1,
        state_dir: Some(dir.clone()),
        ..Default::default()
    };
    let first = Server::bind(cfg()).unwrap();
    let err = Server::bind(cfg()).unwrap_err();
    assert!(
        format!("{err:#}").contains("held by live process"),
        "unexpected error: {err:#}"
    );
    drop(first);
    let _second = Server::bind(cfg()).unwrap();
    drop(_second);
    let _ = std::fs::remove_dir_all(&dir);
}

#[cfg(unix)]
#[test]
fn unix_socket_endpoint_serves_and_cleans_up() {
    let path = std::env::temp_dir()
        .join(format!("intdecomp_serve_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let server = Arc::new(
        Server::bind(ServeConfig {
            endpoint: Endpoint::Unix(path.clone()),
            max_inflight: 1,
            workers: 1,
            ..Default::default()
        })
        .unwrap(),
    );
    let endpoint = server.local_endpoint().clone();
    let srv = Arc::clone(&server);
    let handle = thread::spawn(move || srv.run());
    let pong = serve::request(&endpoint, &bare_request("ping")).unwrap();
    let p = Json::parse(&pong[0]).unwrap();
    assert_eq!(p.get("type").and_then(Json::as_str), Some("pong"));
    stop(&endpoint, handle);
    drop(server);
    assert!(!path.exists(), "socket file is removed when the server drops");
}
