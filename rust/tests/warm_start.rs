//! Integration tests of the versioned surrogate-state subsystem
//! (ISSUE 10): byte-identical round trips of exported states across
//! every surrogate family, a 300-case randomized round-trip property,
//! typed rejection of torn/corrupt documents at every truncation
//! offset, and the end-to-end warm-start acceptance bound — a warm
//! run reaches the cold best in at most half the cold evaluation
//! budget.

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig, SurrogateState, WarmStart};
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::minlp::Oracle;
use intdecomp::solvers::sa::SimulatedAnnealing;
use intdecomp::surrogate::Dataset;
use intdecomp::util::cancel::CancelToken;
use intdecomp::util::rng::Rng;

fn problem(seed: u64) -> intdecomp::cost::Problem {
    generate(&InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed }, 0)
}

fn all_stateful_algorithms() -> Vec<Algorithm> {
    vec![
        Algorithm::Vbocs,
        Algorithm::Nbocs { sigma2: 0.1 },
        Algorithm::Gbocs { beta: 0.001 },
        Algorithm::Fmqa { k_fm: 8 },
        Algorithm::Rfmqa { k_fm: 8, eps: 0.1 },
    ]
}

#[test]
fn exported_states_roundtrip_byte_identically_for_every_algorithm() {
    let p = problem(5005);
    let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
    let cfg = BboConfig::smoke_scale(p.n_bits(), 6).with_restarts(2);
    let never = CancelToken::never();
    for algo in all_stateful_algorithms() {
        let w = bbo::run_warm(&p, &algo, &sa, &cfg, &Backends::default(), 7, &never, None, true)
            .unwrap();
        let state = w.state.expect("state export was requested");
        assert_eq!(
            state.surrogate.as_ref().map(|s| s.kind.clone()),
            algo.state_kind(),
            "{algo:?} must export its own kind"
        );
        let text = state.to_string_strict().unwrap();
        let back = SurrogateState::parse(&text).unwrap();
        assert_eq!(
            back.to_string_strict().unwrap(),
            text,
            "{algo:?}: state round trip must be byte-identical"
        );
        // The same property through the warm-start envelope with the
        // donor's best point attached.
        let warm = WarmStart::new(back).with_prev_best(w.run.best_x.clone(), w.run.best_y);
        let wtext = warm.to_string_strict().unwrap();
        let wback = WarmStart::parse(&wtext).unwrap();
        assert_eq!(
            wback.to_string_strict().unwrap(),
            wtext,
            "{algo:?}: warm-start round trip must be byte-identical"
        );
        let (x, y) = wback.prev_best.unwrap();
        assert_eq!(x, w.run.best_x);
        assert_eq!(y.to_bits(), w.run.best_y.to_bits());
    }
}

#[test]
fn random_states_roundtrip_byte_identically_300_cases() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0u64..300 {
        let n_bits = 2 + (case as usize % 9);
        let rows = (case as usize * 7) % 17;
        let mut data = Dataset::new(n_bits);
        for r in 0..rows {
            // Mix magnitudes and signed zeros — the serialisation must
            // preserve every bit pattern of a finite f64.
            let y = match (case + r as u64) % 5 {
                0 => -0.0,
                1 => 0.0,
                2 => rng.normal() * 1e12,
                3 => rng.normal() * 1e-300,
                _ => rng.normal(),
            };
            data.push(rng.spins(n_bits), y);
        }
        let state = SurrogateState { n_bits, dataset: data, surrogate: None };
        let text = state.to_string_strict().unwrap();
        let back = SurrogateState::parse(&text).unwrap();
        assert_eq!(back.to_string_strict().unwrap(), text, "case {case}");
        assert_eq!(back.dataset.len(), rows, "case {case}");
        for (a, b) in back.dataset.ys.iter().zip(state.dataset.ys.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case}");
        }
        // Half the cases also ride the WarmStart envelope.
        if case % 2 == 0 {
            let warm = WarmStart::new(back).with_prev_best(rng.spins(n_bits), rng.normal());
            let wtext = warm.to_string_strict().unwrap();
            let wback = WarmStart::parse(&wtext).unwrap();
            assert_eq!(wback.to_string_strict().unwrap(), wtext, "case {case}");
        }
    }
}

#[test]
fn non_finite_costs_fail_strict_serialisation_typed() {
    let mut data = Dataset::new(2);
    data.push(vec![1, -1], f64::NAN);
    let state = SurrogateState { n_bits: 2, dataset: data, surrogate: None };
    assert!(
        state.to_string_strict().is_err(),
        "a NaN cost must be a typed serialisation error, not silent JSON"
    );
}

#[test]
fn every_truncation_of_a_state_document_is_a_typed_error() {
    // A real exported document (fitted nBOCS posterior), torn at every
    // byte offset: each prefix must fail typed — parse never panics
    // and never silently accepts a torn document.
    let p = problem(5005);
    let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
    let cfg = BboConfig::smoke_scale(p.n_bits(), 4).with_restarts(2);
    let w = bbo::run_warm(
        &p,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa,
        &cfg,
        &Backends::default(),
        3,
        &CancelToken::never(),
        None,
        true,
    )
    .unwrap();
    let text = w.state.unwrap().to_string_strict().unwrap();
    assert!(text.is_ascii(), "state documents are ASCII JSON");
    for cut in 0..text.len() {
        assert!(
            SurrogateState::parse(&text[..cut]).is_err(),
            "torn at offset {cut} must be rejected"
        );
    }
    assert!(SurrogateState::parse(&text).is_ok());
    // A wrong schema tag is a typed rejection too, not a misread.
    let retagged = text.replace("intdecomp-surrogate-state-v1", "intdecomp-surrogate-state-v9");
    assert!(SurrogateState::parse(&retagged).is_err());
}

#[test]
fn warm_start_reaches_the_cold_best_in_at_most_half_the_evals() {
    let p = problem(5005);
    let sa = SimulatedAnnealing { sweeps: 30, ..Default::default() };
    let never = CancelToken::never();
    let algo = Algorithm::Nbocs { sigma2: 0.1 };
    let backends = Backends::default();

    // Cold baseline (also the state donor): n_init + iters evals.
    let cold_cfg = BboConfig::smoke_scale(p.n_bits(), 24);
    let cold = bbo::run_warm(&p, &algo, &sa, &cold_cfg, &backends, 5, &never, None, true).unwrap();
    let cold_evals = cold.run.ys.len();
    assert_eq!(cold_evals, p.n_bits() + 24);
    let warm_input = WarmStart::new(cold.state.clone().unwrap())
        .with_prev_best(cold.run.best_x.clone(), cold.run.best_y);

    // Warm rerun on the same instance with less than half the budget:
    // the anchor re-evaluation of the donor best reproduces the cold
    // best bit-for-bit on evaluation one.
    let warm_cfg = BboConfig::smoke_scale(p.n_bits(), cold_evals / 2 - 1);
    let warm = bbo::run_warm(
        &p,
        &algo,
        &sa,
        &warm_cfg,
        &backends,
        99,
        &never,
        Some(&warm_input),
        false,
    )
    .unwrap();
    assert!(warm.warm, "the run must report its warm start");
    assert!(warm.state.is_none(), "no export was requested");
    assert_eq!(
        warm.run.ys[0].to_bits(),
        cold.run.best_y.to_bits(),
        "the anchor evaluation reproduces the cold best exactly"
    );
    assert!(
        warm.run.ys.len() * 2 <= cold_evals,
        "warm used {} evals, cold used {cold_evals}",
        warm.run.ys.len()
    );
    assert!(
        warm.run.best_y <= cold.run.best_y,
        "warm ({}) must be at least as good as cold ({})",
        warm.run.best_y,
        cold.run.best_y
    );

    // A serialisation round trip of the warm input changes nothing:
    // the text-fed run is bit-identical to the memory-fed one.
    let via_text = WarmStart::parse(&warm_input.to_string_strict().unwrap()).unwrap();
    let warm2 = bbo::run_warm(
        &p,
        &algo,
        &sa,
        &warm_cfg,
        &backends,
        99,
        &never,
        Some(&via_text),
        false,
    )
    .unwrap();
    assert_eq!(warm2.run.best_y.to_bits(), warm.run.best_y.to_bits());
    assert_eq!(warm2.run.best_x, warm.run.best_x);
}
