//! Cross-module integration tests: instance → cost → baselines → BBO →
//! clustering, on problem sizes small enough to be exhaustively checked.

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::bruteforce::{brute_force, full_scan_gray};
use intdecomp::cluster::{cut, hamming, ward};
use intdecomp::cost::BinMatrix;
use intdecomp::greedy::greedy;
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::minlp::{LinearLsqMinlp, Oracle};
use intdecomp::solvers::{self, sa::SimulatedAnnealing, IsingSolver};
use intdecomp::surrogate::{blr::{Blr, Prior}, Dataset, Surrogate};
use intdecomp::util::rng::Rng;

fn tiny_cfg() -> InstanceConfig {
    InstanceConfig { n: 5, d: 12, k: 2, gamma: 0.8, seed: 42 }
}

#[test]
fn pipeline_exactness_chain() {
    // brute force == gray scan; greedy >= exact; BBO ends >= exact.
    let p = generate(&tiny_cfg(), 0);
    let bf = brute_force(&p);
    let (gray_best, _, _) = full_scan_gray(&p);
    assert!((bf.best_cost - gray_best).abs() < 1e-9);

    let g = greedy(&p, 1);
    assert!(g.cost_refit >= bf.best_cost - 1e-9);

    let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
    let cfg = BboConfig::smoke_scale(p.n_bits(), 60);
    let run = bbo::run(
        &p,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa,
        &cfg,
        &Backends::default(),
        3,
    );
    assert!(run.best_y >= bf.best_cost - 1e-9);
}

#[test]
fn bbo_beats_greedy_on_most_tiny_instances() {
    // The paper's headline: BBO reaches (near-)exact solutions the greedy
    // can't.  On 10-bit problems nBOCS should never be worse than greedy
    // and strictly better on instances where greedy is suboptimal.
    let cfg = tiny_cfg();
    let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
    let mut bbo_wins_or_ties = 0;
    let total = 5;
    for idx in 0..total {
        let p = generate(&cfg, idx);
        let g = greedy(&p, 1);
        let bcfg = BboConfig::smoke_scale(p.n_bits(), 100);
        let run = bbo::run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &bcfg,
            &Backends::default(),
            idx as u64,
        );
        if run.best_y <= g.cost_refit + 1e-9 {
            bbo_wins_or_ties += 1;
        }
    }
    assert!(
        bbo_wins_or_ties >= total - 1,
        "BBO matched/beat greedy on only {bbo_wins_or_ties}/{total}"
    );
}

#[test]
fn all_solvers_agree_with_exhaustive_on_surrogate_models() {
    // Fit a BLR surrogate on real data, then check SA/SQA find the same
    // minimum as exhaustive enumeration (the paper's Fig. 2 claim that
    // solver choice doesn't matter on these landscapes).
    let p = generate(&tiny_cfg(), 1);
    let mut rng = Rng::new(11);
    let mut data = Dataset::new(p.n_bits());
    for _ in 0..80 {
        let x = rng.spins(p.n_bits());
        let y = p.cost_spins(&x);
        data.push(x, y);
    }
    let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
    let model = blr.fit_model(&data, &mut rng).unwrap();

    let exact = solvers::exhaustive::Exhaustive.solve(&model, &mut rng);
    let e_exact = model.energy(&exact);
    for name in ["sa", "sqa"] {
        let solver = solvers::by_name(name).unwrap();
        let (_, e) = solver.solve_best(&model, &mut rng, 10);
        assert!(
            e <= e_exact + 1e-6,
            "{name} missed surrogate optimum: {e} vs {e_exact}"
        );
    }
}

#[test]
fn augmented_runs_find_equivalent_cost_data() {
    let p = generate(&tiny_cfg(), 2);
    let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
    let mut cfg = BboConfig::smoke_scale(p.n_bits(), 8);
    cfg.augment = true;
    let run = bbo::run(
        &p,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa,
        &cfg,
        &Backends::default(),
        5,
    );
    // All orbit members of the best x evaluate to the best y.
    let m = BinMatrix::from_spins(p.n(), p.k, &run.best_x);
    for eq in Oracle::equivalents(&p, m.as_spins()) {
        assert!((p.cost_spins(&eq) - run.best_y).abs() < 1e-9);
    }
}

#[test]
fn clustering_separates_sign_classes_of_solutions() {
    let p = generate(&tiny_cfg(), 3);
    let bf = brute_force(&p);
    let pts: Vec<Vec<i8>> =
        bf.orbit.iter().map(|m| m.data.clone()).collect();
    if pts.len() < 4 {
        return; // degenerate instance; nothing to check
    }
    let merges = ward(&pts);
    let labels = cut(&merges, pts.len(), 4);
    // Points in the same cluster are closer to each other than the
    // global diameter.
    let diam = pts
        .iter()
        .flat_map(|a| pts.iter().map(move |b| hamming(a, b)))
        .max()
        .unwrap();
    for i in 0..pts.len() {
        for j in (i + 1)..pts.len() {
            if labels[i] == labels[j] {
                assert!(hamming(&pts[i], &pts[j]) <= diam);
            }
        }
    }
}

#[test]
fn fmqa_loop_runs_and_improves_over_init() {
    let p = generate(&tiny_cfg(), 4);
    let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
    let cfg = BboConfig::smoke_scale(p.n_bits(), 40);
    let run = bbo::run(
        &p,
        &Algorithm::Fmqa { k_fm: 4 },
        &sa,
        &cfg,
        &Backends::default(),
        6,
    );
    let init_best = run.best_curve[cfg.n_init - 1];
    assert!(run.best_y <= init_best);
}

#[test]
fn minlp_front_end_with_bbo_recovers_support() {
    // The generalisation claim: BBO solves a subset-selection MINLP.
    let mut rng = Rng::new(21);
    let m = 40;
    let n = 8;
    let a = intdecomp::linalg::Matrix::from_vec(m, n, rng.normals(m * n));
    let z: Vec<f64> = (0..n)
        .map(|i| if i == 2 || i == 5 { 1.5 } else { 0.0 })
        .collect();
    let b = a.matvec(&z);
    // rho well above the surrogate's resolution at this y scale (the
    // paper tunes sigma^2 per problem class for the same reason).
    let problem = LinearLsqMinlp::new(a, b, 0.5);
    let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
    let cfg = BboConfig::smoke_scale(n, 80);
    let want: Vec<i8> = (0..n)
        .map(|i| if i == 2 || i == 5 { 1 } else { -1 })
        .collect();
    let want_cost = problem.eval(&want);
    // BBO is stochastic; within a few seeds it must reach the exhaustive
    // optimum (the true support on this noiseless planted problem).
    let mut recovered = 0;
    for seed in 1..=3 {
        let run = bbo::run(
            &problem,
            &Algorithm::Nbocs { sigma2: 10.0 },
            &sa,
            &cfg,
            &Backends::default(),
            seed,
        );
        if run.best_y <= want_cost + 1e-9 {
            assert_eq!(run.best_x, want, "cost tie with wrong support");
            recovered += 1;
        }
    }
    assert!(recovered >= 2, "support recovered in only {recovered}/3 seeds");
}

#[test]
fn paper_scale_instance_statistics() {
    // The synthetic "shrunk VGG" instances land in the paper's band of
    // exact-solution residuals (0.37..0.54 reported; we allow slack).
    let cfg = InstanceConfig::default();
    for idx in 0..3 {
        let p = generate(&cfg, idx);
        let bf = brute_force(&p);
        let nerr = p.normalised_error(bf.best_cost);
        assert!(
            (0.25..0.65).contains(&nerr),
            "instance {idx}: normalised exact residual {nerr}"
        );
        assert_eq!(bf.orbit.len(), 48);
    }
}

#[test]
fn problem_cost_agrees_between_spin_and_matrix_interfaces() {
    let p = generate(&InstanceConfig::default(), 0);
    let mut rng = Rng::new(31);
    for _ in 0..20 {
        let x = rng.spins(p.n_bits());
        let m = BinMatrix::from_spins(p.n(), p.k, &x);
        assert_eq!(p.cost_spins(&x), p.cost(&m));
    }
}
