//! Deterministic numeric-fault injection (ISSUE 9): every degraded
//! path must complete with a finite, valid decomposition — or fail with
//! a typed error — while the degradation counters match the injected
//! fault schedule *exactly*, fault-free wrapped runs stay bit-identical
//! to plain runs, and a live daemon keeps serving through panicking and
//! all-NaN requests.

use std::sync::{Arc, Mutex};
use std::thread;

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig, RunError};
use intdecomp::engine::{
    CompressionJob, Engine, EngineConfig, JobError,
};
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::linalg::NumericError;
use intdecomp::serve::{
    self, bare_request, compress_request, Endpoint, ServeConfig, Server,
};
use intdecomp::shard::ModelSpec;
use intdecomp::solvers::sa::SimulatedAnnealing;
use intdecomp::surrogate::blr::{NativePosterior, PosteriorBackend};
use intdecomp::util::cancel::CancelToken;
use intdecomp::util::fault::{
    DrawCounters, FaultPlan, FaultyOracle, FaultyPosterior,
};
use intdecomp::util::json::Json;

/// Serialises the tests that set the process-global chaos env hooks.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

/// Job seed the chaos hooks key on — distinctive, and small enough to
/// round-trip exactly through the JSON number path (f64 < 2^53).
const CHAOS_SEED: u64 = 195_948_557; // 0x0BAD_F00D

fn problem(layer: usize) -> intdecomp::cost::Problem {
    let icfg = InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 7 };
    generate(&icfg, layer)
}

fn sa(sweeps: usize) -> SimulatedAnnealing {
    SimulatedAnnealing { sweeps, ..Default::default() }
}

fn faulty_backends(
    cholesky_fail: Vec<usize>,
    counters: &DrawCounters,
) -> Backends {
    let c = counters.clone();
    Backends {
        posterior: Some(Box::new(move || {
            Box::new(FaultyPosterior::new(
                NativePosterior,
                cholesky_fail.clone(),
                c.clone(),
            )) as Box<dyn PosteriorBackend>
        })),
        fm_trainer: None,
    }
}

fn assert_valid_decomposition(run: &bbo::BboRun, n_bits: usize) {
    assert!(run.best_y.is_finite(), "best_y = {}", run.best_y);
    assert_eq!(run.best_x.len(), n_bits);
    assert!(run.best_x.iter().all(|&s| s == 1 || s == -1));
}

// ------------------------------------------------ degraded acquisition --

#[test]
fn cholesky_fault_falls_back_and_counts_exactly() {
    let p = problem(0);
    let cfg = BboConfig::smoke_scale(p.n_bits(), 6);
    let counters = DrawCounters::default();
    // Fail the very first posterior draw: exactly one fit degrades.
    let backends = faulty_backends(vec![0], &counters);
    let run = bbo::run(
        &p,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa(10),
        &cfg,
        &backends,
        5,
    );
    assert_eq!(run.ys.len(), cfg.n_init + cfg.iters);
    assert_valid_decomposition(&run, p.n_bits());
    assert_eq!(counters.injected(), 1);
    assert_eq!(run.degradation.surrogate_failures, 1);
    assert_eq!(run.degradation.fallback_proposals, 1);
    assert_eq!(run.degradation.rejected_costs, 0);
    assert!(run.degradation.any());
}

#[test]
fn batched_cholesky_fault_falls_back_for_the_whole_batch() {
    let p = problem(1);
    let mut cfg = BboConfig::smoke_scale(p.n_bits(), 6);
    cfg.batch_size = 3;
    let counters = DrawCounters::default();
    let backends = faulty_backends(vec![0], &counters);
    let run = bbo::run(
        &p,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa(10),
        &cfg,
        &backends,
        5,
    );
    assert_eq!(run.ys.len(), cfg.n_init + cfg.iters);
    assert_valid_decomposition(&run, p.n_bits());
    assert_eq!(counters.injected(), 1);
    assert_eq!(run.degradation.surrogate_failures, 1);
    // A failed batched fit replaces every candidate of that batch.
    assert_eq!(run.degradation.fallback_proposals, 3);
    assert_eq!(run.degradation.rejected_costs, 0);
}

#[test]
fn nan_costs_are_quarantined_with_exact_counters() {
    let p = problem(0);
    let cfg = BboConfig::smoke_scale(p.n_bits(), 6);
    // One fault inside the initial design, one inside acquisition.
    let plan = FaultPlan { nan_cost: vec![2, 9], ..Default::default() };
    let oracle = FaultyOracle::new(&p, plan);
    let run = bbo::run(
        &oracle,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa(10),
        &cfg,
        &Backends::default(),
        5,
    );
    // The budget is still spent (the trace keeps the NaN rows) but the
    // quarantined costs never reach the surrogate or the best.
    assert_eq!(run.ys.len(), cfg.n_init + cfg.iters);
    assert_eq!(run.ys.iter().filter(|y| y.is_nan()).count(), 2);
    assert_eq!(run.degradation.rejected_costs, 2);
    assert_eq!(run.degradation.surrogate_failures, 0);
    assert_valid_decomposition(&run, p.n_bits());
    let finite_min = run
        .ys
        .iter()
        .copied()
        .filter(|y| y.is_finite())
        .fold(f64::INFINITY, f64::min);
    assert_eq!(run.best_y, finite_min);
}

#[test]
fn all_nan_costs_fail_with_the_typed_error() {
    let p = problem(0);
    let cfg = BboConfig::smoke_scale(p.n_bits(), 4);
    let total = cfg.n_init + cfg.iters;
    let plan =
        FaultPlan { nan_cost: (0..total).collect(), ..Default::default() };
    let oracle = FaultyOracle::new(&p, plan);
    let out = bbo::run_cancellable(
        &oracle,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa(10),
        &cfg,
        &Backends::default(),
        5,
        &CancelToken::never(),
    );
    match out.unwrap_err() {
        RunError::Numeric(NumericError::NonFiniteCost { rejected }) => {
            assert_eq!(rejected, total);
        }
        other => panic!("expected NonFiniteCost, got {other:?}"),
    }
    assert_eq!(oracle.evals(), total, "the budget is spent either way");
}

// ----------------------------------------------------- bit-identity --

#[test]
fn fault_free_wrappers_are_bit_identical_to_plain_runs() {
    let p = problem(0);
    let cfg = BboConfig::smoke_scale(p.n_bits(), 8);
    let algo = Algorithm::Nbocs { sigma2: 0.1 };
    let plain =
        bbo::run(&p, &algo, &sa(10), &cfg, &Backends::default(), 13);

    let counters = DrawCounters::default();
    let backends = faulty_backends(Vec::new(), &counters);
    let oracle = FaultyOracle::new(&p, FaultPlan::none());
    let wrapped = bbo::run(&oracle, &algo, &sa(10), &cfg, &backends, 13);

    assert_eq!(plain.xs, wrapped.xs);
    assert_eq!(plain.ys, wrapped.ys);
    assert_eq!(plain.best_x, wrapped.best_x);
    assert_eq!(plain.best_y.to_bits(), wrapped.best_y.to_bits());
    assert!(!wrapped.degradation.any());
    assert_eq!(counters.injected(), 0);
    assert!(counters.calls() > 0, "the wrapper must have been exercised");
}

// ----------------------------------------------- property (≥200 cases) --

#[test]
fn property_injected_nan_faults_never_yield_non_finite_best() {
    // 200+ (seed, fault-schedule) cases: as long as at least one cost
    // survives quarantine, the run completes with a finite best and the
    // rejected counter equals the number of faults that fired.
    let algo = Algorithm::Nbocs { sigma2: 0.1 };
    let mut cases = 0usize;
    for seed in 0..50u64 {
        let p = problem((seed % 4) as usize);
        let cfg = BboConfig::smoke_scale(p.n_bits(), 4);
        let total = cfg.n_init + cfg.iters;
        for pat in 0..4usize {
            // A deterministic, pattern-varied schedule that never
            // covers every evaluation (stride 3 leaves survivors).
            let nan: Vec<usize> = (0..total)
                .filter(|i| (i + pat + seed as usize) % 3 == 0)
                .collect();
            let fired = nan.len();
            assert!(fired < total, "schedule must leave a survivor");
            let plan =
                FaultPlan { nan_cost: nan, ..Default::default() };
            let oracle = FaultyOracle::new(&p, plan);
            let run = bbo::run_cancellable(
                &oracle,
                &algo,
                &sa(5),
                &cfg,
                &Backends::default(),
                seed,
                &CancelToken::never(),
            )
            .expect("a surviving finite cost must complete the run");
            assert_valid_decomposition(&run, p.n_bits());
            assert_eq!(
                run.degradation.rejected_costs,
                fired as u64,
                "seed {seed} pat {pat}"
            );
            cases += 1;
        }
    }
    assert!(cases >= 200, "only {cases} fault cases exercised");
}

// ------------------------------------------------- panic containment --

#[test]
fn engine_contains_injected_panics_and_default_propagates() {
    let _guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var(
        "INTDECOMP_CHAOS_PANIC_SEED",
        CHAOS_SEED.to_string(),
    );

    // Containment on: the panic becomes a typed per-job error.
    let eng = Engine::new(EngineConfig {
        workers: 2,
        contain_panics: true,
        ..Default::default()
    });
    let job = CompressionJob::new("chaos", problem(0), 4, CHAOS_SEED)
        .with_solver(Box::new(sa(5)));
    let out = eng.try_compress_each(vec![job], |_, _| {});
    match out.unwrap_err() {
        JobError::Panicked { message } => {
            assert!(message.contains("chaos"), "{message}");
        }
        other => panic!("expected Panicked, got {other:?}"),
    }

    // Default policy: the panic unwinds through the caller.
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || {
            let job =
                CompressionJob::new("chaos", problem(0), 4, CHAOS_SEED)
                    .with_solver(Box::new(sa(5)));
            Engine::with_workers(1).try_compress_each(vec![job], |_, _| {})
        },
    ));
    assert!(caught.is_err(), "default engine must propagate the panic");

    std::env::remove_var("INTDECOMP_CHAOS_PANIC_SEED");
}

// --------------------------------------------------- daemon survival --

fn chaos_spec(instance_seed: u64, seed: u64) -> ModelSpec {
    ModelSpec {
        n: 4,
        d: 8,
        k: 2,
        gamma: 0.8,
        instance_seed,
        layers: 1,
        iters: 4,
        restarts: 2,
        batch_size: 1,
        augment: false,
        restart_workers: 1,
        algo: "nbocs".into(),
        solver: "sa".into(),
        seed,
        cache_key_raw: false,
    }
}

fn num(s: &Json, key: &str) -> u64 {
    s.get(key)
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("stats missing {key}: {}", s.to_string()))
}

#[test]
fn daemon_survives_chaos_panic_and_all_nan_requests() {
    let _guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let server = Arc::new(
        Server::bind(ServeConfig {
            endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
            max_inflight: 2,
            workers: 2,
            ..Default::default()
        })
        .expect("bind on a free port"),
    );
    let endpoint = server.local_endpoint().clone();
    let srv = Arc::clone(&server);
    let handle = thread::spawn(move || srv.run());

    let expect_500 = |lines: &[String], needle: &str| {
        let last = Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(
            last.get("type").and_then(Json::as_str),
            Some("error"),
            "{lines:?}"
        );
        assert_eq!(last.get("code").and_then(Json::as_u64), Some(500));
        let msg = last.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains(needle), "error {msg:?} lacks {needle:?}");
    };

    // A request whose job panics: contained into a typed 500.
    std::env::set_var(
        "INTDECOMP_CHAOS_PANIC_SEED",
        CHAOS_SEED.to_string(),
    );
    let lines = serve::request(
        &endpoint,
        &compress_request(&chaos_spec(9, CHAOS_SEED)),
    )
    .unwrap();
    expect_500(&lines, "panicked");
    std::env::remove_var("INTDECOMP_CHAOS_PANIC_SEED");

    // A request whose every cost is NaN: typed numeric 500.
    std::env::set_var("INTDECOMP_CHAOS_NAN_SEED", CHAOS_SEED.to_string());
    let lines = serve::request(
        &endpoint,
        &compress_request(&chaos_spec(10, CHAOS_SEED)),
    )
    .unwrap();
    expect_500(&lines, "non-finite");
    std::env::remove_var("INTDECOMP_CHAOS_NAN_SEED");

    // The daemon is still alive and still serves real work.
    let pong = serve::request(&endpoint, &bare_request("ping")).unwrap();
    let p = Json::parse(&pong[0]).unwrap();
    assert_eq!(p.get("type").and_then(Json::as_str), Some("pong"));
    let ok = serve::request(
        &endpoint,
        &compress_request(&chaos_spec(11, 21)),
    )
    .unwrap();
    let done = Json::parse(ok.last().unwrap()).unwrap();
    assert_eq!(done.get("type").and_then(Json::as_str), Some("done"));

    // The fault classes are counted separately in stats.
    let stats = serve::request(&endpoint, &bare_request("stats")).unwrap();
    let s = Json::parse(stats.last().unwrap()).unwrap();
    assert_eq!(num(&s, "panicked"), 1);
    assert_eq!(num(&s, "degraded"), 1);
    assert_eq!(num(&s, "errors"), 2);
    assert_eq!(num(&s, "completed"), 1);
    assert!(
        s.get("degradation").is_some(),
        "stats must carry the degradation block: {}",
        s.to_string()
    );

    let bye = serve::request(&endpoint, &bare_request("shutdown")).unwrap();
    let last = Json::parse(bye.last().unwrap()).unwrap();
    assert_eq!(last.get("type").and_then(Json::as_str), Some("bye"));
    handle.join().unwrap().unwrap();
}
