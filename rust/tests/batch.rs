//! Batched-acquisition integration tests (ISSUE 2): the `batch_size = 1`
//! legacy contract, worker-count invariance of batched runs, distinctness
//! of `solve_batch` candidates inside a real run, and cache accounting
//! under concurrent candidate evaluation.

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::engine::{
    CachedOracle, CompressionJob, CostCache, Engine, EngineConfig,
};
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::solvers::{self, sa::SimulatedAnnealing};
use intdecomp::surrogate::{
    blr::{Blr, Prior},
    Dataset, Surrogate,
};
use intdecomp::util::rng::Rng;

fn tiny(idx: usize) -> intdecomp::cost::Problem {
    let cfg = InstanceConfig { n: 4, d: 10, k: 2, gamma: 0.8, seed: 55 };
    generate(&cfg, idx)
}

fn sa(sweeps: usize) -> SimulatedAnnealing {
    SimulatedAnnealing { sweeps, ..Default::default() }
}

#[test]
fn batch_one_is_bit_identical_to_the_legacy_serial_stream() {
    // The engine regression (compress_all == serial bbo::run) plus this:
    // a config that only sets batch_size = 1 explicitly must reproduce
    // the default-config run exactly, for every algorithm family.
    let p = tiny(0);
    for name in ["nbocs", "fmqa08", "rs"] {
        let algo = Algorithm::by_name(name).unwrap();
        let cfg = BboConfig::smoke_scale(p.n_bits(), 20);
        let a = bbo::run(&p, &algo, &sa(15), &cfg, &Backends::default(), 3);
        let mut explicit = cfg.clone();
        explicit.batch_size = 1;
        let b = bbo::run(
            &p,
            &algo,
            &sa(15),
            &explicit,
            &Backends::default(),
            3,
        );
        assert_eq!(a.xs, b.xs, "{name}");
        assert_eq!(a.ys, b.ys, "{name}");
        assert_eq!(a.best_curve, b.best_curve, "{name}");
    }
}

#[test]
fn batched_runs_are_invariant_to_every_worker_knob() {
    // batch_size > 1 must give one fixed result no matter how the work
    // is spread: restart fan-out width and engine job workers included.
    let p = tiny(1);
    let algo = Algorithm::Nbocs { sigma2: 0.1 };
    let run_with = |restart_workers: usize| {
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 16);
        cfg.batch_size = 4;
        cfg.restart_workers = restart_workers;
        bbo::run(&p, &algo, &sa(12), &cfg, &Backends::default(), 21)
    };
    let reference = run_with(1);
    for rw in [2, 3, 8] {
        let r = run_with(rw);
        assert_eq!(reference.ys, r.ys, "restart_workers {rw}");
        assert_eq!(reference.xs, r.xs, "restart_workers {rw}");
        assert_eq!(reference.best_x, r.best_x);
    }
}

#[test]
fn solve_batch_candidates_are_distinct_on_a_fitted_surrogate() {
    // Distinctness on a *realistic* model: fit a BLR surrogate on real
    // evaluations of a paper-shaped instance, then batch-solve it.
    let p = generate(&InstanceConfig::default(), 0);
    let mut rng = Rng::new(11);
    let mut data = Dataset::new(p.n_bits());
    for _ in 0..60 {
        let x = rng.spins(p.n_bits());
        let y = p.cost_spins(&x);
        data.push(x, y);
    }
    let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
    let model = blr.fit_model(&data, &mut rng).unwrap();
    let top = solvers::solve_batch(
        &sa(30),
        &model,
        &mut Rng::new(5),
        12,
        6,
        4,
    );
    assert!(!top.is_empty() && top.len() <= 6);
    for i in 0..top.len() {
        for j in (i + 1)..top.len() {
            assert_ne!(top[i].0, top[j].0, "duplicate candidate {i}/{j}");
        }
    }
    for w in top.windows(2) {
        assert!(w[0].1 <= w[1].1, "candidates not sorted by energy");
    }
}

#[test]
fn cache_accounting_is_exact_under_concurrent_batched_evaluation() {
    // Concurrent evaluation of a batch must neither lose nor invent
    // lookups: hits + misses == one lookup per black-box evaluation,
    // and the cached values stay correct.
    let p = tiny(2);
    let cache = CostCache::new();
    let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
    let mut cfg = BboConfig::smoke_scale(p.n_bits(), 24);
    cfg.batch_size = 6;
    let run = bbo::run(
        &oracle,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa(15),
        &cfg,
        &Backends::default(),
        13,
    );
    let s = cache.stats();
    assert_eq!(run.ys.len(), cfg.n_init + cfg.iters);
    assert_eq!(s.lookups() as usize, run.ys.len());
    assert!(s.misses >= 1 && s.misses <= s.lookups());
    // Distinct keys can never exceed misses (racing duplicates may
    // double-miss, never double-insert a new key).
    assert!(cache.len() as u64 <= s.misses);
    // Every recorded y is the true cost of its x (cache returned the
    // right values under concurrency).
    for (x, &y) in run.xs.iter().zip(&run.ys) {
        assert_eq!(y, p.cost_spins(x));
    }
}

#[test]
fn engine_batch_size_override_applies_to_all_jobs() {
    let jobs = |batch: usize| -> Vec<CompressionJob> {
        (0..3)
            .map(|i| {
                CompressionJob::new(
                    format!("l{i}"),
                    tiny(i),
                    12,
                    40 + i as u64,
                )
                .with_solver(Box::new(sa(10)))
                .with_batch_size(batch)
            })
            .collect()
    };
    // Per-job batch config and the engine-level override must agree.
    let via_jobs = Engine::with_workers(2).compress_all(jobs(3));
    let via_engine = Engine::new(EngineConfig {
        workers: 2,
        restart_workers: 1,
        batch_size: 3,
        ..Default::default()
    })
    .compress_all(jobs(1));
    for (a, b) in via_jobs.iter().zip(&via_engine) {
        assert_eq!(a.run.ys, b.run.ys);
        assert_eq!(a.run.best_x, b.run.best_x);
        assert_eq!(a.cache.lookups(), b.cache.lookups());
    }
    // And the budget is unchanged by batching.
    for r in &via_jobs {
        assert_eq!(r.run.ys.len(), 8 + 12);
    }
}

#[test]
fn batched_and_serial_runs_agree_on_the_oracle_values() {
    // Batching changes *which* candidates are acquired (one fit per k),
    // but every recorded (x, y) must still satisfy y = f(x).
    let p = tiny(3);
    let mut cfg = BboConfig::smoke_scale(p.n_bits(), 15);
    cfg.batch_size = 5;
    let run = bbo::run(
        &p,
        &Algorithm::Fmqa { k_fm: 8 },
        &sa(10),
        &cfg,
        &Backends::default(),
        2,
    );
    for (x, &y) in run.xs.iter().zip(&run.ys) {
        assert_eq!(y, p.cost_spins(x));
    }
    for w in run.best_curve.windows(2) {
        assert!(w[1] <= w[0] + 1e-12);
    }
}
