//! Engine integration tests: the parallel-determinism regression
//! (compress_all == serial bbo::run, bit for bit), cache accounting
//! through a full run, restart fan-out invariance, and edge cases.

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::engine::{
    self, CacheKeyMode, CachedOracle, CompressionJob, CostCache, Engine,
    EngineConfig,
};
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::minlp::Oracle;
use intdecomp::solvers::sa::SimulatedAnnealing;
use intdecomp::util::rng::Rng;

fn tiny(idx: usize) -> intdecomp::cost::Problem {
    let cfg = InstanceConfig { n: 4, d: 10, k: 2, gamma: 0.8, seed: 77 };
    generate(&cfg, idx)
}

/// Exact-key job: canonical orbit folding is the engine default, but the
/// bit-for-bit regressions below compare against uncached serial
/// `bbo::run`, which only the exact-key mode reproduces.
fn job(idx: usize) -> CompressionJob {
    CompressionJob::new(
        format!("layer{idx}"),
        tiny(idx),
        25,
        100 + idx as u64,
    )
    .with_solver(Box::new(SimulatedAnnealing {
        sweeps: 20,
        ..Default::default()
    }))
    .with_cache_mode(CacheKeyMode::Exact)
}

#[test]
fn compress_all_matches_serial_bbo_runs_bit_for_bit() {
    // 4 small instances through the engine on 4 workers must return the
    // same costs as 4 plain serial bbo::run calls with the same seeds.
    let results =
        Engine::with_workers(4).compress_all((0..4).map(job).collect());
    assert_eq!(results.len(), 4);
    for (idx, r) in results.iter().enumerate() {
        let p = tiny(idx);
        let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 25);
        let serial = bbo::run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            100 + idx as u64,
        );
        assert_eq!(r.name, format!("layer{idx}"));
        assert_eq!(r.run.ys, serial.ys, "layer {idx}: costs diverged");
        assert_eq!(r.run.xs, serial.xs, "layer {idx}: candidates diverged");
        assert_eq!(r.run.best_x, serial.best_x);
        assert_eq!(r.run.best_y, serial.best_y);
    }
}

#[test]
fn worker_counts_agree() {
    let a = Engine::with_workers(1)
        .compress_all((0..3).map(job).collect());
    let b = Engine::with_workers(8)
        .compress_all((0..3).map(job).collect());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.run.ys, y.run.ys);
        assert_eq!(x.run.best_x, y.run.best_x);
        assert_eq!(x.cache, y.cache);
    }
}

#[test]
fn restart_fanout_is_deterministic_across_widths() {
    let p = tiny(0);
    let mk = |rw: usize| {
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 20);
        cfg.restart_workers = rw;
        bbo::run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            7,
        )
    };
    let two = mk(2);
    let eight = mk(8);
    assert_eq!(two.ys, eight.ys);
    assert_eq!(two.best_x, eight.best_x);
    assert_eq!(two.best_y, eight.best_y);
}

#[test]
fn engine_restart_fanout_is_deterministic_too() {
    let mk = |rw: usize| {
        Engine::new(EngineConfig {
            workers: 2,
            restart_workers: rw,
            batch_size: 1,
            ..Default::default()
        })
        .compress_all((0..2).map(job).collect())
    };
    let a = mk(2);
    let b = mk(8);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.run.ys, y.run.ys);
        assert_eq!(x.run.best_x, y.run.best_x);
    }
}

#[test]
fn empty_job_list_is_fine() {
    let results =
        Engine::new(EngineConfig::default()).compress_all(Vec::new());
    assert!(results.is_empty());
}

#[test]
fn cache_accounting_hits_and_misses() {
    let p = tiny(1);
    let cache = CostCache::new();
    let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
    let mut rng = Rng::new(1);
    let x = rng.spins(p.n_bits());
    let y1 = oracle.eval(&x);
    let y2 = oracle.eval(&x);
    assert_eq!(y1, y2);
    assert_eq!(y1, p.cost_spins(&x));
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (1, 1));
    // A guaranteed-distinct second candidate.
    let mut x2 = x.clone();
    x2[0] = -x2[0];
    let _ = oracle.eval(&x2);
    let s = cache.stats();
    assert_eq!((s.hits, s.misses), (1, 2));
    assert_eq!(cache.len(), 2);
    assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
}

#[test]
fn engine_results_carry_cache_stats() {
    let r = Engine::with_workers(2).compress_all(vec![job(0)]);
    let s = &r[0].cache;
    // Every black-box evaluation goes through the cache, once per step.
    assert_eq!(s.lookups() as usize, r[0].run.ys.len());
    // Distinct candidates stored == misses; hits are the repeats.
    assert!(s.misses >= 1);
    assert!(s.misses <= s.lookups());
    let table = engine::summary_table(&r);
    assert!(table.contains("layer0"));
}

#[test]
fn canonical_default_is_deterministic_and_orbit_consistent() {
    // CompressionJob::new defaults to canonical-orbit cache keys (the
    // ROADMAP flip): results must be reproducible across worker counts,
    // keep exact one-lookup-per-evaluation accounting, and every
    // recorded y must equal the cost of some orbit member of its x
    // (the canonical representative's, by construction).
    let mk = || {
        CompressionJob::new("canon", tiny(1), 20, 31).with_solver(
            Box::new(SimulatedAnnealing { sweeps: 15, ..Default::default() }),
        )
    };
    assert_eq!(mk().cache_mode, CacheKeyMode::Canonical);
    let a = Engine::with_workers(1).compress_all(vec![mk()]);
    let b = Engine::with_workers(8).compress_all(vec![mk()]);
    assert_eq!(a[0].run.ys, b[0].run.ys);
    assert_eq!(a[0].cache, b[0].cache);
    assert_eq!(a[0].cache.lookups() as usize, a[0].run.ys.len());
    let p = tiny(1);
    for (x, &y) in a[0].run.xs.iter().zip(&a[0].run.ys) {
        let m = intdecomp::cost::BinMatrix::from_spins(p.n(), p.k, x);
        let canon_cost = p.cost(&m.canonical());
        assert_eq!(y, canon_cost, "stored value not the representative's");
    }
}
