//! Failure-injection and edge-case tests: corrupted artifacts, degenerate
//! models/datasets, extreme problem shapes — the system must fail loudly
//! at load time and stay numerically sane at run time.

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::cli::Args;
use intdecomp::cost::{BinMatrix, Problem};
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::linalg::Matrix;
use intdecomp::runtime::XlaRuntime;
use intdecomp::serve::{Endpoint, ServeConfig, Server};
use intdecomp::shard::{recover_log, LayerRecord};
use intdecomp::solvers::{self, IsingSolver, QuadModel};
use intdecomp::surrogate::{
    blr::{Blr, Prior},
    Dataset, Surrogate,
};
use intdecomp::util::rng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("intdecomp_fi_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------- artifacts --

#[test]
fn runtime_rejects_missing_meta() {
    let dir = tmpdir("nometa");
    assert!(XlaRuntime::load(&dir).is_err());
}

#[test]
fn runtime_rejects_corrupt_meta() {
    let dir = tmpdir("badmeta");
    std::fs::write(dir.join("meta.json"), "{not json").unwrap();
    assert!(XlaRuntime::load(&dir).is_err());
    std::fs::write(dir.join("meta.json"), r#"{"n": 8}"#).unwrap();
    assert!(XlaRuntime::load(&dir).is_err());
}

#[test]
fn runtime_rejects_missing_or_garbage_hlo() {
    let dir = tmpdir("badhlo");
    std::fs::write(
        dir.join("meta.json"),
        r#"{"n":8,"d":100,"k":3,"nbits":24,"p":301,"batch":256,
            "nmax":1280,"kfms":[8],"fm_steps":100}"#,
    )
    .unwrap();
    // Missing cost_batch.hlo.txt entirely:
    assert!(XlaRuntime::load(&dir).is_err());
    // Garbage HLO text:
    std::fs::write(dir.join("cost_batch.hlo.txt"), "HloModule junk\n!!!")
        .unwrap();
    assert!(XlaRuntime::load(&dir).is_err());
}

#[test]
fn runtime_shape_guards_fire() {
    // Only runs when real artifacts exist.
    let Some(rt) = XlaRuntime::load_default() else { return };
    // Wrong W shape must error, not crash or silently pad.
    let wrong_w = Matrix::zeros(4, 7);
    let m = BinMatrix::ones(4, 2);
    assert!(rt.cost_batch(&wrong_w, &[m]).is_err());
    // Oversized dataset must error.
    let phi = Matrix::zeros(rt.meta.nmax + 1, rt.meta.p);
    let y = vec![0.0; rt.meta.nmax + 1];
    assert!(rt.gram(&phi, &y).is_err());
}

// ------------------------------------------------------------- models --

#[test]
fn solvers_survive_all_zero_model() {
    let model = QuadModel::new(12);
    let mut rng = Rng::new(1);
    for name in ["sa", "sq", "sqa", "exhaustive"] {
        let solver = solvers::by_name(name).unwrap();
        let x = solver.solve(&model, &mut rng);
        assert_eq!(x.len(), 12, "{name}");
        assert!(x.iter().all(|&s| s == 1 || s == -1), "{name}");
        assert_eq!(model.energy(&x), 0.0, "{name}");
    }
}

#[test]
fn solvers_survive_huge_couplings() {
    let mut model = QuadModel::new(8);
    for i in 0..8 {
        model.h[i] = 1e12;
        for j in (i + 1)..8 {
            model.set_pair(i, j, -1e12);
        }
    }
    let mut rng = Rng::new(2);
    for name in ["sa", "sq", "sqa"] {
        let solver = solvers::by_name(name).unwrap();
        let x = solver.solve(&model, &mut rng);
        assert!(model.energy(&x).is_finite(), "{name}");
    }
}

// ------------------------------------------------------------ datasets --

#[test]
fn blr_handles_constant_targets() {
    // Zero-variance y: σ_n² conditional degenerates; draws must stay
    // finite thanks to the scale clamps.
    let mut rng = Rng::new(3);
    let mut data = Dataset::new(6);
    for _ in 0..40 {
        data.push(rng.spins(6), 1.25);
    }
    for prior in [
        Prior::Normal { sigma2: 0.1 },
        Prior::NormalGamma { a: 1.0, beta: 0.001 },
        Prior::Horseshoe,
    ] {
        let mut blr = Blr::new(prior.clone());
        for _ in 0..3 {
            let a = blr.sample_alpha(&data, &mut rng);
            assert!(
                a.iter().all(|v| v.is_finite()),
                "{prior:?} non-finite"
            );
        }
    }
}

#[test]
fn blr_underdetermined_tiny_dataset() {
    // 3 rows, 22 features: posterior exists only through the prior.
    let mut rng = Rng::new(4);
    let mut data = Dataset::new(6);
    for _ in 0..3 {
        data.push(rng.spins(6), rng.normal());
    }
    let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
    let model = blr.fit_model(&data, &mut rng);
    assert!(model.energy(&vec![1i8; 6]).is_finite());
}

#[test]
fn blr_duplicate_rows_only() {
    // Rank-1 Φ: heavy collinearity, jitter ladder must cope.
    let mut rng = Rng::new(5);
    let mut data = Dataset::new(5);
    let x = rng.spins(5);
    for _ in 0..30 {
        data.push(x.clone(), 2.0);
    }
    let mut blr = Blr::new(Prior::Horseshoe);
    let a = blr.sample_alpha(&data, &mut rng);
    assert!(a.iter().all(|v| v.is_finite()));
}

// ------------------------------------------------------------ problems --

#[test]
fn extreme_problem_shapes() {
    let mut rng = Rng::new(6);
    // K = 1 and D = 1.
    for (n, d, k) in [(8usize, 1usize, 1usize), (2, 5, 1), (4, 3, 4)] {
        let w = Matrix::from_vec(n, d, rng.normals(n * d));
        let p = Problem::new(w, k);
        let m = BinMatrix::new(n, k, rng.spins(n * k));
        let c = p.cost(&m);
        assert!(c.is_finite() && c >= 0.0, "({n},{d},{k})");
        let explicit = p.cost_explicit(&m);
        assert!((c - explicit).abs() < 1e-6 * (1.0 + explicit));
    }
}

#[test]
fn zero_matrix_problem() {
    let p = Problem::new(Matrix::zeros(6, 10), 2);
    let m = BinMatrix::ones(6, 2);
    assert_eq!(p.cost(&m), 0.0);
    assert_eq!(p.w_norm_sq, 0.0);
}

#[test]
fn bbo_on_constant_oracle_terminates() {
    struct Flat;
    impl intdecomp::minlp::Oracle for Flat {
        fn n_bits(&self) -> usize {
            6
        }
        fn eval(&self, _x: &[i8]) -> f64 {
            3.0
        }
    }
    let sa = solvers::sa::SimulatedAnnealing {
        sweeps: 5,
        ..Default::default()
    };
    let cfg = BboConfig::smoke_scale(6, 10);
    let run = bbo::run(
        &Flat,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa,
        &cfg,
        &Backends::default(),
        7,
    );
    assert_eq!(run.best_y, 3.0);
    assert_eq!(run.ys.len(), 16);
}

#[test]
fn rfmqa_explores_more_than_fmqa() {
    // ε-greedy must inject random (typically fresh) candidates.
    let p = generate(
        &InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 11 },
        0,
    );
    let sa = solvers::sa::SimulatedAnnealing {
        sweeps: 10,
        ..Default::default()
    };
    let cfg = BboConfig::smoke_scale(p.n_bits(), 60);
    let distinct = |algo: &Algorithm| -> usize {
        let run = bbo::run(&p, algo, &sa, &cfg, &Backends::default(), 3);
        let set: std::collections::HashSet<Vec<i8>> =
            run.xs.into_iter().collect();
        set.len()
    };
    let plain = distinct(&Algorithm::Fmqa { k_fm: 4 });
    let rand = distinct(&Algorithm::Rfmqa { k_fm: 4, eps: 0.5 });
    assert!(
        rand >= plain,
        "rFMQA sampled {rand} distinct vs FMQA {plain}"
    );
}

// ------------------------------------------- serve state / result logs --

fn serve_cfg(dir: &std::path::Path) -> ServeConfig {
    ServeConfig {
        endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
        max_inflight: 1,
        workers: 1,
        state_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

#[test]
fn corrupt_serve_lockfile_is_reclaimed_at_bind() {
    // A state dir left behind with a garbage lockfile (disk corruption,
    // partial write) must not wedge the daemon: unparseable contents
    // are stale by definition and bind takes the lock over.
    let dir = tmpdir("servelock_garbage");
    std::fs::write(dir.join("serve.state.lock"), "\x00\x7f not a pid")
        .unwrap();
    let server = Server::bind(serve_cfg(&dir)).expect("stale takeover");
    drop(server);
    // The reclaimed lock is released on drop, so a restart binds clean.
    let again = Server::bind(serve_cfg(&dir)).unwrap();
    drop(again);
}

#[cfg(target_os = "linux")]
#[test]
fn dead_pid_serve_lockfile_is_reclaimed_at_bind() {
    // A SIGKILLed daemon leaves its PID behind; the next bind must
    // detect the owner is gone and take over instead of failing.
    let dir = tmpdir("servelock_dead");
    // Far above kernel.pid_max, so no live process can own it.
    std::fs::write(dir.join("serve.state.lock"), "4294967294\n").unwrap();
    let server = Server::bind(serve_cfg(&dir)).expect("dead-owner takeover");
    drop(server);
}

#[test]
fn live_pid_serve_lockfile_blocks_bind() {
    // A lockfile naming a live process (here: ourselves) is genuinely
    // held — bind must fail fast with a clear error, not steal it.
    let dir = tmpdir("servelock_live");
    std::fs::write(
        dir.join("serve.state.lock"),
        format!("{}\n", std::process::id()),
    )
    .unwrap();
    let err = Server::bind(serve_cfg(&dir)).unwrap_err();
    assert!(
        format!("{err:#}").contains("held by live process"),
        "unexpected error: {err:#}"
    );
    // The refused bind must not have clobbered the lockfile.
    assert!(dir.join("serve.state.lock").exists());
}

fn log_record(job: usize) -> LayerRecord {
    LayerRecord {
        job,
        name: format!("couche-é{}", job + 1),
        n: 4,
        d: 8,
        k: 2,
        algo: "nBOCS".into(),
        solver: "sa".into(),
        evals: 7,
        best_y: 0.25,
        best_x: vec![1, -1, 1, 1, -1, -1, 1, -1],
        err: 0.04,
        ratio: 0.16,
        cache_hits: 2,
        cache_misses: 5,
    }
}

#[test]
fn recover_log_drops_a_tail_torn_mid_utf8() {
    // A crash mid-append can cut a record inside a multi-byte UTF-8
    // sequence.  Whether or not the torn tail is newline-terminated,
    // recovery must keep the valid prefix and drop the tail — never
    // error out on the invalid UTF-8.
    let dir = tmpdir("utf8log");
    let path = dir.join("log.jsonl");
    let l1 = log_record(0).to_json_line("feed");
    let l2 = log_record(1).to_json_line("feed");
    // Cut the second line one byte into the 'é' (0xC3 0xA9), leaving a
    // dangling lead byte.
    let b2 = l2.as_bytes();
    let cut = b2.iter().position(|&b| b == 0xC3).unwrap() + 1;
    assert!(!l2.is_char_boundary(cut), "cut must split the 'é'");

    // Unterminated torn tail: the scanner never sees a newline, so the
    // tail is dropped as an incomplete line.
    let mut raw = format!("{l1}\n").into_bytes();
    raw.extend_from_slice(&b2[..cut]);
    std::fs::write(&path, &raw).unwrap();
    let rec = recover_log(&path, "feed").unwrap();
    assert_eq!(rec.records.len(), 1);
    assert_eq!(rec.records[0].name, "couche-é1");
    assert_eq!(rec.valid_bytes as usize, l1.len() + 1);
    assert_eq!(rec.dropped_bytes as usize, cut);

    // Newline-terminated torn tail: the line is complete but not valid
    // UTF-8, which must read as a bad line, not a panic or an Err.
    let mut raw = format!("{l1}\n").into_bytes();
    raw.extend_from_slice(&b2[..cut]);
    raw.push(b'\n');
    std::fs::write(&path, &raw).unwrap();
    let rec = recover_log(&path, "feed").unwrap();
    assert_eq!(rec.records.len(), 1);
    assert_eq!(rec.valid_bytes as usize, l1.len() + 1);
    assert_eq!(rec.dropped_bytes as usize, cut + 1);

    // Sanity: an untorn log with the same multi-byte names recovers
    // both records bit-exactly.
    std::fs::write(&path, format!("{l1}\n{l2}\n")).unwrap();
    let rec = recover_log(&path, "feed").unwrap();
    assert_eq!(rec.records.len(), 2);
    assert_eq!(rec.records[1].name, "couche-é2");
    assert_eq!(rec.dropped_bytes, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------- cli --

#[test]
fn cli_rejects_malformed_flags() {
    assert!(Args::parse(["--".to_string()]).is_err());
    let a = Args::parse(["x".into(), "--runs".into(), "nan".into()])
        .unwrap();
    assert!(a.usize_flag("runs", 1).is_err());
}

#[test]
fn config_rejects_bad_numbers() {
    let a = Args::parse(["exp".into(), "--iters=abc".into()]).unwrap();
    assert!(intdecomp::config::ExpConfig::from_args(&a).is_err());
}
