//! Failure-injection and edge-case tests: corrupted artifacts, degenerate
//! models/datasets, extreme problem shapes — the system must fail loudly
//! at load time and stay numerically sane at run time.

use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
use intdecomp::cli::Args;
use intdecomp::cost::{BinMatrix, Problem};
use intdecomp::instance::{generate, InstanceConfig};
use intdecomp::linalg::Matrix;
use intdecomp::runtime::XlaRuntime;
use intdecomp::serve::{
    self, compress_request, recover_journal, Endpoint, Journal,
    RecoverMode, ServeConfig, Server,
};
use intdecomp::shard::{
    recover_log, CheckpointLog, LayerRecord, ModelSpec,
};
use intdecomp::solvers::{self, IsingSolver, QuadModel};
use intdecomp::surrogate::{
    blr::{Blr, Prior},
    Dataset, Surrogate,
};
use intdecomp::util::json::Json;
use intdecomp::util::rng::Rng;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("intdecomp_fi_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------- artifacts --

#[test]
fn runtime_rejects_missing_meta() {
    let dir = tmpdir("nometa");
    assert!(XlaRuntime::load(&dir).is_err());
}

#[test]
fn runtime_rejects_corrupt_meta() {
    let dir = tmpdir("badmeta");
    std::fs::write(dir.join("meta.json"), "{not json").unwrap();
    assert!(XlaRuntime::load(&dir).is_err());
    std::fs::write(dir.join("meta.json"), r#"{"n": 8}"#).unwrap();
    assert!(XlaRuntime::load(&dir).is_err());
}

#[test]
fn runtime_rejects_missing_or_garbage_hlo() {
    let dir = tmpdir("badhlo");
    std::fs::write(
        dir.join("meta.json"),
        r#"{"n":8,"d":100,"k":3,"nbits":24,"p":301,"batch":256,
            "nmax":1280,"kfms":[8],"fm_steps":100}"#,
    )
    .unwrap();
    // Missing cost_batch.hlo.txt entirely:
    assert!(XlaRuntime::load(&dir).is_err());
    // Garbage HLO text:
    std::fs::write(dir.join("cost_batch.hlo.txt"), "HloModule junk\n!!!")
        .unwrap();
    assert!(XlaRuntime::load(&dir).is_err());
}

#[test]
fn runtime_shape_guards_fire() {
    // Only runs when real artifacts exist.
    let Some(rt) = XlaRuntime::load_default() else { return };
    // Wrong W shape must error, not crash or silently pad.
    let wrong_w = Matrix::zeros(4, 7);
    let m = BinMatrix::ones(4, 2);
    assert!(rt.cost_batch(&wrong_w, &[m]).is_err());
    // Oversized dataset must error.
    let phi = Matrix::zeros(rt.meta.nmax + 1, rt.meta.p);
    let y = vec![0.0; rt.meta.nmax + 1];
    assert!(rt.gram(&phi, &y).is_err());
}

// ------------------------------------------------------------- models --

#[test]
fn solvers_survive_all_zero_model() {
    let model = QuadModel::new(12);
    let mut rng = Rng::new(1);
    for name in ["sa", "sq", "sqa", "exhaustive"] {
        let solver = solvers::by_name(name).unwrap();
        let x = solver.solve(&model, &mut rng);
        assert_eq!(x.len(), 12, "{name}");
        assert!(x.iter().all(|&s| s == 1 || s == -1), "{name}");
        assert_eq!(model.energy(&x), 0.0, "{name}");
    }
}

#[test]
fn solvers_survive_huge_couplings() {
    let mut model = QuadModel::new(8);
    for i in 0..8 {
        model.h[i] = 1e12;
        for j in (i + 1)..8 {
            model.set_pair(i, j, -1e12);
        }
    }
    let mut rng = Rng::new(2);
    for name in ["sa", "sq", "sqa"] {
        let solver = solvers::by_name(name).unwrap();
        let x = solver.solve(&model, &mut rng);
        assert!(model.energy(&x).is_finite(), "{name}");
    }
}

// ------------------------------------------------------------ datasets --

#[test]
fn blr_handles_constant_targets() {
    // Zero-variance y: σ_n² conditional degenerates; draws must stay
    // finite thanks to the scale clamps.
    let mut rng = Rng::new(3);
    let mut data = Dataset::new(6);
    for _ in 0..40 {
        data.push(rng.spins(6), 1.25);
    }
    for prior in [
        Prior::Normal { sigma2: 0.1 },
        Prior::NormalGamma { a: 1.0, beta: 0.001 },
        Prior::Horseshoe,
    ] {
        let mut blr = Blr::new(prior.clone());
        for _ in 0..3 {
            let a = blr.sample_alpha(&data, &mut rng).unwrap();
            assert!(
                a.iter().all(|v| v.is_finite()),
                "{prior:?} non-finite"
            );
        }
    }
}

#[test]
fn blr_underdetermined_tiny_dataset() {
    // 3 rows, 22 features: posterior exists only through the prior.
    let mut rng = Rng::new(4);
    let mut data = Dataset::new(6);
    for _ in 0..3 {
        data.push(rng.spins(6), rng.normal());
    }
    let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
    let model = blr.fit_model(&data, &mut rng).unwrap();
    assert!(model.energy(&vec![1i8; 6]).is_finite());
}

#[test]
fn blr_duplicate_rows_only() {
    // Rank-1 Φ: heavy collinearity, jitter ladder must cope.
    let mut rng = Rng::new(5);
    let mut data = Dataset::new(5);
    let x = rng.spins(5);
    for _ in 0..30 {
        data.push(x.clone(), 2.0);
    }
    let mut blr = Blr::new(Prior::Horseshoe);
    let a = blr.sample_alpha(&data, &mut rng).unwrap();
    assert!(a.iter().all(|v| v.is_finite()));
}

// ------------------------------------------------------------ problems --

#[test]
fn extreme_problem_shapes() {
    let mut rng = Rng::new(6);
    // K = 1 and D = 1.
    for (n, d, k) in [(8usize, 1usize, 1usize), (2, 5, 1), (4, 3, 4)] {
        let w = Matrix::from_vec(n, d, rng.normals(n * d));
        let p = Problem::new(w, k);
        let m = BinMatrix::new(n, k, rng.spins(n * k));
        let c = p.cost(&m);
        assert!(c.is_finite() && c >= 0.0, "({n},{d},{k})");
        let explicit = p.cost_explicit(&m);
        assert!((c - explicit).abs() < 1e-6 * (1.0 + explicit));
    }
}

#[test]
fn zero_matrix_problem() {
    let p = Problem::new(Matrix::zeros(6, 10), 2);
    let m = BinMatrix::ones(6, 2);
    assert_eq!(p.cost(&m), 0.0);
    assert_eq!(p.w_norm_sq, 0.0);
}

#[test]
fn bbo_on_constant_oracle_terminates() {
    struct Flat;
    impl intdecomp::minlp::Oracle for Flat {
        fn n_bits(&self) -> usize {
            6
        }
        fn eval(&self, _x: &[i8]) -> f64 {
            3.0
        }
    }
    let sa = solvers::sa::SimulatedAnnealing {
        sweeps: 5,
        ..Default::default()
    };
    let cfg = BboConfig::smoke_scale(6, 10);
    let run = bbo::run(
        &Flat,
        &Algorithm::Nbocs { sigma2: 0.1 },
        &sa,
        &cfg,
        &Backends::default(),
        7,
    );
    assert_eq!(run.best_y, 3.0);
    assert_eq!(run.ys.len(), 16);
}

#[test]
fn rfmqa_explores_more_than_fmqa() {
    // ε-greedy must inject random (typically fresh) candidates.
    let p = generate(
        &InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 11 },
        0,
    );
    let sa = solvers::sa::SimulatedAnnealing {
        sweeps: 10,
        ..Default::default()
    };
    let cfg = BboConfig::smoke_scale(p.n_bits(), 60);
    let distinct = |algo: &Algorithm| -> usize {
        let run = bbo::run(&p, algo, &sa, &cfg, &Backends::default(), 3);
        let set: std::collections::HashSet<Vec<i8>> =
            run.xs.into_iter().collect();
        set.len()
    };
    let plain = distinct(&Algorithm::Fmqa { k_fm: 4 });
    let rand = distinct(&Algorithm::Rfmqa { k_fm: 4, eps: 0.5 });
    assert!(
        rand >= plain,
        "rFMQA sampled {rand} distinct vs FMQA {plain}"
    );
}

// ------------------------------------------- serve state / result logs --

fn serve_cfg(dir: &std::path::Path) -> ServeConfig {
    ServeConfig {
        endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
        max_inflight: 1,
        workers: 1,
        state_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

#[test]
fn corrupt_serve_lockfile_is_reclaimed_at_bind() {
    // A state dir left behind with a garbage lockfile (disk corruption,
    // partial write) must not wedge the daemon: unparseable contents
    // are stale by definition and bind takes the lock over.
    let dir = tmpdir("servelock_garbage");
    std::fs::write(dir.join("serve.state.lock"), "\x00\x7f not a pid")
        .unwrap();
    let server = Server::bind(serve_cfg(&dir)).expect("stale takeover");
    drop(server);
    // The reclaimed lock is released on drop, so a restart binds clean.
    let again = Server::bind(serve_cfg(&dir)).unwrap();
    drop(again);
}

#[cfg(target_os = "linux")]
#[test]
fn dead_pid_serve_lockfile_is_reclaimed_at_bind() {
    // A SIGKILLed daemon leaves its PID behind; the next bind must
    // detect the owner is gone and take over instead of failing.
    let dir = tmpdir("servelock_dead");
    // Far above kernel.pid_max, so no live process can own it.
    std::fs::write(dir.join("serve.state.lock"), "4294967294\n").unwrap();
    let server = Server::bind(serve_cfg(&dir)).expect("dead-owner takeover");
    drop(server);
}

#[test]
fn live_pid_serve_lockfile_blocks_bind() {
    // A lockfile naming a live process (here: ourselves) is genuinely
    // held — bind must fail fast with a clear error, not steal it.
    let dir = tmpdir("servelock_live");
    std::fs::write(
        dir.join("serve.state.lock"),
        format!("{}\n", std::process::id()),
    )
    .unwrap();
    let err = Server::bind(serve_cfg(&dir)).unwrap_err();
    assert!(
        format!("{err:#}").contains("held by live process"),
        "unexpected error: {err:#}"
    );
    // The refused bind must not have clobbered the lockfile.
    assert!(dir.join("serve.state.lock").exists());
}

fn log_record(job: usize) -> LayerRecord {
    LayerRecord {
        job,
        name: format!("couche-é{}", job + 1),
        n: 4,
        d: 8,
        k: 2,
        algo: "nBOCS".into(),
        solver: "sa".into(),
        evals: 7,
        best_y: 0.25,
        best_x: vec![1, -1, 1, 1, -1, -1, 1, -1],
        err: 0.04,
        ratio: 0.16,
        cache_hits: 2,
        cache_misses: 5,
        surrogate_failures: 0,
        fallback_proposals: 0,
        rejected_costs: 0,
    }
}

#[test]
fn recover_log_drops_a_tail_torn_mid_utf8() {
    // A crash mid-append can cut a record inside a multi-byte UTF-8
    // sequence.  Whether or not the torn tail is newline-terminated,
    // recovery must keep the valid prefix and drop the tail — never
    // error out on the invalid UTF-8.
    let dir = tmpdir("utf8log");
    let path = dir.join("log.jsonl");
    let l1 = log_record(0).to_json_line("feed").unwrap();
    let l2 = log_record(1).to_json_line("feed").unwrap();
    // Cut the second line one byte into the 'é' (0xC3 0xA9), leaving a
    // dangling lead byte.
    let b2 = l2.as_bytes();
    let cut = b2.iter().position(|&b| b == 0xC3).unwrap() + 1;
    assert!(!l2.is_char_boundary(cut), "cut must split the 'é'");

    // Unterminated torn tail: the scanner never sees a newline, so the
    // tail is dropped as an incomplete line.
    let mut raw = format!("{l1}\n").into_bytes();
    raw.extend_from_slice(&b2[..cut]);
    std::fs::write(&path, &raw).unwrap();
    let rec = recover_log(&path, "feed").unwrap();
    assert_eq!(rec.records.len(), 1);
    assert_eq!(rec.records[0].name, "couche-é1");
    assert_eq!(rec.valid_bytes as usize, l1.len() + 1);
    assert_eq!(rec.dropped_bytes as usize, cut);

    // Newline-terminated torn tail: the line is complete but not valid
    // UTF-8, which must read as a bad line, not a panic or an Err.
    let mut raw = format!("{l1}\n").into_bytes();
    raw.extend_from_slice(&b2[..cut]);
    raw.push(b'\n');
    std::fs::write(&path, &raw).unwrap();
    let rec = recover_log(&path, "feed").unwrap();
    assert_eq!(rec.records.len(), 1);
    assert_eq!(rec.valid_bytes as usize, l1.len() + 1);
    assert_eq!(rec.dropped_bytes as usize, cut + 1);

    // Sanity: an untorn log with the same multi-byte names recovers
    // both records bit-exactly.
    std::fs::write(&path, format!("{l1}\n{l2}\n")).unwrap();
    let rec = recover_log(&path, "feed").unwrap();
    assert_eq!(rec.records.len(), 2);
    assert_eq!(rec.records[1].name, "couche-é2");
    assert_eq!(rec.dropped_bytes, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------- crash-durability (ISSUE 8) --

fn fi_spec(seed: u64) -> ModelSpec {
    ModelSpec {
        n: 4,
        d: 8,
        k: 2,
        gamma: 0.8,
        instance_seed: 9,
        layers: 2,
        iters: 4,
        restarts: 2,
        batch_size: 1,
        augment: false,
        restart_workers: 1,
        algo: "nbocs".into(),
        solver: "sa".into(),
        seed,
        cache_key_raw: false,
    }
}

#[test]
fn checkpoint_log_recovers_a_valid_prefix_at_every_truncation_offset() {
    // Property: whatever byte a crash tears the log at — including
    // mid-UTF-8 and mid-line — recovery keeps exactly the longest
    // whole-line prefix, and finishing the run off that prefix
    // reproduces the uninterrupted log bit for bit.
    let fp = "feed";
    let records: Vec<LayerRecord> = (0..3).map(log_record).collect();
    let mut full = Vec::new();
    for r in &records {
        full.extend_from_slice(r.to_json_line(fp).unwrap().as_bytes());
        full.push(b'\n');
    }
    let dir = tmpdir("ckpt_prop");
    let path = dir.join("log.jsonl");
    let mut cases = 0usize;
    for cut in 0..=full.len() {
        std::fs::write(&path, &full[..cut]).unwrap();
        let rec = recover_log(&path, fp).unwrap();
        assert_eq!(
            rec.valid_bytes + rec.dropped_bytes,
            cut as u64,
            "offset {cut}: prefix + tail must cover the file"
        );
        let n = rec.records.len();
        assert!(n <= records.len(), "offset {cut}");
        for (got, want) in rec.records.iter().zip(&records) {
            assert_eq!(
                got.to_json_line(fp).unwrap(),
                want.to_json_line(fp).unwrap(),
                "offset {cut}: recovered record differs"
            );
        }
        // Resume through the shared CheckpointLog: the torn tail is
        // truncated and re-appending the missing records reproduces
        // the uninterrupted bytes exactly.
        let mut log = CheckpointLog::open(&path, fp).unwrap();
        assert_eq!(log.records().len(), n, "offset {cut}");
        for r in records.iter().skip(n) {
            log.append(r).unwrap();
        }
        drop(log);
        assert_eq!(
            std::fs::read(&path).unwrap(),
            full,
            "offset {cut}: resumed log not byte-identical"
        );
        cases += 1;
    }
    assert!(cases >= 200, "only {cases} truncation cases exercised");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn journal_recovers_a_valid_prefix_at_every_truncation_offset() {
    // Same property for the write-ahead request journal: any
    // truncation yields a consistent prefix, and replaying the
    // remaining operations reproduces the uninterrupted journal.
    let a = fi_spec(1);
    let b = fi_spec(2);
    let (fa, fb) = (a.fingerprint(), b.fingerprint());
    type Op = Box<dyn Fn(&mut Journal) -> std::io::Result<()>>;
    let ops: Vec<Op> = vec![
        {
            let (a, fa) = (a.clone(), fa.clone());
            Box::new(move |j: &mut Journal| j.record_admitted(&a, &fa))
        },
        {
            let (b, fb) = (b.clone(), fb.clone());
            Box::new(move |j: &mut Journal| j.record_admitted(&b, &fb))
        },
        {
            let fa = fa.clone();
            Box::new(move |j: &mut Journal| j.record_completed(&fa))
        },
        {
            let fb = fb.clone();
            Box::new(move |j: &mut Journal| j.record_cancelled(&fb))
        },
    ];
    let dir = tmpdir("journal_prop");
    let path = serve::journal::journal_path(&dir);
    {
        let (mut j, _) = Journal::open(&path).unwrap();
        for op in &ops {
            op(&mut j).unwrap();
        }
    }
    let full = std::fs::read(&path).unwrap();
    assert!(full.len() >= 200, "journal too small for the property");
    for cut in 0..=full.len() {
        std::fs::write(&path, &full[..cut]).unwrap();
        let rec = recover_journal(&path).unwrap();
        assert_eq!(
            rec.valid_bytes + rec.dropped_bytes,
            cut as u64,
            "offset {cut}"
        );
        // Whole lines up to the cut survive; every surviving entry is
        // internally consistent (spec fingerprint == envelope).
        let whole_lines =
            full[..cut].iter().filter(|&&c| c == b'\n').count();
        assert!(rec.entries.len() <= 2, "offset {cut}");
        for e in &rec.entries {
            assert_eq!(e.spec.fingerprint(), e.fingerprint, "offset {cut}");
        }
        // Reopen (truncating the tail) and replay the remaining
        // operations: byte-identical to the uninterrupted journal.
        let (mut j, reopened) = Journal::open(&path).unwrap();
        assert_eq!(
            reopened.valid_bytes,
            rec.valid_bytes,
            "offset {cut}"
        );
        for op in ops.iter().skip(whole_lines) {
            op(&mut j).unwrap();
        }
        drop(j);
        assert_eq!(
            std::fs::read(&path).unwrap(),
            full,
            "offset {cut}: replayed journal not byte-identical"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn interrupted_request_recovers_and_serves_an_identical_report() {
    use std::sync::Arc;
    use std::thread;

    let spec = fi_spec(21);
    let fp = spec.fingerprint();
    let req = compress_request(&spec);

    // Ground truth: an uninterrupted run on a journal-less daemon.
    let plain = Arc::new(
        Server::bind(ServeConfig {
            endpoint: Endpoint::Tcp("127.0.0.1:0".into()),
            max_inflight: 1,
            workers: 1,
            ..Default::default()
        })
        .unwrap(),
    );
    let ep = plain.local_endpoint().clone();
    let srv = Arc::clone(&plain);
    let h = thread::spawn(move || srv.run());
    let truth = serve::request(&ep, &req).unwrap();
    let _ = serve::request(&ep, &serve::bare_request("shutdown"));
    let _ = h.join();
    let tj = Json::parse(truth.last().unwrap()).unwrap();
    assert_eq!(tj.get("type").and_then(Json::as_str), Some("done"));
    assert_eq!(tj.get("recovered").and_then(Json::as_bool), Some(false));
    assert_eq!(
        tj.get("resumed_layers").and_then(Json::as_usize),
        Some(0)
    );
    let report = tj
        .get("report")
        .and_then(Json::as_str)
        .expect("done line carries the report")
        .to_string();

    // Simulate a SIGKILL mid-request: an admitted journal entry and a
    // checkpoint log holding layer 0 plus a torn tail.  The plain
    // run's first response line IS the layer-0 checkpoint line
    // (records are pure functions of the spec).
    let dir = tmpdir("kill_recover");
    {
        let (mut j, _) =
            Journal::open(&serve::journal::journal_path(&dir)).unwrap();
        j.record_admitted(&spec, &fp).unwrap();
    }
    let jobs = serve::journal::jobs_log_path(&dir, &fp);
    std::fs::create_dir_all(jobs.parent().unwrap()).unwrap();
    std::fs::write(&jobs, format!("{}\n{{\"torn", truth[0])).unwrap();

    // Strict mode refuses to start on the torn tail.
    let mut strict = serve_cfg(&dir);
    strict.recover = RecoverMode::Strict;
    let err = Server::bind(strict).unwrap_err();
    assert!(
        format!("{err:#}").contains("torn"),
        "unexpected strict-mode error: {err:#}"
    );

    // The default recovers at bind: layer 1 is re-run, the journal is
    // marked completed, and the re-sent request is served from the
    // durable log with a byte-identical report.
    let server = Arc::new(Server::bind(serve_cfg(&dir)).unwrap());
    let r = server.resume_stats().expect("journaled daemon");
    assert_eq!(r.recovered_requests, 1);
    assert_eq!(r.replayed_layers, 1);
    assert!(r.dropped_bytes > 0, "torn tail must be counted");
    let ep = server.local_endpoint().clone();
    let srv = Arc::clone(&server);
    let h = thread::spawn(move || srv.run());

    // Introspection: the recovered request shows up completed.
    let jl = serve::request(&ep, &serve::bare_request("jobs")).unwrap();
    let jj = Json::parse(jl.last().unwrap()).unwrap();
    let rows = match jj.get("jobs") {
        Some(Json::Arr(rows)) => rows.clone(),
        other => panic!("jobs reply: {other:?}"),
    };
    assert_eq!(rows.len(), 1);
    assert_eq!(
        rows[0].get("fingerprint").and_then(Json::as_str),
        Some(fp.as_str())
    );
    assert_eq!(
        rows[0].get("status").and_then(Json::as_str),
        Some("completed")
    );
    assert_eq!(
        rows[0].get("layers_done").and_then(Json::as_usize),
        Some(2)
    );

    let served = serve::request(&ep, &req).unwrap();
    let _ = serve::request(&ep, &serve::bare_request("shutdown"));
    let _ = h.join();
    assert_eq!(
        served[..spec.layers],
        truth[..spec.layers],
        "streamed layer lines must be byte-identical"
    );
    let sj = Json::parse(served.last().unwrap()).unwrap();
    assert_eq!(sj.get("type").and_then(Json::as_str), Some("done"));
    assert_eq!(sj.get("recovered").and_then(Json::as_bool), Some(true));
    assert_eq!(
        sj.get("resumed_layers").and_then(Json::as_usize),
        Some(spec.layers)
    );
    assert_eq!(
        sj.get("report").and_then(Json::as_str),
        Some(report.as_str()),
        "recovered-then-served report must be byte-identical"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------- cli --

#[test]
fn cli_rejects_malformed_flags() {
    assert!(Args::parse(["--".to_string()]).is_err());
    let a = Args::parse(["x".into(), "--runs".into(), "nan".into()])
        .unwrap();
    assert!(a.usize_flag("runs", 1).is_err());
}

#[test]
fn config_rejects_bad_numbers() {
    let a = Args::parse(["exp".into(), "--iters=abc".into()]).unwrap();
    assert!(intdecomp::config::ExpConfig::from_args(&a).is_err());
}
