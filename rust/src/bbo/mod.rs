//! The black-box optimisation loop — the paper's core algorithm.
//!
//! ```text
//!   data ← n random evaluations                    (initial design)
//!   repeat 2n² times:
//!     surrogate ← fit(data)         (BOCS Thompson draw / FM training)
//!     x* ← IsingSolver.minimise(surrogate)        (best of 10 restarts)
//!     y* ← f(x*)                                  (black-box evaluation)
//!     data ← data ∪ {(x*, y*)}   [+ symmetry orbit if augmenting]
//! ```
//!
//! Algorithms (paper labels): RS, vBOCS, nBOCS, gBOCS, FMQA08, FMQA12,
//! nBOCSqa / nBOCSsq (solver swaps) and nBOCSa (data augmentation).

use crate::minlp::Oracle;
use crate::solvers::IsingSolver;
use crate::surrogate::{
    blr::{Blr, PosteriorBackend, Prior},
    fm::{FactorizationMachine, FmTrainer},
    Dataset, Surrogate,
};
use crate::util::{rng::Rng, timer::Timer};

/// Paper algorithm selector.
#[derive(Clone, Debug, PartialEq)]
pub enum Algorithm {
    /// Random search baseline.
    Rs,
    /// Horseshoe-prior BOCS (vanilla).
    Vbocs,
    /// Normal-prior BOCS (paper-tuned σ² = 0.1).
    Nbocs { sigma2: f64 },
    /// Normal-gamma BOCS (paper-tuned β = 0.001).
    Gbocs { beta: f64 },
    /// Factorisation machine with k_FM factors (8 or 12 in the paper).
    Fmqa { k_fm: usize },
    /// Randomised FMQA (the paper's Discussion / ref. 24 future-work
    /// item): FMQA plus ε-greedy exploration — with probability ε the
    /// acquired candidate is random, which breaks the deterministic
    /// trap-in-local-minimum behaviour of vanilla FMQA.
    Rfmqa { k_fm: usize, eps: f64 },
}

impl Algorithm {
    pub fn label(&self) -> String {
        match self {
            Algorithm::Rs => "RS".into(),
            Algorithm::Vbocs => "vBOCS".into(),
            Algorithm::Nbocs { .. } => "nBOCS".into(),
            Algorithm::Gbocs { .. } => "gBOCS".into(),
            Algorithm::Fmqa { k_fm } => format!("FMQA{k_fm:02}"),
            Algorithm::Rfmqa { k_fm, .. } => format!("rFMQA{k_fm:02}"),
        }
    }

    /// The paper's tuned defaults (Fig. 6 grid searches).
    pub fn by_name(name: &str) -> Option<Algorithm> {
        match name {
            "rs" | "RS" => Some(Algorithm::Rs),
            "vbocs" | "vBOCS" => Some(Algorithm::Vbocs),
            "nbocs" | "nBOCS" => Some(Algorithm::Nbocs { sigma2: 0.1 }),
            "gbocs" | "gBOCS" => Some(Algorithm::Gbocs { beta: 0.001 }),
            "fmqa08" | "FMQA08" => Some(Algorithm::Fmqa { k_fm: 8 }),
            "fmqa12" | "FMQA12" => Some(Algorithm::Fmqa { k_fm: 12 }),
            "rfmqa08" | "rFMQA08" => {
                Some(Algorithm::Rfmqa { k_fm: 8, eps: 0.1 })
            }
            "rfmqa12" | "rFMQA12" => {
                Some(Algorithm::Rfmqa { k_fm: 12, eps: 0.1 })
            }
            _ => None,
        }
    }
}

/// Loop configuration.
#[derive(Clone, Debug)]
pub struct BboConfig {
    /// Initial random design size (paper: n).
    pub n_init: usize,
    /// Acquisition iterations (paper: 2n²).
    pub iters: usize,
    /// Ising-solver restarts per iteration (paper: 10).
    pub restarts: usize,
    /// Add the symmetry orbit of each evaluation (nBOCSa / Fig. 3).
    pub augment: bool,
    /// Worker threads for the restart fan-out.  `1` (the default)
    /// reproduces the legacy serial restart loop bit-for-bit (one RNG
    /// threaded through all restarts); any value `> 1` switches to
    /// per-restart RNG streams forked from the loop RNG
    /// ([`crate::solvers::solve_best_parallel`]), whose result is
    /// bit-identical for every worker count `> 1`.
    pub restart_workers: usize,
}

impl BboConfig {
    /// Paper defaults for a problem of n bits: n init + 2n² iterations.
    pub fn paper_scale(n_bits: usize) -> Self {
        BboConfig {
            n_init: n_bits,
            iters: 2 * n_bits * n_bits,
            restarts: 10,
            augment: false,
            restart_workers: 1,
        }
    }

    /// Reduced smoke scale for tests / default CLI runs.
    pub fn smoke_scale(n_bits: usize, iters: usize) -> Self {
        BboConfig {
            n_init: n_bits,
            iters,
            restarts: 10,
            augment: false,
            restart_workers: 1,
        }
    }
}

/// Per-run output: everything the figures need.
#[derive(Clone, Debug)]
pub struct BboRun {
    pub algo: String,
    pub solver: String,
    /// Black-box evaluations in acquisition order (init design first).
    pub xs: Vec<Vec<i8>>,
    pub ys: Vec<f64>,
    /// Best-so-far cost after each evaluation.
    pub best_curve: Vec<f64>,
    /// Final best (x, y).
    pub best_x: Vec<i8>,
    pub best_y: f64,
    /// Wall-clock breakdown (seconds).
    pub time_total: f64,
    pub time_surrogate: f64,
    pub time_solver: f64,
    pub time_eval: f64,
}

impl BboRun {
    /// Did the run hit the exact optimum (within tolerance)?
    pub fn found_exact(&self, best_cost: f64, tol: f64) -> bool {
        self.best_y <= best_cost + tol
    }
}

/// Hooks for routing heavy steps through the PJRT artifacts.
#[derive(Default)]
pub struct Backends {
    pub posterior: Option<Box<dyn Fn() -> Box<dyn PosteriorBackend>>>,
    pub fm_trainer: Option<Box<dyn Fn(usize) -> Box<dyn FmTrainer>>>,
}

fn build_surrogate(
    algo: &Algorithm,
    n_bits: usize,
    backends: &Backends,
    rng: &mut Rng,
) -> Option<Box<dyn Surrogate>> {
    let make_blr = |prior: Prior| -> Box<dyn Surrogate> {
        match &backends.posterior {
            Some(f) => Box::new(Blr::with_backend(prior, f())),
            None => Box::new(Blr::new(prior)),
        }
    };
    match algo {
        Algorithm::Rs => None,
        Algorithm::Vbocs => Some(make_blr(Prior::Horseshoe)),
        Algorithm::Nbocs { sigma2 } => {
            Some(make_blr(Prior::Normal { sigma2: *sigma2 }))
        }
        Algorithm::Gbocs { beta } => {
            Some(make_blr(Prior::NormalGamma { a: 1.0, beta: *beta }))
        }
        Algorithm::Fmqa { k_fm } | Algorithm::Rfmqa { k_fm, .. } => {
            let mut fm = FactorizationMachine::new(n_bits, *k_fm, rng);
            if let Some(f) = &backends.fm_trainer {
                fm = fm.with_trainer(f(*k_fm));
            }
            Some(Box::new(fm))
        }
    }
}

/// Run one BBO optimisation.
pub fn run(
    oracle: &dyn Oracle,
    algo: &Algorithm,
    solver: &dyn IsingSolver,
    cfg: &BboConfig,
    backends: &Backends,
    seed: u64,
) -> BboRun {
    let total_timer = Timer::start();
    let mut rng = Rng::new(seed);
    let n = oracle.n_bits();
    let mut data = Dataset::new(n);
    let mut surrogate = build_surrogate(algo, n, backends, &mut rng);

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut best_curve = Vec::new();
    let mut best_x: Vec<i8> = Vec::new();
    let mut best_y = f64::INFINITY;
    let (mut t_sur, mut t_sol, mut t_eval) = (0.0, 0.0, 0.0);

    let mut record = |x: Vec<i8>,
                      y: f64,
                      data: &mut Dataset,
                      xs: &mut Vec<Vec<i8>>,
                      ys: &mut Vec<f64>,
                      best_curve: &mut Vec<f64>| {
        if y < best_y {
            best_y = y;
            best_x = x.clone();
        }
        best_curve.push(best_y);
        if cfg.augment {
            for eq in oracle.equivalents(&x) {
                data.push(eq, y);
            }
        }
        data.push(x.clone(), y);
        xs.push(x);
        ys.push(y);
    };

    // Initial design.
    for _ in 0..cfg.n_init {
        let x = rng.spins(n);
        let t = Timer::start();
        let y = oracle.eval(&x);
        t_eval += t.seconds();
        record(x, y, &mut data, &mut xs, &mut ys, &mut best_curve);
    }

    // ε-greedy exploration rate (rFMQA only).
    let eps = match algo {
        Algorithm::Rfmqa { eps, .. } => *eps,
        _ => 0.0,
    };

    // Acquisition loop.
    for _ in 0..cfg.iters {
        let x = match surrogate.as_mut() {
            None => rng.spins(n), // RS
            Some(sur) => {
                let t = Timer::start();
                let model = sur.fit_model(&data, &mut rng);
                t_sur += t.seconds();
                let t = Timer::start();
                let (x, _) = if cfg.restart_workers > 1 {
                    crate::solvers::solve_best_parallel(
                        solver,
                        &model,
                        &mut rng,
                        cfg.restarts,
                        cfg.restart_workers,
                    )
                } else {
                    solver.solve_best(&model, &mut rng, cfg.restarts)
                };
                t_sol += t.seconds();
                if eps > 0.0 && rng.f64() < eps {
                    rng.spins(n) // randomised-FMQA exploration step
                } else {
                    x
                }
            }
        };
        let t = Timer::start();
        let y = oracle.eval(&x);
        t_eval += t.seconds();
        record(x, y, &mut data, &mut xs, &mut ys, &mut best_curve);
    }

    BboRun {
        algo: algo.label() + if cfg.augment { "a" } else { "" },
        solver: solver.name().into(),
        xs,
        ys,
        best_curve,
        best_x,
        best_y,
        time_total: total_timer.seconds(),
        time_surrogate: t_sur,
        time_solver: t_sol,
        time_eval: t_eval,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::{generate, InstanceConfig};
    use crate::solvers::sa::SimulatedAnnealing;

    fn tiny_problem() -> crate::cost::Problem {
        let cfg =
            InstanceConfig { n: 4, d: 10, k: 2, gamma: 0.8, seed: 77 };
        generate(&cfg, 0)
    }

    #[test]
    fn best_curve_is_monotone_nonincreasing() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 30);
        let run = run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            1,
        );
        assert_eq!(run.best_curve.len(), cfg.n_init + cfg.iters);
        for w in run.best_curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!((run.best_curve.last().unwrap() - run.best_y).abs() < 1e-12);
    }

    #[test]
    fn nbocs_beats_random_search_on_tiny_problem() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 60);
        let mut n_wins = 0;
        for seed in 0..3 {
            let rb = run(&p, &Algorithm::Rs, &sa, &cfg,
                         &Backends::default(), seed);
            let nb = run(
                &p,
                &Algorithm::Nbocs { sigma2: 0.1 },
                &sa,
                &cfg,
                &Backends::default(),
                seed,
            );
            if nb.best_y <= rb.best_y + 1e-12 {
                n_wins += 1;
            }
        }
        assert!(n_wins >= 2, "nBOCS won only {n_wins}/3 vs RS");
    }

    #[test]
    fn bbo_finds_exact_solution_on_tiny_problem() {
        let p = tiny_problem();
        let exact = crate::bruteforce::brute_force(&p);
        let sa = SimulatedAnnealing { sweeps: 30, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 2 * 8 * 8);
        let r = run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            5,
        );
        assert!(
            r.found_exact(exact.best_cost, 1e-9),
            "best {} vs exact {}",
            r.best_y,
            exact.best_cost
        );
    }

    #[test]
    fn augmentation_multiplies_dataset_not_evaluations() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 10);
        cfg.augment = true;
        let r = run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            2,
        );
        // Evaluations (x-axis) unchanged by augmentation.
        assert_eq!(r.xs.len(), cfg.n_init + cfg.iters);
        assert!(r.algo.ends_with('a'));
    }

    #[test]
    fn all_algorithms_run() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 5);
        for name in ["rs", "vbocs", "nbocs", "gbocs", "fmqa08", "fmqa12"] {
            let algo = Algorithm::by_name(name).unwrap();
            let r =
                run(&p, &algo, &sa, &cfg, &Backends::default(), 3);
            assert_eq!(r.ys.len(), cfg.n_init + cfg.iters, "{name}");
            assert!(r.best_y.is_finite(), "{name}");
        }
    }

    #[test]
    fn restart_fanout_is_worker_count_invariant() {
        // restart_workers > 1 uses forked per-restart streams, so the
        // whole run is bit-identical for any worker count > 1.
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 12);
        cfg.restart_workers = 2;
        let a = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 11);
        cfg.restart_workers = 6;
        let b = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 11);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.best_x, b.best_x);
        assert_eq!(a.best_y, b.best_y);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 15);
        let a = run(&p, &Algorithm::Gbocs { beta: 0.001 }, &sa, &cfg,
                    &Backends::default(), 9);
        let b = run(&p, &Algorithm::Gbocs { beta: 0.001 }, &sa, &cfg,
                    &Backends::default(), 9);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.best_x, b.best_x);
    }
}
