//! The black-box optimisation loop — the paper's core algorithm.
//!
//! ```text
//!   data ← n random evaluations                    (initial design)
//!   repeat 2n² times:
//!     surrogate ← fit(data)         (BOCS Thompson draw / FM training)
//!     x* ← IsingSolver.minimise(surrogate)        (best of 10 restarts)
//!     y* ← f(x*)                                  (black-box evaluation)
//!     data ← data ∪ {(x*, y*)}   [+ symmetry orbit if augmenting]
//! ```
//!
//! Algorithms (paper labels): RS, vBOCS, nBOCS, gBOCS, FMQA08, FMQA12,
//! nBOCSqa / nBOCSsq (solver swaps) and nBOCSa (data augmentation).
//!
//! **Batched acquisition** ([`BboConfig::batch_size`] > 1, FMQA-style,
//! arXiv:2209.01016) amortises the expensive surrogate fit: one fit per
//! iteration feeds [`crate::solvers::solve_batch`], the top-k distinct
//! restart minima are all evaluated concurrently on the persistent
//! worker pool, and the dataset ingests the whole batch in one update.
//! The total evaluation budget ([`BboConfig::iters`]) is unchanged —
//! batching only divides the number of surrogate fits by k.
//!
//! **Solver execution** (ISSUE 4): every acquisition's restart fan-out —
//! serial `solve_best`, [`crate::solvers::solve_best_parallel`] and
//! [`crate::solvers::solve_batch`] alike — runs on the replica-major
//! lockstep engine ([`crate::solvers::replica`]), with the per-model
//! schedule scan hoisted out of the restart loop.  Results are
//! bit-identical to the legacy per-chain execution on every path.

use crate::linalg::NumericError;
use crate::minlp::Oracle;
use crate::solvers::IsingSolver;
use crate::surrogate::{
    blr::{Blr, PosteriorBackend, Prior},
    fm::{FactorizationMachine, FmTrainer},
    Dataset, Surrogate,
};
use crate::util::cancel::{CancelCause, CancelToken};
use crate::util::{rng::Rng, timer::Timer};

pub use crate::surrogate::state::{StateError, SurrogateState, WarmStart};

/// Paper algorithm selector.
#[derive(Clone, Debug, PartialEq)]
pub enum Algorithm {
    /// Random search baseline.
    Rs,
    /// Horseshoe-prior BOCS (vanilla).
    Vbocs,
    /// Normal-prior BOCS (paper-tuned σ² = 0.1).
    Nbocs { sigma2: f64 },
    /// Normal-gamma BOCS (paper-tuned β = 0.001).
    Gbocs { beta: f64 },
    /// Factorisation machine with k_FM factors (8 or 12 in the paper).
    Fmqa { k_fm: usize },
    /// Randomised FMQA (the paper's Discussion / ref. 24 future-work
    /// item): FMQA plus ε-greedy exploration — with probability ε the
    /// acquired candidate is random, which breaks the deterministic
    /// trap-in-local-minimum behaviour of vanilla FMQA.
    Rfmqa { k_fm: usize, eps: f64 },
}

impl Algorithm {
    /// The paper's label for this algorithm (e.g. "nBOCS", "FMQA08").
    pub fn label(&self) -> String {
        match self {
            Algorithm::Rs => "RS".into(),
            Algorithm::Vbocs => "vBOCS".into(),
            Algorithm::Nbocs { .. } => "nBOCS".into(),
            Algorithm::Gbocs { .. } => "gBOCS".into(),
            Algorithm::Fmqa { k_fm } => format!("FMQA{k_fm:02}"),
            Algorithm::Rfmqa { k_fm, .. } => format!("rFMQA{k_fm:02}"),
        }
    }

    /// The paper's tuned defaults (Fig. 6 grid searches).
    pub fn by_name(name: &str) -> Option<Algorithm> {
        match name {
            "rs" | "RS" => Some(Algorithm::Rs),
            "vbocs" | "vBOCS" => Some(Algorithm::Vbocs),
            "nbocs" | "nBOCS" => Some(Algorithm::Nbocs { sigma2: 0.1 }),
            "gbocs" | "gBOCS" => Some(Algorithm::Gbocs { beta: 0.001 }),
            "fmqa08" | "FMQA08" => Some(Algorithm::Fmqa { k_fm: 8 }),
            "fmqa12" | "FMQA12" => Some(Algorithm::Fmqa { k_fm: 12 }),
            "rfmqa08" | "rFMQA08" => {
                Some(Algorithm::Rfmqa { k_fm: 8, eps: 0.1 })
            }
            "rfmqa12" | "rFMQA12" => {
                Some(Algorithm::Rfmqa { k_fm: 12, eps: 0.1 })
            }
            _ => None,
        }
    }

    /// The surrogate-state kind this algorithm's surrogate exports and
    /// accepts (`None` for surrogate-free random search) — the
    /// compatibility key checked before attaching a persisted
    /// [`SurrogateState`] to a job (serve warm store, CLI
    /// `--warm-from`).
    pub fn state_kind(&self) -> Option<String> {
        match self {
            Algorithm::Rs => None,
            Algorithm::Vbocs => Some("vBOCS".into()),
            Algorithm::Nbocs { .. } => Some("nBOCS".into()),
            Algorithm::Gbocs { .. } => Some("gBOCS".into()),
            Algorithm::Fmqa { k_fm } | Algorithm::Rfmqa { k_fm, .. } => {
                Some(format!("fm-k{k_fm}"))
            }
        }
    }
}

/// Loop configuration.
///
/// ```
/// use intdecomp::bbo::BboConfig;
///
/// let cfg = BboConfig::paper_scale(24);
/// assert_eq!((cfg.n_init, cfg.iters, cfg.restarts), (24, 1152, 10));
/// // Serial, single-threaded defaults — the paper's exact protocol.
/// assert_eq!((cfg.restart_workers, cfg.batch_size), (1, 1));
/// ```
#[derive(Clone, Debug)]
pub struct BboConfig {
    /// Initial random design size (paper: n).
    pub n_init: usize,
    /// Acquisition iterations (paper: 2n²).
    pub iters: usize,
    /// Ising-solver restarts per iteration (paper: 10).
    pub restarts: usize,
    /// Add the symmetry orbit of each evaluation (nBOCSa / Fig. 3).
    pub augment: bool,
    /// Worker threads for the restart fan-out.  `1` (the default)
    /// reproduces the legacy serial restart loop bit-for-bit (one RNG
    /// threaded through all restarts); any value `> 1` switches to
    /// per-restart RNG streams forked from the loop RNG
    /// ([`crate::solvers::solve_best_parallel`]), whose result is
    /// bit-identical for every worker count `> 1`.
    pub restart_workers: usize,
    /// Candidates acquired per surrogate fit (batched acquisition,
    /// FMQA-style).  `1` (the default) is the paper's serial loop,
    /// bit-for-bit identical to the legacy stream when
    /// `restart_workers` is also 1.  Any value `> 1` fits the surrogate
    /// once per iteration, takes the top-k distinct restart minima from
    /// [`crate::solvers::solve_batch`] (padding with random candidates
    /// when the restarts found fewer distinct minima), evaluates them
    /// concurrently, and ingests all of them in one dataset update.
    /// The total evaluation budget `iters` is unchanged; results are
    /// deterministic for any worker count.
    pub batch_size: usize,
}

impl BboConfig {
    /// Paper defaults for a problem of n bits: n init + 2n² iterations.
    pub fn paper_scale(n_bits: usize) -> Self {
        BboConfig {
            n_init: n_bits,
            iters: 2 * n_bits * n_bits,
            restarts: 10,
            augment: false,
            restart_workers: 1,
            batch_size: 1,
        }
    }

    /// Reduced smoke scale for tests / default CLI runs.
    pub fn smoke_scale(n_bits: usize, iters: usize) -> Self {
        BboConfig {
            n_init: n_bits,
            iters,
            restarts: 10,
            augment: false,
            restart_workers: 1,
            batch_size: 1,
        }
    }

    /// Override the solver restart count.
    ///
    /// Together with the other `with_*` setters this is the ONE shared
    /// builder path for loop configuration (ISSUE 10): `ExpConfig`,
    /// `ModelSpec`, `CompressionJob` and the engine's per-job overrides
    /// all chain these on a [`BboConfig::paper_scale`] /
    /// [`BboConfig::smoke_scale`] base instead of re-spelling the
    /// struct literal at each layer.
    pub fn with_restarts(mut self, restarts: usize) -> Self {
        self.restarts = restarts;
        self
    }

    /// Enable/disable symmetry-orbit data augmentation (nBOCSa).
    pub fn with_augment(mut self, augment: bool) -> Self {
        self.augment = augment;
        self
    }

    /// Override the restart fan-out worker count (clamped to ≥ 1).
    pub fn with_restart_workers(mut self, workers: usize) -> Self {
        self.restart_workers = workers.max(1);
        self
    }

    /// Override the acquisition batch size (clamped to ≥ 1).
    pub fn with_batch_size(mut self, batch: usize) -> Self {
        self.batch_size = batch.max(1);
        self
    }
}

/// Counters for every degraded-mode event of one BBO run (ISSUE 9).
///
/// A fault-free run has all counters at zero; each nonzero count marks
/// one place where the loop absorbed a numeric fault instead of
/// aborting.  The counters are exact — the fault-injection tests assert
/// they match the number of injected faults — and they propagate to
/// `LayerRecord` rows and the serve daemon's `stats` line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Degradation {
    /// Surrogate fits that failed with a typed [`NumericError`] (non-SPD
    /// posterior, diverged FM) and were replaced by a fallback
    /// acquisition.
    pub surrogate_failures: u64,
    /// Candidates proposed by the random fallback instead of the
    /// surrogate+solver path (one per missing candidate; a failed
    /// batched fit counts the whole batch).
    pub fallback_proposals: u64,
    /// Oracle evaluations quarantined because the cost came back
    /// non-finite — recorded in the trace but never pushed into the
    /// surrogate dataset's Gram moments.
    pub rejected_costs: u64,
}

impl Degradation {
    /// True when any degraded-mode event occurred.
    pub fn any(&self) -> bool {
        self.surrogate_failures > 0
            || self.fallback_proposals > 0
            || self.rejected_costs > 0
    }
}

/// Why a [`run_cancellable`] / [`run_warm`] call did not produce a
/// [`BboRun`].
#[derive(Clone, Debug, PartialEq)]
pub enum RunError {
    /// The cancel token tripped (caller cancelled or deadline expired).
    Cancelled(CancelCause),
    /// A numeric fault the degraded mode could not absorb — today only
    /// [`NumericError::NonFiniteCost`]: every oracle evaluation was
    /// quarantined, so there is no finite best to report.
    Numeric(NumericError),
    /// The supplied warm-start state is incompatible with this run
    /// (wrong problem size, wrong surrogate kind, malformed payload).
    /// Warm-start errors are never silently degraded to a cold start —
    /// the caller decides.
    Warm(StateError),
}

impl std::fmt::Display for RunError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunError::Cancelled(cause) => write!(f, "{cause}"),
            RunError::Numeric(e) => write!(f, "{e}"),
            RunError::Warm(e) => write!(f, "warm start rejected: {e}"),
        }
    }
}

impl std::error::Error for RunError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RunError::Cancelled(_) => None,
            RunError::Numeric(e) => Some(e),
            RunError::Warm(e) => Some(e),
        }
    }
}

impl From<CancelCause> for RunError {
    fn from(cause: CancelCause) -> Self {
        RunError::Cancelled(cause)
    }
}

impl From<NumericError> for RunError {
    fn from(e: NumericError) -> Self {
        RunError::Numeric(e)
    }
}

impl From<StateError> for RunError {
    fn from(e: StateError) -> Self {
        RunError::Warm(e)
    }
}

/// Per-run output: everything the figures need.
#[derive(Clone, Debug)]
pub struct BboRun {
    /// Algorithm label (with the augmentation suffix when enabled).
    pub algo: String,
    /// Ising-solver name used for the acquisition minimisations.
    pub solver: String,
    /// Black-box evaluations in acquisition order (init design first).
    pub xs: Vec<Vec<i8>>,
    /// Observed costs, aligned with `xs`.
    pub ys: Vec<f64>,
    /// Best-so-far cost after each evaluation.
    pub best_curve: Vec<f64>,
    /// Final best (x, y).
    pub best_x: Vec<i8>,
    /// Cost of `best_x` — the run's final result.
    pub best_y: f64,
    /// Total wall-clock of the run (seconds).
    pub time_total: f64,
    /// Seconds spent fitting / drawing from the surrogate.
    pub time_surrogate: f64,
    /// Seconds spent in Ising-solver restarts.
    pub time_solver: f64,
    /// Seconds spent in black-box evaluations.
    pub time_eval: f64,
    /// Degraded-mode event counters (all zero on a fault-free run).
    pub degradation: Degradation,
}

impl BboRun {
    /// Did the run hit the exact optimum (within tolerance)?
    pub fn found_exact(&self, best_cost: f64, tol: f64) -> bool {
        self.best_y <= best_cost + tol
    }
}

/// Hooks for routing heavy steps through the PJRT artifacts.
#[derive(Default)]
pub struct Backends {
    /// Factory for the BLR posterior-draw backend (None = native).
    pub posterior: Option<Box<dyn Fn() -> Box<dyn PosteriorBackend>>>,
    /// Factory for the FM trainer backend, keyed on k_FM (None = native).
    pub fm_trainer: Option<Box<dyn Fn(usize) -> Box<dyn FmTrainer>>>,
}

fn build_surrogate(
    algo: &Algorithm,
    n_bits: usize,
    backends: &Backends,
    rng: &mut Rng,
) -> Option<Box<dyn Surrogate>> {
    let make_blr = |prior: Prior| -> Box<dyn Surrogate> {
        match &backends.posterior {
            Some(f) => Box::new(Blr::with_backend(prior, f())),
            None => Box::new(Blr::new(prior)),
        }
    };
    match algo {
        Algorithm::Rs => None,
        Algorithm::Vbocs => Some(make_blr(Prior::Horseshoe)),
        Algorithm::Nbocs { sigma2 } => {
            Some(make_blr(Prior::Normal { sigma2: *sigma2 }))
        }
        Algorithm::Gbocs { beta } => {
            Some(make_blr(Prior::NormalGamma { a: 1.0, beta: *beta }))
        }
        Algorithm::Fmqa { k_fm } | Algorithm::Rfmqa { k_fm, .. } => {
            let mut fm = FactorizationMachine::new(n_bits, *k_fm, rng);
            if let Some(f) = &backends.fm_trainer {
                fm = fm.with_trainer(f(*k_fm));
            }
            Some(Box::new(fm))
        }
    }
}

/// Rolling per-evaluation bookkeeping shared by the serial and batched
/// acquisition paths: best-so-far tracking plus the xs/ys/best-curve
/// traces the figures need.
struct Trace {
    xs: Vec<Vec<i8>>,
    ys: Vec<f64>,
    best_curve: Vec<f64>,
    best_x: Vec<i8>,
    best_y: f64,
}

impl Trace {
    fn new() -> Self {
        Trace {
            xs: Vec::new(),
            ys: Vec::new(),
            best_curve: Vec::new(),
            best_x: Vec::new(),
            best_y: f64::INFINITY,
        }
    }

    /// Record one evaluation (in acquisition order).
    fn note(&mut self, x: Vec<i8>, y: f64) {
        if y < self.best_y {
            self.best_y = y;
            self.best_x = x.clone();
        }
        self.best_curve.push(self.best_y);
        self.xs.push(x);
        self.ys.push(y);
    }
}

/// Expand one evaluation into the dataset rows it contributes: the
/// symmetry orbit first when augmenting (nBOCSa), then the point itself
/// — the same push order the legacy serial loop used.
fn expand_pairs(
    oracle: &dyn Oracle,
    augment: bool,
    x: &[i8],
    y: f64,
    out: &mut Vec<(Vec<i8>, f64)>,
) {
    if augment {
        for eq in oracle.equivalents(x) {
            out.push((eq, y));
        }
    }
    out.push((x.to_vec(), y));
}

/// Run one BBO optimisation.
///
/// With `cfg.batch_size == 1` this is the paper's serial loop: one
/// surrogate fit, one solver fan-out and one black-box evaluation per
/// iteration (bit-for-bit the legacy stream when `restart_workers` is
/// also 1).  With `cfg.batch_size = k > 1` each iteration fits the
/// surrogate once, acquires the top-k distinct candidates from
/// [`crate::solvers::solve_batch`], evaluates all of them concurrently
/// on the persistent worker pool, and ingests the whole batch into the
/// dataset in one update ([`Dataset::push_batch`]); the total number of
/// black-box evaluations stays `cfg.n_init + cfg.iters` either way.
///
/// Every run is a pure function of `(oracle, algo, solver, cfg, seed)`:
/// worker counts never change the result.
///
/// ```
/// use intdecomp::bbo::{self, Algorithm, Backends, BboConfig};
/// use intdecomp::instance::{generate, InstanceConfig};
/// use intdecomp::solvers::sa::SimulatedAnnealing;
///
/// let icfg = InstanceConfig { n: 4, d: 10, k: 2, gamma: 0.8, seed: 7 };
/// let p = generate(&icfg, 0);
/// let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
/// let mut cfg = BboConfig::smoke_scale(p.n_bits(), 8);
/// cfg.batch_size = 4; // 2 surrogate fits instead of 8
/// let run = bbo::run(
///     &p,
///     &Algorithm::Nbocs { sigma2: 0.1 },
///     &sa,
///     &cfg,
///     &Backends::default(),
///     1,
/// );
/// assert_eq!(run.ys.len(), cfg.n_init + cfg.iters);
/// assert!(run.best_y.is_finite());
/// ```
pub fn run(
    oracle: &dyn Oracle,
    algo: &Algorithm,
    solver: &dyn IsingSolver,
    cfg: &BboConfig,
    backends: &Backends,
    seed: u64,
) -> BboRun {
    match run_cancellable(
        oracle,
        algo,
        solver,
        cfg,
        backends,
        seed,
        &CancelToken::never(),
    ) {
        Ok(run) => run,
        Err(RunError::Cancelled(cause)) => {
            unreachable!("never-token run reported cancellation: {cause}")
        }
        // A finite-input oracle (Problem::cost of a finite W) always
        // produces finite costs, so this is unreachable for real
        // problems; fault-injection callers use run_cancellable.
        Err(RunError::Numeric(e)) => panic!("BBO run failed: {e}"),
        // `run` never supplies a warm start.
        Err(RunError::Warm(e)) => {
            unreachable!("cold run reported a warm-start error: {e}")
        }
    }
}

/// [`run`] with cooperative cancellation: `cancel` is polled at every
/// iteration boundary (each initial-design evaluation and each
/// acquisition step — serial or batched), and a tripped token unwinds
/// the run with its [`CancelCause`] before the next step starts.
///
/// The checks never touch the RNG or any numeric path, so a run that
/// *completes* under a token is bit-identical to [`run`] with the same
/// seed — the serve daemon's byte-identity contract for requests that
/// finish.
///
/// **Degraded-mode determinism contract (ISSUE 9).**  Numeric faults
/// degrade rather than abort: a failed surrogate fit falls back to
/// random candidate proposal (each missing candidate consumes exactly
/// one `rng.spins(n_bits)` from the main acquisition stream, in
/// candidate order, after the fit's own RNG consumption), and a
/// non-finite oracle cost is quarantined — recorded in the trace but
/// never pushed into the surrogate dataset.  Fault-free runs never
/// enter either branch, so they stay bit-identical to the pre-fault
/// streams.  Every degraded event is counted in [`BboRun::degradation`].
/// Only a run with *no* finite cost at all fails, with
/// [`RunError::Numeric`]\([`NumericError::NonFiniteCost`]).
///
/// ```
/// use intdecomp::bbo::{self, Algorithm, Backends, BboConfig, RunError};
/// use intdecomp::instance::{generate, InstanceConfig};
/// use intdecomp::solvers::sa::SimulatedAnnealing;
/// use intdecomp::util::cancel::{CancelCause, CancelToken};
///
/// let icfg = InstanceConfig { n: 4, d: 10, k: 2, gamma: 0.8, seed: 7 };
/// let p = generate(&icfg, 0);
/// let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
/// let cfg = BboConfig::smoke_scale(p.n_bits(), 8);
/// let tok = CancelToken::never();
/// tok.cancel(); // already tripped: aborts before any evaluation
/// let out = bbo::run_cancellable(
///     &p,
///     &Algorithm::Nbocs { sigma2: 0.1 },
///     &sa,
///     &cfg,
///     &Backends::default(),
///     1,
///     &tok,
/// );
/// assert_eq!(out.unwrap_err(), RunError::Cancelled(CancelCause::Cancelled));
/// ```
#[allow(clippy::too_many_arguments)]
pub fn run_cancellable(
    oracle: &dyn Oracle,
    algo: &Algorithm,
    solver: &dyn IsingSolver,
    cfg: &BboConfig,
    backends: &Backends,
    seed: u64,
    cancel: &CancelToken,
) -> Result<BboRun, RunError> {
    run_warm(oracle, algo, solver, cfg, backends, seed, cancel, None, false)
        .map(|w| w.run)
}

/// Output of [`run_warm`]: the run itself plus the warm-start metadata.
#[derive(Clone, Debug)]
pub struct WarmRun {
    /// The optimisation run.
    pub run: BboRun,
    /// End-of-run exported state (when requested): the final dataset
    /// with its sufficient statistics plus the fitted surrogate's
    /// parameters, ready to seed a later run.
    pub state: Option<SurrogateState>,
    /// True when a warm start was applied (the run skipped the random
    /// initial design).
    pub warm: bool,
}

/// [`run_cancellable`] with warm-start input and state export
/// (ISSUE 10).
///
/// With `warm = None` this *is* [`run_cancellable`]: the cold branch
/// executes the exact legacy code, so cold runs stay bit-identical to
/// pre-warm-start builds (pinned by the seed-pinned regression tests).
///
/// With `warm = Some(w)` the random initial design is skipped: the
/// dataset is seeded from `w.state.dataset`, the surrogate imports
/// `w.state.surrogate`, and the donor run's best point (if present) is
/// re-evaluated once on the *current* oracle to anchor the trace — the
/// stale donor costs stay in the dataset as surrogate training data but
/// never enter this run's trace or best curve, so a drifted instance
/// reports only costs measured against itself.  Evaluation budget:
/// `(1 if prev_best) + cfg.iters` instead of `cfg.n_init + cfg.iters`.
///
/// An incompatible state (wrong `n_bits`, wrong surrogate kind,
/// malformed payload) fails typed with [`RunError::Warm`] — never a
/// silent cold start.
///
/// RNG discipline: the surrogate is built *before* the warm import with
/// the same stream the cold path uses (the FM draws its init normals
/// either way), so warm and cold runs consume the seed stream at
/// identical positions up to the acquisition loop.
#[allow(clippy::too_many_arguments)]
pub fn run_warm(
    oracle: &dyn Oracle,
    algo: &Algorithm,
    solver: &dyn IsingSolver,
    cfg: &BboConfig,
    backends: &Backends,
    seed: u64,
    cancel: &CancelToken,
    warm: Option<&WarmStart>,
    export_state: bool,
) -> Result<WarmRun, RunError> {
    let total_timer = Timer::start();
    let mut rng = Rng::new(seed);
    let n = oracle.n_bits();
    let mut data = Dataset::new(n);
    let mut surrogate = build_surrogate(algo, n, backends, &mut rng);
    let mut trace = Trace::new();
    let (mut t_sur, mut t_sol, mut t_eval) = (0.0, 0.0, 0.0);
    let mut pairs: Vec<(Vec<i8>, f64)> = Vec::new();
    let mut degradation = Degradation::default();

    if let Some(w) = warm {
        // Warm start: validate, seed, re-anchor.  No random init design.
        if w.state.n_bits != n {
            return Err(RunError::Warm(StateError::BitsMismatch {
                expected: n,
                found: w.state.n_bits,
            }));
        }
        data = w.state.dataset.clone();
        if let (Some(sur), Some(params)) =
            (surrogate.as_mut(), w.state.surrogate.as_ref())
        {
            // RS carries no surrogate: a state payload is simply unused
            // there (the dataset and prev_best still seed the run).
            sur.import_state(params).map_err(RunError::Warm)?;
        }
        if let Some((x, _stale_y)) = &w.prev_best {
            if x.len() != n {
                return Err(RunError::Warm(StateError::Malformed {
                    field: "prev_best.x",
                    detail: format!(
                        "expected {n} spins, found {}",
                        x.len()
                    ),
                }));
            }
            if let Some(cause) = cancel.cause() {
                return Err(cause.into());
            }
            let t = Timer::start();
            let y = oracle.eval(x);
            t_eval += t.seconds();
            if y.is_finite() {
                expand_pairs(oracle, cfg.augment, x, y, &mut pairs);
            } else {
                degradation.rejected_costs += 1;
            }
            data.push_batch(pairs.drain(..));
            trace.note(x.clone(), y);
        }
    } else {
        // Initial design.  Non-finite costs are quarantined: noted in
        // the trace (the evaluation budget was spent) but never pushed
        // into the dataset's Gram moments.
        for _ in 0..cfg.n_init {
            if let Some(cause) = cancel.cause() {
                return Err(cause.into());
            }
            let x = rng.spins(n);
            let t = Timer::start();
            let y = oracle.eval(&x);
            t_eval += t.seconds();
            if y.is_finite() {
                expand_pairs(oracle, cfg.augment, &x, y, &mut pairs);
            } else {
                degradation.rejected_costs += 1;
            }
            data.push_batch(pairs.drain(..));
            trace.note(x, y);
        }
    }

    // ε-greedy exploration rate (rFMQA only).
    let eps = match algo {
        Algorithm::Rfmqa { eps, .. } => *eps,
        _ => 0.0,
    };

    // Acquisition loop: `cfg.iters` evaluations total, acquired
    // `batch_size` at a time.
    let batch = cfg.batch_size.max(1);
    let mut acquired = 0;
    while acquired < cfg.iters {
        if let Some(cause) = cancel.cause() {
            return Err(cause.into());
        }
        if batch == 1 {
            // Serial path — bit-for-bit the legacy stream.
            let x = match surrogate.as_mut() {
                None => rng.spins(n), // RS
                Some(sur) => {
                    let t = Timer::start();
                    let fit = sur.fit_model(&data, &mut rng);
                    t_sur += t.seconds();
                    match fit {
                        Err(_) => {
                            // Degraded acquisition: the surrogate could
                            // not be fit, so this iteration's candidate
                            // comes off the main stream — exactly one
                            // rng.spins(n), consumed after the fit's own
                            // RNG use.  Fault-free runs never take this
                            // branch, so their stream is untouched.
                            degradation.surrogate_failures += 1;
                            degradation.fallback_proposals += 1;
                            rng.spins(n)
                        }
                        Ok(model) => {
                            let t = Timer::start();
                            let (x, _) = if cfg.restart_workers > 1 {
                                crate::solvers::solve_best_parallel(
                                    solver,
                                    &model,
                                    &mut rng,
                                    cfg.restarts,
                                    cfg.restart_workers,
                                )
                            } else {
                                solver.solve_best(
                                    &model,
                                    &mut rng,
                                    cfg.restarts,
                                )
                            };
                            t_sol += t.seconds();
                            if eps > 0.0 && rng.f64() < eps {
                                // randomised-FMQA exploration step
                                rng.spins(n)
                            } else {
                                x
                            }
                        }
                    }
                }
            };
            let t = Timer::start();
            let y = oracle.eval(&x);
            t_eval += t.seconds();
            if y.is_finite() {
                expand_pairs(oracle, cfg.augment, &x, y, &mut pairs);
            } else {
                degradation.rejected_costs += 1;
            }
            data.push_batch(pairs.drain(..));
            trace.note(x, y);
            acquired += 1;
            continue;
        }

        // Batched path: one fit, k candidates, concurrent evaluation,
        // one dataset update.  The tail batch shrinks so the total
        // evaluation budget is exactly `cfg.iters`.
        let k_step = batch.min(cfg.iters - acquired);
        let xs_batch: Vec<Vec<i8>> = match surrogate.as_mut() {
            // RS acquires candidates independently of the data, so a
            // "batch" is simply the next k draws of the same stream.
            None => (0..k_step).map(|_| rng.spins(n)).collect(),
            Some(sur) => {
                let t = Timer::start();
                let fit = sur.fit_model(&data, &mut rng);
                t_sur += t.seconds();
                match fit {
                    Err(_) => {
                        // Degraded batched acquisition: the whole batch
                        // comes off the main stream, one rng.spins(n)
                        // per candidate in slot order (same order the
                        // pad/ε-greedy paths use).
                        degradation.surrogate_failures += 1;
                        degradation.fallback_proposals += k_step as u64;
                        (0..k_step).map(|_| rng.spins(n)).collect()
                    }
                    Ok(model) => {
                        let t = Timer::start();
                        let cands = crate::solvers::solve_batch(
                            solver,
                            &model,
                            &mut rng,
                            cfg.restarts,
                            k_step,
                            cfg.restart_workers,
                        );
                        t_sol += t.seconds();
                        let mut xs: Vec<Vec<i8>> =
                            cands.into_iter().map(|(x, _)| x).collect();
                        // Fewer distinct restart minima than the batch
                        // asks for: pad with random exploration
                        // candidates so the evaluation budget is spent
                        // either way.
                        while xs.len() < k_step {
                            xs.push(rng.spins(n));
                        }
                        if eps > 0.0 {
                            // Per-slot ε-greedy replacement, decided on
                            // the main stream in candidate order
                            // (deterministic for any worker count).
                            for x in xs.iter_mut() {
                                if rng.f64() < eps {
                                    *x = rng.spins(n);
                                }
                            }
                        }
                        xs
                    }
                }
            }
        };
        // Evaluate the whole batch concurrently through the oracle's
        // batched entry point (scratch-reusing `cost_batch` for native
        // problems, a pool fan-out of `eval` otherwise).  Results come
        // back in candidate order, so recording below is deterministic
        // regardless of the evaluation interleaving.
        let t = Timer::start();
        let ys_batch: Vec<f64> = oracle.eval_batch(&xs_batch, k_step);
        t_eval += t.seconds();
        for (x, &y) in xs_batch.iter().zip(&ys_batch) {
            if y.is_finite() {
                expand_pairs(oracle, cfg.augment, x, y, &mut pairs);
            } else {
                degradation.rejected_costs += 1;
            }
        }
        // One surrogate-dataset update for the whole batch.
        data.push_batch(pairs.drain(..));
        for (x, y) in xs_batch.into_iter().zip(ys_batch) {
            trace.note(x, y);
        }
        acquired += k_step;
    }

    // Every evaluation quarantined: there is no finite decomposition to
    // report, so the run fails with the typed taxonomy error.
    if !trace.best_y.is_finite() {
        return Err(RunError::Numeric(NumericError::NonFiniteCost {
            rejected: degradation.rejected_costs as usize,
        }));
    }

    // Export the end-of-run state when asked (the dataset clone is the
    // only cost; cold callers pass `false` and pay nothing).
    let state = if export_state {
        Some(SurrogateState {
            n_bits: n,
            dataset: data.clone(),
            surrogate: surrogate.as_ref().map(|s| s.export_state()),
        })
    } else {
        None
    };

    Ok(WarmRun {
        run: BboRun {
            algo: algo.label() + if cfg.augment { "a" } else { "" },
            solver: solver.name().into(),
            xs: trace.xs,
            ys: trace.ys,
            best_curve: trace.best_curve,
            best_x: trace.best_x,
            best_y: trace.best_y,
            time_total: total_timer.seconds(),
            time_surrogate: t_sur,
            time_solver: t_sol,
            time_eval: t_eval,
            degradation,
        },
        state,
        warm: warm.is_some(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::{generate, InstanceConfig};
    use crate::solvers::sa::SimulatedAnnealing;

    fn tiny_problem() -> crate::cost::Problem {
        let cfg =
            InstanceConfig { n: 4, d: 10, k: 2, gamma: 0.8, seed: 77 };
        generate(&cfg, 0)
    }

    #[test]
    fn best_curve_is_monotone_nonincreasing() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 30);
        let run = run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            1,
        );
        assert_eq!(run.best_curve.len(), cfg.n_init + cfg.iters);
        for w in run.best_curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!((run.best_curve.last().unwrap() - run.best_y).abs() < 1e-12);
    }

    #[test]
    fn completed_cancellable_run_is_bit_identical_to_plain_run() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 12);
        let algo = Algorithm::Nbocs { sigma2: 0.1 };
        let plain = run(&p, &algo, &sa, &cfg, &Backends::default(), 4);
        let tok = CancelToken::never();
        let cancellable = run_cancellable(
            &p,
            &algo,
            &sa,
            &cfg,
            &Backends::default(),
            4,
            &tok,
        )
        .unwrap();
        assert_eq!(plain.xs, cancellable.xs);
        assert_eq!(plain.ys, cancellable.ys);
        assert_eq!(plain.best_x, cancellable.best_x);
        assert_eq!(plain.best_y, cancellable.best_y);
    }

    #[test]
    fn pre_cancelled_token_aborts_before_any_evaluation() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 12);
        let tok = CancelToken::never();
        tok.cancel();
        let out = run_cancellable(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            4,
            &tok,
        );
        assert_eq!(
            out.unwrap_err(),
            RunError::Cancelled(CancelCause::Cancelled)
        );
    }

    #[test]
    fn expired_deadline_aborts_with_deadline_cause() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 12);
        let tok =
            CancelToken::with_deadline(std::time::Duration::from_millis(0));
        let out = run_cancellable(
            &p,
            &Algorithm::Rs,
            &sa,
            &cfg,
            &Backends::default(),
            4,
            &tok,
        );
        assert_eq!(
            out.unwrap_err(),
            RunError::Cancelled(CancelCause::DeadlineExceeded)
        );
    }

    #[test]
    fn nbocs_beats_random_search_on_tiny_problem() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 20, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 60);
        let mut n_wins = 0;
        for seed in 0..3 {
            let rb = run(&p, &Algorithm::Rs, &sa, &cfg,
                         &Backends::default(), seed);
            let nb = run(
                &p,
                &Algorithm::Nbocs { sigma2: 0.1 },
                &sa,
                &cfg,
                &Backends::default(),
                seed,
            );
            if nb.best_y <= rb.best_y + 1e-12 {
                n_wins += 1;
            }
        }
        assert!(n_wins >= 2, "nBOCS won only {n_wins}/3 vs RS");
    }

    #[test]
    fn bbo_finds_exact_solution_on_tiny_problem() {
        let p = tiny_problem();
        let exact = crate::bruteforce::brute_force(&p);
        let sa = SimulatedAnnealing { sweeps: 30, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 2 * 8 * 8);
        let r = run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            5,
        );
        assert!(
            r.found_exact(exact.best_cost, 1e-9),
            "best {} vs exact {}",
            r.best_y,
            exact.best_cost
        );
    }

    #[test]
    fn augmentation_multiplies_dataset_not_evaluations() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 10);
        cfg.augment = true;
        let r = run(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            2,
        );
        // Evaluations (x-axis) unchanged by augmentation.
        assert_eq!(r.xs.len(), cfg.n_init + cfg.iters);
        assert!(r.algo.ends_with('a'));
    }

    #[test]
    fn all_algorithms_run() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 5);
        for name in ["rs", "vbocs", "nbocs", "gbocs", "fmqa08", "fmqa12"] {
            let algo = Algorithm::by_name(name).unwrap();
            let r =
                run(&p, &algo, &sa, &cfg, &Backends::default(), 3);
            assert_eq!(r.ys.len(), cfg.n_init + cfg.iters, "{name}");
            assert!(r.best_y.is_finite(), "{name}");
        }
    }

    #[test]
    fn restart_fanout_is_worker_count_invariant() {
        // restart_workers > 1 uses forked per-restart streams, so the
        // whole run is bit-identical for any worker count > 1.
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 12);
        cfg.restart_workers = 2;
        let a = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 11);
        cfg.restart_workers = 6;
        let b = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 11);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.best_x, b.best_x);
        assert_eq!(a.best_y, b.best_y);
    }

    #[test]
    fn batched_run_spends_exact_eval_budget() {
        // Whatever the batch size (dividing iters or not), the total
        // evaluation budget and the monotone best-curve are unchanged.
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        for batch in [2usize, 3, 4, 7] {
            let mut cfg = BboConfig::smoke_scale(p.n_bits(), 10);
            cfg.batch_size = batch;
            let r = run(
                &p,
                &Algorithm::Nbocs { sigma2: 0.1 },
                &sa,
                &cfg,
                &Backends::default(),
                4,
            );
            assert_eq!(r.ys.len(), cfg.n_init + cfg.iters, "batch {batch}");
            assert_eq!(r.best_curve.len(), r.ys.len());
            for w in r.best_curve.windows(2) {
                assert!(w[1] <= w[0] + 1e-12);
            }
        }
    }

    #[test]
    fn batched_run_is_worker_count_invariant() {
        // Batched acquisition uses forked per-restart streams and
        // order-preserving concurrent evaluation, so ANY worker count
        // (1 included) gives the identical run.
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 12);
        cfg.batch_size = 4;
        cfg.restart_workers = 2;
        let a = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 8);
        cfg.restart_workers = 6;
        let b = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 8);
        cfg.restart_workers = 1;
        let c = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 8);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.ys, c.ys);
        assert_eq!(a.best_x, b.best_x);
        assert_eq!(a.best_x, c.best_x);
        assert_eq!(a.best_y, b.best_y);
    }

    #[test]
    fn rs_batched_matches_rs_serial_bit_for_bit() {
        // RS draws candidates straight off the main stream, so the
        // batched path must reproduce the serial path exactly — a
        // cross-path determinism check of the whole batching plumbing.
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 9);
        let serial = run(&p, &Algorithm::Rs, &sa, &cfg,
                         &Backends::default(), 3);
        let mut bcfg = cfg.clone();
        bcfg.batch_size = 4; // 9 = 4 + 4 + 1: exercises the tail batch
        let batched = run(&p, &Algorithm::Rs, &sa, &bcfg,
                          &Backends::default(), 3);
        assert_eq!(serial.xs, batched.xs);
        assert_eq!(serial.ys, batched.ys);
        assert_eq!(serial.best_curve, batched.best_curve);
        assert_eq!(serial.best_x, batched.best_x);
    }

    #[test]
    fn batch_size_one_is_the_legacy_serial_stream() {
        // The constructors default to batch_size = 1, and setting it
        // explicitly must change nothing: the k = 1 path IS the legacy
        // serial loop (same branch, same RNG stream).  The seed-pinned
        // tests above (exact-hit, beats-RS) guard the stream itself.
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 15);
        assert_eq!(cfg.batch_size, 1);
        assert_eq!(BboConfig::paper_scale(8).batch_size, 1);
        let a = run(&p, &Algorithm::Gbocs { beta: 0.001 }, &sa, &cfg,
                    &Backends::default(), 9);
        let mut explicit = cfg.clone();
        explicit.batch_size = 1;
        let b = run(&p, &Algorithm::Gbocs { beta: 0.001 }, &sa, &explicit,
                    &Backends::default(), 9);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.best_x, b.best_x);
    }

    #[test]
    fn all_algorithms_run_batched() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 6);
        cfg.batch_size = 3;
        for name in
            ["rs", "vbocs", "nbocs", "gbocs", "fmqa08", "rfmqa08"]
        {
            let algo = Algorithm::by_name(name).unwrap();
            let r = run(&p, &algo, &sa, &cfg, &Backends::default(), 3);
            assert_eq!(r.ys.len(), cfg.n_init + cfg.iters, "{name}");
            assert!(r.best_y.is_finite(), "{name}");
        }
    }

    #[test]
    fn batched_augmentation_multiplies_dataset_not_evaluations() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let mut cfg = BboConfig::smoke_scale(p.n_bits(), 8);
        cfg.augment = true;
        cfg.batch_size = 4;
        let r = run(&p, &Algorithm::Nbocs { sigma2: 0.1 }, &sa, &cfg,
                    &Backends::default(), 2);
        assert_eq!(r.xs.len(), cfg.n_init + cfg.iters);
        assert!(r.algo.ends_with('a'));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 15);
        let a = run(&p, &Algorithm::Gbocs { beta: 0.001 }, &sa, &cfg,
                    &Backends::default(), 9);
        let b = run(&p, &Algorithm::Gbocs { beta: 0.001 }, &sa, &cfg,
                    &Backends::default(), 9);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.best_x, b.best_x);
    }

    // ---- warm start (ISSUE 10) -------------------------------------

    /// The base problem with a tiny gaussian drift on W (same shape,
    /// argmin preserved at this scale — the re-deployed fine-tuned
    /// model scenario).
    fn drifted_problem(
        base: &crate::cost::Problem,
        scale: f64,
        seed: u64,
    ) -> crate::cost::Problem {
        let mut w = base.w.clone();
        let mut rng = Rng::new(seed);
        for v in w.data.iter_mut() {
            *v += scale * rng.normal();
        }
        crate::cost::Problem::new(w, 2) // tiny_problem uses k = 2
    }

    /// A long cold run on `p` that exports its state — the donor every
    /// warm test seeds from.  seed 5 / 2·8·8 iters / 30 sweeps is the
    /// exact-hit configuration pinned by
    /// `bbo_finds_exact_solution_on_tiny_problem`.
    fn donor_run(p: &crate::cost::Problem) -> WarmRun {
        let sa = SimulatedAnnealing { sweeps: 30, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 2 * 8 * 8);
        run_warm(
            p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            5,
            &CancelToken::never(),
            None,
            true,
        )
        .unwrap()
    }

    #[test]
    fn run_warm_without_warm_start_is_the_cold_path_bit_for_bit() {
        // warm = None must execute the exact legacy code: same RNG
        // stream, same trace — the cold bit-identity contract.
        let p = tiny_problem();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 12);
        let algo = Algorithm::Nbocs { sigma2: 0.1 };
        let cold = run(&p, &algo, &sa, &cfg, &Backends::default(), 4);
        let via_warm = run_warm(
            &p,
            &algo,
            &sa,
            &cfg,
            &Backends::default(),
            4,
            &CancelToken::never(),
            None,
            false,
        )
        .unwrap();
        assert!(!via_warm.warm);
        assert!(via_warm.state.is_none());
        assert_eq!(cold.xs, via_warm.run.xs);
        for (a, b) in cold.ys.iter().zip(&via_warm.run.ys) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(cold.best_x, via_warm.run.best_x);
        assert_eq!(cold.best_y.to_bits(), via_warm.run.best_y.to_bits());
    }

    #[test]
    fn warm_start_on_unperturbed_instance_reproduces_cold_best() {
        let p = tiny_problem();
        let donor = donor_run(&p);
        let warm_input =
            WarmStart::new(donor.state.clone().unwrap()).with_prev_best(
                donor.run.best_x.clone(),
                donor.run.best_y,
            );
        let sa = SimulatedAnnealing { sweeps: 30, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 4);
        let warm = run_warm(
            &p,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            6,
            &CancelToken::never(),
            Some(&warm_input),
            false,
        )
        .unwrap();
        assert!(warm.warm);
        // The first trace entry is the donor best re-evaluated on the
        // same oracle: bit-identical cost, so the cold best cost is
        // reproduced immediately and never lost.
        assert_eq!(warm.run.ys[0].to_bits(), donor.run.best_y.to_bits());
        assert!(warm.run.best_y <= donor.run.best_y);
        // Budget: one anchor evaluation + iters, no random init design.
        assert_eq!(warm.run.ys.len(), 1 + cfg.iters);
    }

    #[test]
    fn warm_start_reaches_cold_best_in_half_the_evals_under_drift() {
        // The acceptance scenario: re-compress a slightly drifted
        // instance.  The warm run gets ≤ half the cold run's evaluation
        // budget and must still match (or beat) the cold best cost.
        let p = tiny_problem();
        let donor = donor_run(&p);
        let drifted = drifted_problem(&p, 1e-9, 909);
        let sa = SimulatedAnnealing { sweeps: 30, ..Default::default() };
        // Cold reference on the drifted instance: full budget.
        let cold_cfg = BboConfig::smoke_scale(drifted.n_bits(), 8);
        let cold = run(
            &drifted,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cold_cfg,
            &Backends::default(),
            6,
        );
        // Warm run: half the evaluations (1 anchor + 7 acquisitions =
        // 8, vs the cold 8 init + 8 acquisitions = 16).
        let warm_input =
            WarmStart::new(donor.state.clone().unwrap()).with_prev_best(
                donor.run.best_x.clone(),
                donor.run.best_y,
            );
        let warm_cfg = BboConfig::smoke_scale(drifted.n_bits(), 7);
        let warm = run_warm(
            &drifted,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &warm_cfg,
            &Backends::default(),
            6,
            &CancelToken::never(),
            Some(&warm_input),
            false,
        )
        .unwrap();
        assert!(warm.run.ys.len() * 2 <= cold.ys.len());
        assert!(
            warm.run.best_y <= cold.best_y + 1e-12,
            "warm best {} did not reach cold best {}",
            warm.run.best_y,
            cold.best_y
        );
    }

    #[test]
    fn warm_start_survives_a_serialisation_roundtrip() {
        // Seeding from a parsed text document gives the bit-identical
        // run to seeding from the in-memory state.
        let p = tiny_problem();
        let donor = donor_run(&p);
        let warm_input =
            WarmStart::new(donor.state.clone().unwrap()).with_prev_best(
                donor.run.best_x.clone(),
                donor.run.best_y,
            );
        let text = warm_input.to_string_strict().unwrap();
        let reparsed = WarmStart::parse(&text).unwrap();
        let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 5);
        let algo = Algorithm::Nbocs { sigma2: 0.1 };
        let from_memory = run_warm(
            &p, &algo, &sa, &cfg, &Backends::default(), 3,
            &CancelToken::never(), Some(&warm_input), false,
        )
        .unwrap();
        let from_text = run_warm(
            &p, &algo, &sa, &cfg, &Backends::default(), 3,
            &CancelToken::never(), Some(&reparsed), false,
        )
        .unwrap();
        for (a, b) in from_memory.run.ys.iter().zip(&from_text.run.ys) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(from_memory.run.best_x, from_text.run.best_x);
    }

    #[test]
    fn warm_start_kind_mismatch_is_a_typed_error() {
        let p = tiny_problem();
        let donor = donor_run(&p); // nBOCS state
        let warm_input = WarmStart::new(donor.state.clone().unwrap());
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let cfg = BboConfig::smoke_scale(p.n_bits(), 3);
        let out = run_warm(
            &p,
            &Algorithm::Fmqa { k_fm: 8 },
            &sa,
            &cfg,
            &Backends::default(),
            3,
            &CancelToken::never(),
            Some(&warm_input),
            false,
        );
        assert!(matches!(
            out,
            Err(RunError::Warm(StateError::KindMismatch { .. }))
        ));
    }

    #[test]
    fn warm_start_bits_mismatch_is_a_typed_error() {
        let p = tiny_problem(); // n_bits = 8
        let donor = donor_run(&p);
        let warm_input = WarmStart::new(donor.state.clone().unwrap());
        let other = generate(
            &InstanceConfig { n: 3, d: 6, k: 2, gamma: 0.8, seed: 1 },
            0,
        ); // n_bits = 6
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let cfg = BboConfig::smoke_scale(other.n_bits(), 3);
        let out = run_warm(
            &other,
            &Algorithm::Nbocs { sigma2: 0.1 },
            &sa,
            &cfg,
            &Backends::default(),
            3,
            &CancelToken::never(),
            Some(&warm_input),
            false,
        );
        assert!(matches!(
            out,
            Err(RunError::Warm(StateError::BitsMismatch {
                expected: 6,
                found: 8
            }))
        ));
    }

    #[test]
    fn algorithm_state_kinds_match_surrogate_exports() {
        // The serve warm store's compatibility pre-check relies on
        // Algorithm::state_kind agreeing with what each surrogate
        // actually exports.
        let mut rng = Rng::new(99);
        for (algo, n) in [
            (Algorithm::Vbocs, 4usize),
            (Algorithm::Nbocs { sigma2: 0.1 }, 4),
            (Algorithm::Gbocs { beta: 0.001 }, 4),
            (Algorithm::Fmqa { k_fm: 8 }, 4),
            (Algorithm::Rfmqa { k_fm: 12, eps: 0.1 }, 4),
        ] {
            let sur =
                build_surrogate(&algo, n, &Backends::default(), &mut rng)
                    .unwrap();
            assert_eq!(
                Some(sur.export_state().kind),
                algo.state_kind(),
                "{algo:?}"
            );
        }
        assert_eq!(Algorithm::Rs.state_kind(), None);
    }
}
