//! Experiment harness: one module per paper figure/table (DESIGN.md §4).
//!
//! Every experiment prints the rows/series the paper reports (ASCII table
//! or terminal plot) and writes CSV into `cfg.out_dir` for offline
//! plotting.  Default scale is a smoke run that finishes in minutes on one
//! core; `--full` switches to the paper protocol (25 runs × 1176
//! evaluations × 10 instances; 100 runs for RS).

pub mod ablation;
pub mod convergence;
pub mod counts;
pub mod domains;
pub mod hyper;
pub mod solutions;
pub mod timing;

use std::sync::Arc;

use crate::bbo::{self, Algorithm, Backends, BboConfig, BboRun};
use crate::bruteforce::{brute_force, BruteForceResult};
use crate::config::ExpConfig;
use crate::cost::Problem;
use crate::engine::{CachedOracle, CostCache};
use crate::instance::generate_suite;
use crate::minlp::Oracle;
use crate::runtime::{XlaCostOracle, XlaRuntime};
use crate::solvers;
use crate::util::threadpool::parallel_map;

/// One (algorithm, solver, augmentation) combination with its paper label.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// BBO algorithm of the run.
    pub algo: Algorithm,
    /// Ising solver name: "sa", "sqa" (the QA stand-in), "sq".
    pub solver: String,
    /// Whether to add the symmetry orbit of each evaluation (nBOCSa).
    pub augment: bool,
}

impl RunSpec {
    /// Spec with the SA solver and no augmentation.
    pub fn new(algo: Algorithm) -> Self {
        RunSpec { algo, solver: "sa".into(), augment: false }
    }

    /// Swap the Ising solver (builder style).
    pub fn with_solver(mut self, solver: &str) -> Self {
        self.solver = solver.into();
        self
    }

    /// Enable data augmentation (builder style).
    pub fn augmented(mut self) -> Self {
        self.augment = true;
        self
    }

    /// Paper label, e.g. nBOCS / nBOCSqa / nBOCSsq / nBOCSa.
    pub fn label(&self) -> String {
        let mut l = self.algo.label();
        match self.solver.as_str() {
            "sa" => {}
            "sqa" => l.push_str("qa"),
            other => l.push_str(other),
        }
        if self.augment {
            l.push('a');
        }
        l
    }

    /// The paper's six core algorithms (Fig. 1 / Fig. 7).
    pub fn core_six() -> Vec<RunSpec> {
        vec![
            RunSpec::new(Algorithm::Rs),
            RunSpec::new(Algorithm::Vbocs),
            RunSpec::new(Algorithm::Nbocs { sigma2: 0.1 }),
            RunSpec::new(Algorithm::Gbocs { beta: 0.001 }),
            RunSpec::new(Algorithm::Fmqa { k_fm: 8 }),
            RunSpec::new(Algorithm::Fmqa { k_fm: 12 }),
        ]
    }

    /// The paper's full nine columns (Table 1 / Table 2).
    pub fn table_nine() -> Vec<RunSpec> {
        let mut v = Self::core_six();
        v.push(
            RunSpec::new(Algorithm::Nbocs { sigma2: 0.1 })
                .with_solver("sqa"),
        );
        v.push(
            RunSpec::new(Algorithm::Nbocs { sigma2: 0.1 })
                .with_solver("sq"),
        );
        v.push(RunSpec::new(Algorithm::Nbocs { sigma2: 0.1 }).augmented());
        v
    }
}

/// Shared experiment state: instances, cached exact solutions, runtime.
pub struct Ctx {
    /// The run's configuration (scale, budgets, seeds, output dir).
    pub cfg: ExpConfig,
    /// The synthetic instance suite.
    pub problems: Vec<Problem>,
    /// Exact (brute-forced) solution of each instance.
    pub exact: Vec<BruteForceResult>,
    /// PJRT artifact runtime when loaded (None = native math).
    pub rt: Option<Arc<XlaRuntime>>,
}

impl Ctx {
    /// Generate the instance suite, brute-force the exact solutions and
    /// (optionally) load the PJRT artifacts.
    pub fn new(cfg: ExpConfig) -> Ctx {
        let problems = generate_suite(&cfg.instance, cfg.instances);
        eprintln!(
            "[ctx] {} instances ({}x{}, K={}), brute-forcing exact solutions...",
            problems.len(),
            cfg.instance.n,
            cfg.instance.d,
            cfg.instance.k
        );
        let exact: Vec<BruteForceResult> = problems
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let r = brute_force(p);
                eprintln!(
                    "[ctx] instance {}: exact residual {:.3}, orbit {}",
                    i + 1,
                    p.normalised_error(r.best_cost),
                    r.orbit.len()
                );
                r
            })
            .collect();
        let rt = if cfg.use_xla {
            let rt = XlaRuntime::load_default().map(Arc::new);
            match &rt {
                Some(r) => eprintln!(
                    "[ctx] PJRT artifacts loaded from {} ({})",
                    r.dir.display(),
                    r.platform()
                ),
                None => eprintln!(
                    "[ctx] no artifacts found — native cost path"
                ),
            }
            rt
        } else {
            None
        };
        Ctx { cfg, problems, exact, rt }
    }

    /// Tolerance for "found the exact solution" on instance `inst`
    /// (loose enough for the f32 artifact path, far tighter than the
    /// best→second-best gap).
    pub fn exact_tol(&self, inst: usize) -> f64 {
        let bf = &self.exact[inst];
        1e-7 + 1e-3 * (bf.second_cost - bf.best_cost).max(0.0)
    }

    fn bbo_config(&self) -> BboConfig {
        self.cfg.bbo_config(self.problems[0].n_bits())
    }

    /// Run `runs` independent BBO runs of `spec` on instance `inst`.
    ///
    /// Every run evaluates through a fresh [`CachedOracle`] with
    /// canonical-orbit keys by default (the ROADMAP flip for
    /// orbit-heavy workloads — augmentation and FMQA re-acquisition hit
    /// the same orbit constantly); `--cache-key raw`
    /// ([`ExpConfig::cache_key_raw`]) restores exact keys and with them
    /// bit-identical replay of the uncached legacy runs.
    pub fn run_spec(
        &self,
        spec: &RunSpec,
        inst: usize,
        runs: usize,
    ) -> Vec<BboRun> {
        let problem = &self.problems[inst];
        let mut cfg = self.bbo_config();
        cfg.augment = spec.augment;
        // The XLA cost artifact only fits the shapes it was compiled for.
        let use_xla_cost = self
            .rt
            .as_ref()
            .map(|rt| {
                rt.meta.n == problem.n()
                    && rt.meta.d == problem.d()
                    && rt.meta.k == problem.k
            })
            .unwrap_or(false);
        let seeds: Vec<u64> = (0..runs)
            .map(|r| {
                self.cfg
                    .seed
                    .wrapping_mul(0x9E37_79B9)
                    .wrapping_add((inst as u64) << 32)
                    .wrapping_add(r as u64)
            })
            .collect();
        let spec = spec.clone();
        let rt = self.rt.clone();
        let canonical = !self.cfg.cache_key_raw;
        let (n, k) = (problem.n(), problem.k);
        parallel_map(seeds, self.cfg.workers, move |seed| {
            let solver = solvers::by_name(&spec.solver)
                .unwrap_or_else(|| panic!("unknown solver {}", spec.solver));
            let backends = Backends::default();
            let cache = if canonical {
                CostCache::with_canonical_keys()
            } else {
                CostCache::new()
            };
            if use_xla_cost {
                let oracle = XlaCostOracle {
                    rt: rt.as_ref().unwrap().clone(),
                    problem: problem.clone(),
                };
                let cached = CachedOracle::new(&oracle, &cache, n, k);
                bbo::run(&cached, &spec.algo, solver.as_ref(), &cfg,
                         &backends, seed)
            } else {
                let cached = CachedOracle::new(problem, &cache, n, k);
                bbo::run(&cached, &spec.algo, solver.as_ref(), &cfg,
                         &backends, seed)
            }
        })
    }

    /// Residual-error curve (paper's y-axis) of one run on an instance:
    /// `(sqrt(best_so_far) - sqrt(exact)) / ||W||` per evaluation step.
    pub fn residual_curve(&self, inst: usize, run: &BboRun) -> Vec<f64> {
        let p = &self.problems[inst];
        let best = self.exact[inst].best_cost;
        run.best_curve
            .iter()
            .map(|&c| p.residual_error(c, best))
            .collect()
    }

    /// Mean ± 95% CI across runs at each step.
    pub fn mean_ci(curves: &[Vec<f64>]) -> (Vec<f64>, Vec<f64>) {
        let len = curves.iter().map(Vec::len).min().unwrap_or(0);
        let mut mean = Vec::with_capacity(len);
        let mut ci = Vec::with_capacity(len);
        for t in 0..len {
            let vals: Vec<f64> = curves.iter().map(|c| c[t]).collect();
            mean.push(crate::util::mean(&vals));
            ci.push(crate::util::ci95(&vals));
        }
        (mean, ci)
    }
}

/// Count how many of the runs hit the exact optimum of the instance.
pub fn count_exact_hits(ctx: &Ctx, inst: usize, runs: &[BboRun]) -> usize {
    let best = ctx.exact[inst].best_cost;
    let tol = ctx.exact_tol(inst);
    runs.iter().filter(|r| r.found_exact(best, tol)).count()
}

/// The greedy baseline's residual error on an instance (red dotted line).
/// Uses the series cost — the original algorithm's actual output
/// `(M, [c_1..c_K])`, not the refit C — matching the paper's "original
/// approximated solution" line.
pub fn greedy_residual(ctx: &Ctx, inst: usize) -> f64 {
    let p = &ctx.problems[inst];
    let g = crate::greedy::greedy(p, ctx.cfg.seed);
    p.residual_error(g.cost_series, ctx.exact[inst].best_cost)
}

/// The second-best orbit's residual error (grey dotted line).
pub fn second_best_residual(ctx: &Ctx, inst: usize) -> f64 {
    let p = &ctx.problems[inst];
    let bf = &ctx.exact[inst];
    p.residual_error(bf.second_cost, bf.best_cost)
}

/// Oracle sanity shim used by tests: evaluate through whatever path the
/// ctx would use for BBO.
pub fn eval_like_bbo(ctx: &Ctx, inst: usize, x: &[i8]) -> f64 {
    let p = &ctx.problems[inst];
    match &ctx.rt {
        Some(rt)
            if rt.meta.n == p.n()
                && rt.meta.d == p.d()
                && rt.meta.k == p.k =>
        {
            XlaCostOracle { rt: rt.clone(), problem: p.clone() }.eval(x)
        }
        _ => p.eval(x),
    }
}
