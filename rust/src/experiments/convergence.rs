//! Convergence experiments: Figs. 1, 2, 3 and 7 (residual error vs
//! iteration step, 95% CI, greedy + second-best reference lines).

use super::{Ctx, RunSpec};
use crate::bbo::Algorithm;
use crate::report::{ascii_plot_log, fmt, write_csv};

/// Run a set of specs on one instance; returns (label, mean, ci) series.
pub fn run_series(
    ctx: &Ctx,
    specs: &[RunSpec],
    inst: usize,
) -> Vec<(String, Vec<f64>, Vec<f64>)> {
    specs
        .iter()
        .map(|spec| {
            let runs = if spec.algo == Algorithm::Rs {
                ctx.cfg.rs_runs
            } else {
                ctx.cfg.runs
            };
            eprintln!(
                "[convergence] instance {} {} x{} runs...",
                inst + 1,
                spec.label(),
                runs
            );
            let results = ctx.run_spec(spec, inst, runs);
            let curves: Vec<Vec<f64>> = results
                .iter()
                .map(|r| ctx.residual_curve(inst, r))
                .collect();
            let (mean, ci) = Ctx::mean_ci(&curves);
            (spec.label(), mean, ci)
        })
        .collect()
}

/// Emit one convergence figure: CSV + terminal plot with reference lines.
pub fn emit_figure(
    ctx: &Ctx,
    name: &str,
    inst: usize,
    series: &[(String, Vec<f64>, Vec<f64>)],
) {
    let greedy = super::greedy_residual(ctx, inst);
    let second = super::second_best_residual(ctx, inst);

    // CSV: step, <algo>_mean, <algo>_ci95, ...
    let len = series.iter().map(|(_, m, _)| m.len()).min().unwrap_or(0);
    let mut header: Vec<String> = vec!["step".into()];
    for (label, _, _) in series {
        header.push(format!("{label}_mean"));
        header.push(format!("{label}_ci95"));
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut rows = Vec::with_capacity(len);
    for t in 0..len {
        let mut row = vec![t.to_string()];
        for (_, mean, ci) in series {
            row.push(fmt(mean[t]));
            row.push(fmt(ci[t]));
        }
        rows.push(row);
    }
    let path = format!("{}/{}.csv", ctx.cfg.out_dir, name);
    write_csv(&path, &header_refs, &rows).expect("write csv");

    // Terminal plot (+ constant reference lines).
    let mut plot_series: Vec<(String, Vec<f64>)> = series
        .iter()
        .map(|(l, m, _)| (l.clone(), m.clone()))
        .collect();
    plot_series.push(("greedy (original)".into(), vec![greedy; len]));
    plot_series.push(("second-best".into(), vec![second; len]));
    println!(
        "== {name} (instance {}) — residual error vs iteration ==",
        inst + 1
    );
    println!("{}", ascii_plot_log(&plot_series, 72, 20));
    println!("greedy residual     : {}", fmt(greedy));
    println!("second-best residual: {}", fmt(second));
    for (label, mean, _) in series {
        println!(
            "{label:<10} final mean residual: {}",
            fmt(*mean.last().unwrap_or(&f64::NAN))
        );
    }
    println!("csv: {path}\n");
}

/// Fig. 1: six core algorithms on instance 1 (SA back-end).
pub fn fig1(ctx: &Ctx) {
    let series = run_series(ctx, &RunSpec::core_six(), 0);
    emit_figure(ctx, "fig1", 0, &series);
}

/// Fig. 2: nBOCS under SA vs QA(SQA) vs SQ.
pub fn fig2(ctx: &Ctx) {
    let nbocs = || RunSpec::new(Algorithm::Nbocs { sigma2: 0.1 });
    let specs = vec![
        nbocs(),
        nbocs().with_solver("sqa"),
        nbocs().with_solver("sq"),
    ];
    let series = run_series(ctx, &specs, 0);
    emit_figure(ctx, "fig2", 0, &series);
}

/// Fig. 3: data augmentation on/off for RS and nBOCS.
pub fn fig3(ctx: &Ctx) {
    let specs = vec![
        RunSpec::new(Algorithm::Rs),
        RunSpec::new(Algorithm::Rs).augmented(),
        RunSpec::new(Algorithm::Nbocs { sigma2: 0.1 }),
        RunSpec::new(Algorithm::Nbocs { sigma2: 0.1 }).augmented(),
    ];
    let series = run_series(ctx, &specs, 0);
    emit_figure(ctx, "fig3", 0, &series);
}

/// Fig. 7: the core six on every other instance.
pub fn fig7(ctx: &Ctx) {
    for inst in 1..ctx.problems.len() {
        let series = run_series(ctx, &RunSpec::core_six(), inst);
        emit_figure(ctx, &format!("fig7_instance{}", inst + 1), inst, &series);
    }
}
