//! Table 2: mean execution time per run for every algorithm (instance 1),
//! plus the greedy and brute-force reference rows the paper quotes in the
//! text (0.00096 s and 5553.51 s on their hardware).

use super::{Ctx, RunSpec};
use crate::report::{ascii_table, fmt, write_csv};
use crate::util::timer::Timer;

/// Table 2: wall-clock decomposition per algorithm.
pub fn table2(ctx: &Ctx) {
    let inst = 0;
    let specs = RunSpec::table_nine();
    // Timing wants identical run counts per algorithm.
    let runs = ctx.cfg.runs.max(1);

    let mut rows = Vec::new();
    for spec in &specs {
        eprintln!("[table2] timing {} ({} runs)...", spec.label(), runs);
        let results = ctx.run_spec(spec, inst, runs);
        let total: Vec<f64> =
            results.iter().map(|r| r.time_total).collect();
        let sur: Vec<f64> =
            results.iter().map(|r| r.time_surrogate).collect();
        let sol: Vec<f64> =
            results.iter().map(|r| r.time_solver).collect();
        let ev: Vec<f64> = results.iter().map(|r| r.time_eval).collect();
        rows.push(vec![
            spec.label(),
            fmt(crate::util::mean(&total)),
            fmt(crate::util::mean(&sur)),
            fmt(crate::util::mean(&sol)),
            fmt(crate::util::mean(&ev)),
        ]);
    }

    // Reference rows: the original greedy and the brute-force search.
    let t = Timer::start();
    let _ = crate::greedy::greedy(&ctx.problems[inst], ctx.cfg.seed);
    let greedy_s = t.seconds();
    rows.push(vec![
        "original (greedy)".into(),
        fmt(greedy_s),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    let t = Timer::start();
    let _ = crate::bruteforce::brute_force(&ctx.problems[inst]);
    let bf_s = t.seconds();
    rows.push(vec![
        "brute force (canonical)".into(),
        fmt(bf_s),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);

    let headers =
        ["algorithm", "total s/run", "surrogate s", "solver s", "eval s"];
    println!(
        "== table2 — mean execution time per run ({} evaluations) ==",
        ctx.cfg.iters + ctx.problems[inst].n_bits()
    );
    println!("{}", ascii_table(&headers, &rows));
    let path = format!("{}/table2.csv", ctx.cfg.out_dir);
    write_csv(&path, &headers, &rows).expect("write csv");
    println!("csv: {path}\n");
}
