//! Ablations of the design choices DESIGN.md calls out (not in the paper's
//! figures, but implied by its protocol):
//!
//! * solver restarts per iteration (paper fixes 10);
//! * SA sweep budget;
//! * Gibbs sweeps per nBOCS fit;
//! * vanilla FMQA vs the randomised FMQA the Discussion proposes
//!   (ref. 24) — implemented as ε-greedy acquisition.

use super::{count_exact_hits, Ctx, RunSpec};
use crate::bbo::{self, Algorithm, Backends};
use crate::report::{ascii_table, fmt, write_csv};
use crate::solvers::sa::SimulatedAnnealing;
use crate::util::mean;

fn run_with(
    ctx: &Ctx,
    algo: &Algorithm,
    sa: &SimulatedAnnealing,
    restarts: usize,
    runs: usize,
) -> (f64, usize) {
    let p = &ctx.problems[0];
    // The shared builder path, with the sweep's restart override and
    // the ablation protocol's fixed serial acquisition.
    let cfg = ctx
        .cfg
        .bbo_config(p.n_bits())
        .with_restarts(restarts)
        .with_batch_size(1);
    let results: Vec<_> = (0..runs)
        .map(|r| {
            bbo::run(p, algo, sa, &cfg, &Backends::default(),
                     ctx.cfg.seed.wrapping_add(r as u64))
        })
        .collect();
    let finals: Vec<f64> = results.iter().map(|r| r.best_y).collect();
    let hits = count_exact_hits(ctx, 0, &results);
    (mean(&finals), hits)
}

/// Run every design-choice sweep and print/CSV the results.
pub fn ablation(ctx: &Ctx) {
    let runs = ctx.cfg.runs.max(1);
    let nbocs = Algorithm::Nbocs { sigma2: 0.1 };
    let mut rows = Vec::new();

    println!("== ablation — design-choice sweeps (instance 1, {} runs, {} iters) ==",
             runs, ctx.cfg.iters);

    // 1. Solver restarts (paper: 10).
    for restarts in [1usize, 3, 10, 30] {
        let sa = SimulatedAnnealing::default();
        let (m, hits) = run_with(ctx, &nbocs, &sa, restarts, runs);
        rows.push(vec![
            "restarts".into(),
            restarts.to_string(),
            fmt(m),
            hits.to_string(),
        ]);
        eprintln!("[ablation] restarts={restarts}: mean {m:.6} hits {hits}");
    }

    // 2. SA sweep budget.
    for sweeps in [10usize, 50, 100, 300] {
        let sa = SimulatedAnnealing { sweeps, ..Default::default() };
        let (m, hits) = run_with(ctx, &nbocs, &sa, 10, runs);
        rows.push(vec![
            "sa_sweeps".into(),
            sweeps.to_string(),
            fmt(m),
            hits.to_string(),
        ]);
        eprintln!("[ablation] sweeps={sweeps}: mean {m:.6} hits {hits}");
    }

    // 3. FMQA vs randomised FMQA (the Discussion's future-work item).
    for (label, algo) in [
        ("fmqa08", Algorithm::Fmqa { k_fm: 8 }),
        ("rfmqa08_eps0.1", Algorithm::Rfmqa { k_fm: 8, eps: 0.1 }),
        ("rfmqa08_eps0.3", Algorithm::Rfmqa { k_fm: 8, eps: 0.3 }),
    ] {
        let sa = SimulatedAnnealing::default();
        let (m, hits) = run_with(ctx, &algo, &sa, 10, runs);
        rows.push(vec![
            "fm_variant".into(),
            label.into(),
            fmt(m),
            hits.to_string(),
        ]);
        eprintln!("[ablation] {label}: mean {m:.6} hits {hits}");
    }

    let headers = ["knob", "value", "mean final cost", "exact hits"];
    println!("{}", ascii_table(&headers, &rows));
    let path = format!("{}/ablation.csv", ctx.cfg.out_dir);
    write_csv(&path, &headers, &rows).expect("write csv");
    println!("csv: {path}\n");
}

/// RunSpec helper used by tests.
pub fn rfmqa_spec() -> RunSpec {
    RunSpec::new(Algorithm::Rfmqa { k_fm: 8, eps: 0.1 })
}
