//! Fig. 6: hyperparameter grids — σ² for nBOCS, β for gBOCS, scored by the
//! mean final best cost on instance 1.

use super::{Ctx, RunSpec};
use crate::bbo::Algorithm;
use crate::report::{ascii_table, fmt, write_csv};

/// Fig. 6: hyperparameter grid searches for the tuned algorithms.
pub fn fig6(ctx: &Ctx) {
    let inst = 0;
    let sigma2_grid = [1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0];
    let beta_grid = [1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 100.0];

    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();

    for &s2 in &sigma2_grid {
        let spec = RunSpec::new(Algorithm::Nbocs { sigma2: s2 });
        let runs = ctx.run_spec(&spec, inst, ctx.cfg.runs);
        let finals: Vec<f64> = runs.iter().map(|r| r.best_y).collect();
        let m = crate::util::mean(&finals);
        rows.push(vec!["nBOCS σ²".into(), fmt(s2), fmt(m)]);
        csv_rows.push(vec!["sigma2".into(), fmt(s2), fmt(m)]);
        eprintln!("[fig6] nBOCS sigma2={s2}: mean final cost {m:.6}");
    }
    for &b in &beta_grid {
        let spec = RunSpec::new(Algorithm::Gbocs { beta: b });
        let runs = ctx.run_spec(&spec, inst, ctx.cfg.runs);
        let finals: Vec<f64> = runs.iter().map(|r| r.best_y).collect();
        let m = crate::util::mean(&finals);
        rows.push(vec!["gBOCS β".into(), fmt(b), fmt(m)]);
        csv_rows.push(vec!["beta".into(), fmt(b), fmt(m)]);
        eprintln!("[fig6] gBOCS beta={b}: mean final cost {m:.6}");
    }

    println!("== fig6 — hyperparameter dependence of the final cost ==");
    println!(
        "{}",
        ascii_table(&["hyperparameter", "value", "mean final cost"], &rows)
    );
    let path = format!("{}/fig6.csv", ctx.cfg.out_dir);
    write_csv(&path, &["param", "value", "mean_final_cost"], &csv_rows)
        .expect("write csv");
    println!("csv: {path}\n");
}
