//! Fig. 5: the 48 exact solutions of instance 1 as pixel boxes, plus the
//! Ward dendrogram and the 4-domain cut used by Fig. 4.

use super::Ctx;
use crate::cluster::{cut, ward};
use crate::report::write_csv;

/// Fig. 5: the exact solutions and their symmetry orbits.
pub fn fig5(ctx: &Ctx) {
    let inst = 0;
    let bf = &ctx.exact[inst];
    let pts: Vec<Vec<i8>> =
        bf.orbit.iter().map(|m| m.data.clone()).collect();
    let merges = ward(&pts);
    let labels = cut(&merges, pts.len(), 4.min(pts.len()));

    println!(
        "== fig5 — {} exact solutions of instance 1 (cost {:.6}) ==",
        bf.orbit.len(),
        bf.best_cost
    );
    println!("(each box is M^T, rows = K columns of M; '#' = +1, '.' = -1)\n");

    // Pixel art: boxes laid out 8 per row group.
    let per_row = 8;
    let (n, k) = (bf.orbit[0].n, bf.orbit[0].k);
    for (gi, group) in bf.orbit.chunks(per_row).enumerate() {
        let start = gi * per_row;
        // Header: solution index + domain label.
        let mut header = String::new();
        for (gi, _) in group.iter().enumerate() {
            header.push_str(&format!(
                "{:>2}:d{}  {}",
                start + gi,
                labels[start + gi],
                " ".repeat(n.saturating_sub(5))
            ));
        }
        println!("{header}");
        for row in 0..k {
            let mut line = String::new();
            for m in group {
                for i in 0..n {
                    line.push(if m.get(i, row) == 1 { '#' } else { '.' });
                }
                line.push_str("   ");
            }
            println!("{line}");
        }
        println!();
    }

    // Dendrogram (scipy linkage convention) to CSV + text.
    let mut rows = Vec::new();
    println!("Ward merges (a, b -> node, distance, size):");
    for (step, m) in merges.iter().enumerate() {
        let node = pts.len() + step;
        if step >= merges.len().saturating_sub(8) {
            println!(
                "  {:>3} + {:>3} -> {:>3}   d={:<8.3} size={}",
                m.a, m.b, node, m.dist, m.size
            );
        }
        rows.push(vec![
            m.a.to_string(),
            m.b.to_string(),
            node.to_string(),
            format!("{:.6}", m.dist),
            m.size.to_string(),
        ]);
    }
    let path = format!("{}/fig5_dendrogram.csv", ctx.cfg.out_dir);
    write_csv(&path, &["a", "b", "node", "dist", "size"], &rows)
        .expect("write csv");

    // Solutions + labels CSV.
    let sol_rows: Vec<Vec<String>> = bf
        .orbit
        .iter()
        .zip(&labels)
        .enumerate()
        .map(|(i, (m, &lab))| {
            let bits: String = m
                .data
                .iter()
                .map(|&s| if s == 1 { '1' } else { '0' })
                .collect();
            vec![i.to_string(), lab.to_string(), bits]
        })
        .collect();
    let spath = format!("{}/fig5_solutions.csv", ctx.cfg.out_dir);
    write_csv(&spath, &["index", "domain", "bits"], &sol_rows)
        .expect("write csv");

    let domain_sizes: Vec<usize> = (0..4)
        .map(|d| labels.iter().filter(|&&l| l == d).count())
        .collect();
    println!("domain sizes: {domain_sizes:?}");
    println!("csv: {path}, {spath}\n");
}
