//! Fig. 4: domain-population traces — which of the four solution-space
//! domains each algorithm samples from, per iteration, for five
//! individual runs (window-100 smoothing).

use super::{Ctx, RunSpec};
use crate::cluster::{cut, domain_trace, ward};
use crate::report::{fmt, write_csv};

const N_DOMAINS: usize = 4;
const WINDOW: usize = 100;

/// Fig. 4: convergence on the alternative problem domains.
pub fn fig4(ctx: &Ctx) {
    let inst = 0;
    let bf = &ctx.exact[inst];
    let pts: Vec<Vec<i8>> =
        bf.orbit.iter().map(|m| m.data.clone()).collect();
    let merges = ward(&pts);
    let labels = cut(&merges, pts.len(), N_DOMAINS.min(pts.len()));

    let specs = {
        let mut s = RunSpec::core_six();
        s.push(RunSpec::new(crate::bbo::Algorithm::Nbocs { sigma2: 0.1 })
            .augmented());
        s
    };
    let n_runs = 5.min(ctx.cfg.runs.max(1));

    println!("== fig4 — domain populations ({} domains, window {WINDOW}) ==",
             N_DOMAINS);
    for spec in &specs {
        let runs = ctx.run_spec(spec, inst, n_runs);
        let mut rows = Vec::new();
        let mut focus_sum = 0.0;
        for (ri, run) in runs.iter().enumerate() {
            let traces =
                domain_trace(&run.xs, &pts, &labels, N_DOMAINS, WINDOW);
            let steps = run.xs.len();
            for t in 0..steps {
                let mut row = vec![ri.to_string(), t.to_string()];
                for d in 0..N_DOMAINS {
                    row.push(fmt(traces[d][t]));
                }
                rows.push(row);
            }
            // "Focus" = max final domain share (FMQA ≈ 1, RS ≈ 0.25).
            let focus = (0..N_DOMAINS)
                .map(|d| traces[d][steps - 1])
                .fold(0.0f64, f64::max);
            focus_sum += focus;
        }
        let path = format!(
            "{}/fig4_{}.csv",
            ctx.cfg.out_dir,
            spec.label().to_lowercase()
        );
        write_csv(
            &path,
            &["run", "step", "dom0", "dom1", "dom2", "dom3"],
            &rows,
        )
        .expect("write csv");
        println!(
            "{:<10} mean final focus {:.3}   ({} runs)  csv: {}",
            spec.label(),
            focus_sum / runs.len() as f64,
            runs.len(),
            path
        );
    }
    println!();
}
