//! Table 1: counts of exact-solution hits per algorithm × instance.

use super::{count_exact_hits, Ctx, RunSpec};
use crate::bbo::Algorithm;
use crate::report::{ascii_table, write_csv};

/// Table 1: exact-hit counts per algorithm across the instance suite.
pub fn table1(ctx: &Ctx) {
    let specs = RunSpec::table_nine();
    let n_inst = ctx.problems.len();

    // counts[spec][instance]
    let mut counts = vec![vec![0usize; n_inst]; specs.len()];
    for (si, spec) in specs.iter().enumerate() {
        for inst in 0..n_inst {
            let runs = if spec.algo == Algorithm::Rs {
                ctx.cfg.rs_runs
            } else {
                ctx.cfg.runs
            };
            eprintln!(
                "[table1] {} instance {} ({} runs)...",
                spec.label(),
                inst + 1,
                runs
            );
            let results = ctx.run_spec(spec, inst, runs);
            counts[si][inst] = count_exact_hits(ctx, inst, &results);
        }
    }

    // Render like the paper: instance rows, algorithm columns.
    let mut headers: Vec<String> = vec!["Instance".into()];
    headers.extend(specs.iter().map(|s| s.label()));
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut rows = Vec::new();
    for inst in 0..n_inst {
        let mut row = vec![(inst + 1).to_string()];
        for cnt in counts.iter() {
            row.push(cnt[inst].to_string());
        }
        rows.push(row);
    }
    let mut total_row = vec!["Total".to_string()];
    for cnt in counts.iter() {
        total_row.push(cnt.iter().sum::<usize>().to_string());
    }
    rows.push(total_row);

    println!(
        "== table1 — exact-solution hits per {} runs (RS: {}) ==",
        ctx.cfg.runs, ctx.cfg.rs_runs
    );
    println!("{}", ascii_table(&header_refs, &rows));
    let path = format!("{}/table1.csv", ctx.cfg.out_dir);
    write_csv(&path, &header_refs, &rows).expect("write csv");
    println!("csv: {path}\n");
}
