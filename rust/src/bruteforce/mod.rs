//! Exact brute-force search (paper "Exact solutions" / Methods).
//!
//! Ground truth for every experiment: the exact minimiser of Eq. 8, the
//! second-best cost (grey dotted line in Fig. 1), and the full
//! `K! * 2^K`-element solution orbit (Fig. 5, Table 1 hit-counting).
//!
//! Two engines:
//!
//! * [`brute_force`] — the fast path.  The cost is invariant under column
//!   sign flips and permutations, so it only enumerates *canonical* column
//!   multisets: each column's sign is fixed (first entry +1, `2^(N-1)`
//!   classes) and columns are non-decreasing in class id.  For the paper
//!   scale (N=8, K=3) this is C(130, 3) = 357,760 candidates instead of
//!   2^24 = 16.7M — a 47× reduction with zero loss (validated against the
//!   full scan in tests).
//! * [`full_scan_gray`] — the literal 2^(NK) sweep the paper ran (5553 s in
//!   their setup), walking a Gray code so consecutive candidates differ by
//!   one flipped entry.  Used for validation on small sizes and as the
//!   §Perf benchmark workload.

use crate::cost::{BinMatrix, Problem};

/// Outcome of the exact search.
#[derive(Clone, Debug)]
pub struct BruteForceResult {
    /// Exact minimum of the cost (Eq. 8).
    pub best_cost: f64,
    /// Second-lowest *distinct* cost (a different symmetry orbit).
    pub second_cost: f64,
    /// Canonical minimisers (usually 1 for a generic instance).
    pub canonical: Vec<BinMatrix>,
    /// Full expanded solution orbit: all column permutations and sign
    /// flips of the canonical minimisers, deduplicated (48 = 3! * 2^3 for
    /// a generic K=3 instance).
    pub orbit: Vec<BinMatrix>,
    /// Number of candidates evaluated.
    pub evaluated: usize,
}

/// Build the ±1 column of a sign class id (first entry +1).
fn class_column(n: usize, id: usize) -> Vec<i8> {
    let mut col = Vec::with_capacity(n);
    col.push(1);
    for bit in 0..(n - 1) {
        col.push(if (id >> bit) & 1 == 1 { -1 } else { 1 });
    }
    col
}

/// Relative tolerance for grouping equal costs across candidates.
const TIE_REL: f64 = 1e-9;

/// Exact search over canonical column multisets.
pub fn brute_force(problem: &Problem) -> BruteForceResult {
    let (n, k) = (problem.n(), problem.k);
    assert!(n >= 2 && n <= 24, "class enumeration needs 2 <= N <= 24");
    let classes = 1usize << (n - 1);
    let tol = TIE_REL * problem.w_norm_sq.max(1.0);

    let mut best = f64::INFINITY;
    let mut second = f64::INFINITY;
    let mut canonical: Vec<BinMatrix> = Vec::new();
    let mut evaluated = 0usize;

    // Non-decreasing K-tuples of class ids (multisets).
    let mut stack = vec![0usize; k];
    let mut m_data = vec![1i8; n * k];
    enumerate_multisets(classes, k, &mut stack, 0, 0, &mut |ids| {
        for (j, &id) in ids.iter().enumerate() {
            let col = class_column(n, id);
            m_data[j * n..(j + 1) * n].copy_from_slice(&col);
        }
        let m = BinMatrix::new(n, k, m_data.clone());
        let c = problem.cost(&m);
        evaluated += 1;
        if c < best - tol {
            second = best;
            best = c;
            canonical.clear();
            canonical.push(m);
        } else if c <= best + tol {
            canonical.push(m);
        } else if c < second - tol {
            second = c;
        }
    });

    let orbit = expand_orbit(&canonical);
    BruteForceResult { best_cost: best, second_cost: second, canonical, orbit, evaluated }
}

fn enumerate_multisets(
    classes: usize,
    k: usize,
    stack: &mut Vec<usize>,
    depth: usize,
    start: usize,
    visit: &mut impl FnMut(&[usize]),
) {
    if depth == k {
        visit(stack);
        return;
    }
    for id in start..classes {
        stack[depth] = id;
        enumerate_multisets(classes, k, stack, depth + 1, id, visit);
    }
}

/// All permutations of 0..k (Heap's algorithm).
fn permutations(k: usize) -> Vec<Vec<usize>> {
    let mut perm: Vec<usize> = (0..k).collect();
    let mut out = vec![perm.clone()];
    let mut c = vec![0usize; k];
    let mut i = 0;
    while i < k {
        if c[i] < i {
            if i % 2 == 0 {
                perm.swap(0, i);
            } else {
                perm.swap(c[i], i);
            }
            out.push(perm.clone());
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
    out
}

/// Expand canonical solutions into the full symmetry orbit
/// (all `K! * 2^K` sign/permutation variants, deduplicated).
pub fn expand_orbit(canonical: &[BinMatrix]) -> Vec<BinMatrix> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for m in canonical {
        let k = m.k;
        for perm in permutations(k) {
            for sign_bits in 0..(1usize << k) {
                let signs: Vec<i8> = (0..k)
                    .map(|j| if (sign_bits >> j) & 1 == 1 { -1 } else { 1 })
                    .collect();
                let t = m.transformed(&perm, &signs);
                if seen.insert(t.data.clone()) {
                    out.push(t);
                }
            }
        }
    }
    out
}

/// Literal full sweep over all 2^(NK) candidates via Gray code (one entry
/// flips between consecutive candidates).  Returns (best cost, argmin,
/// candidates evaluated).
pub fn full_scan_gray(problem: &Problem) -> (f64, BinMatrix, usize) {
    let bits = problem.n_bits();
    assert!(bits <= 30, "full scan is 2^bits evaluations");
    let total = 1u64 << bits;
    let (n, k) = (problem.n(), problem.k);
    let mut m = BinMatrix::ones(n, k);
    let mut best = problem.cost(&m);
    let mut argmin = m.clone();

    for g in 1..total {
        // Bit flipped between Gray(g-1) and Gray(g) is trailing-zeros(g).
        let bit = g.trailing_zeros() as usize;
        m.data[bit] = -m.data[bit];
        let c = problem.cost(&m);
        if c < best {
            best = c;
            argmin = m.clone();
        }
    }
    (best, argmin, total as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::{generate, InstanceConfig};

    fn small_problem(n: usize, d: usize, k: usize, seed: u64) -> Problem {
        let cfg = InstanceConfig { n, d, k, gamma: 0.8, seed };
        generate(&cfg, 0)
    }

    #[test]
    fn class_enumeration_matches_full_scan() {
        // Exhaustive cross-validation of the 47x symmetry reduction.
        for seed in [1, 2, 3] {
            let p = small_problem(4, 7, 2, seed);
            let fast = brute_force(&p);
            let (slow_best, _, evals) = full_scan_gray(&p);
            assert_eq!(evals, 1 << 8);
            assert!(
                (fast.best_cost - slow_best).abs() < 1e-9,
                "seed={seed}: {} vs {}",
                fast.best_cost,
                slow_best
            );
        }
    }

    #[test]
    fn candidate_count_is_multiset_count() {
        // C(2^(n-1) + k - 1, k) canonical candidates.
        let p = small_problem(4, 5, 2, 4);
        let r = brute_force(&p);
        // 2^3 = 8 classes, multisets of 2: C(9,2) = 36.
        assert_eq!(r.evaluated, 36);
    }

    #[test]
    fn orbit_size_generic_is_k_factorial_times_2k() {
        let p = small_problem(5, 9, 2, 5);
        let r = brute_force(&p);
        if r.canonical.len() == 1 {
            let m = &r.canonical[0];
            let distinct_cols = m.col(0) != m.col(1);
            if distinct_cols {
                // 2! * 2^2 = 8 equivalent matrices.
                assert_eq!(r.orbit.len(), 8);
            }
        }
    }

    #[test]
    fn orbit_members_share_the_optimal_cost() {
        let p = small_problem(5, 8, 2, 6);
        let r = brute_force(&p);
        for m in &r.orbit {
            assert!((p.cost(m) - r.best_cost).abs() < 1e-9);
        }
    }

    #[test]
    fn second_cost_strictly_above_best() {
        let p = small_problem(5, 8, 2, 7);
        let r = brute_force(&p);
        assert!(r.second_cost > r.best_cost);
        assert!(r.second_cost.is_finite());
    }

    #[test]
    fn canonical_forms_are_canonical() {
        let p = small_problem(4, 6, 2, 8);
        let r = brute_force(&p);
        for m in &r.canonical {
            assert_eq!(m, &m.canonical());
        }
    }

    #[test]
    fn gray_code_walks_whole_space() {
        // On a 2x2 problem (4 bits): 16 candidates, best must equal the
        // canonical search.
        let p = small_problem(2, 3, 2, 9);
        let fast = brute_force(&p);
        let (slow, argmin, evals) = full_scan_gray(&p);
        assert_eq!(evals, 16);
        assert!((fast.best_cost - slow).abs() < 1e-9);
        assert!((p.cost(&argmin) - slow).abs() < 1e-12);
    }

    #[test]
    fn paper_scale_smoke() {
        // N=8, K=3: 366k canonical candidates — must run quickly and find
        // a 48-element orbit on a generic instance.
        let p = generate(&InstanceConfig::default(), 0);
        let r = brute_force(&p);
        assert_eq!(r.evaluated, 357_760);
        assert_eq!(r.orbit.len(), 48, "generic instance has 3!*2^3 = 48");
        assert!(r.best_cost > 0.0 && r.best_cost < p.w_norm_sq);
        // Paper band for exact normalised residual: ~0.37-0.54.
        let nerr = p.normalised_error(r.best_cost);
        assert!(nerr > 0.2 && nerr < 0.7, "normalised residual {nerr}");
    }
}
