//! The integer-decomposition cost function (paper Eq. 1–9) — native twin of
//! the Pallas cost kernel.
//!
//! For a target `W (N×D)` and binary `M (N×K, ±1)` the black-box cost is
//!
//! ```text
//!   cost(M) = || W - M (M^T M)^+ M^T W ||_F^2
//! ```
//!
//! Key identity used everywhere in this crate: with `Q` an orthonormal basis
//! of `col(M)` and `S = W W^T` (N×N, precomputed once per problem),
//!
//! ```text
//!   cost(M) = ||W||_F^2 - Σ_k q_k^T S q_k
//! ```
//!
//! which drops the per-candidate complexity from `O(NKD)` to `O(K N^2)` —
//! the optimisation that makes the 2^24 brute-force sweep cheap.  The basis
//! comes from a threshold-masked modified Gram–Schmidt, so rank-deficient
//! candidates get exact pseudoinverse semantics (a dependent column simply
//! contributes nothing), matching `ref.py` / the Pallas kernel.

use std::cell::RefCell;

use crate::linalg::{dot, lu_solve, Matrix, NumericError};
use crate::util::threadpool::parallel_map;

/// Rank threshold for the masked Gram–Schmidt.  For integer columns the
/// Gram determinant is a non-negative integer, so independent residual
/// norms are bounded below by `1/N^{K-1}`; 1e-9 sits far under that floor
/// and far above f64 noise.
pub const EPS_RANK: f64 = 1e-9;

/// Binary matrix M (N×K), column-major storage of ±1 entries.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BinMatrix {
    /// Rows N.
    pub n: usize,
    /// Columns K.
    pub k: usize,
    /// Column-major: entry (i, j) at `data[j * n + i]`.
    pub data: Vec<i8>,
}

impl BinMatrix {
    /// From column-major ±1 entries (length must be n·k).
    pub fn new(n: usize, k: usize, data: Vec<i8>) -> Self {
        assert_eq!(data.len(), n * k);
        debug_assert!(data.iter().all(|&s| s == 1 || s == -1));
        BinMatrix { n, k, data }
    }

    /// All +1 matrix.
    pub fn ones(n: usize, k: usize) -> Self {
        BinMatrix { n, k, data: vec![1; n * k] }
    }

    /// From a flat ±1 spin vector (column-major), as used by the BBO loop.
    pub fn from_spins(n: usize, k: usize, x: &[i8]) -> Self {
        BinMatrix::new(n, k, x.to_vec())
    }

    /// The flat ±1 spin vector view (column-major).
    pub fn as_spins(&self) -> &[i8] {
        &self.data
    }

    /// Column j as a slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[i8] {
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Entry (i, j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> i8 {
        self.data[j * self.n + i]
    }

    /// Set entry (i, j).
    pub fn set(&mut self, i: usize, j: usize, v: i8) {
        self.data[j * self.n + i] = v;
    }

    /// Flip entry (i, j).
    pub fn flip(&mut self, i: usize, j: usize) {
        self.data[j * self.n + i] = -self.data[j * self.n + i];
    }

    /// Apply a column permutation and per-column sign flips; used to
    /// enumerate the `K! * 2^K` symmetry orbit (paper "two types of
    /// arbitrariness").
    pub fn transformed(&self, perm: &[usize], signs: &[i8]) -> BinMatrix {
        assert_eq!(perm.len(), self.k);
        assert_eq!(signs.len(), self.k);
        let mut data = Vec::with_capacity(self.n * self.k);
        for (dst, &src) in perm.iter().enumerate() {
            let s = signs[dst];
            data.extend(self.col(src).iter().map(|&v| v * s));
        }
        BinMatrix::new(self.n, self.k, data)
    }

    /// Dense f64 copy (row-major Matrix), for least-squares / display.
    pub fn to_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n, self.k);
        for j in 0..self.k {
            for i in 0..self.n {
                m[(i, j)] = self.get(i, j) as f64;
            }
        }
        m
    }

    /// Canonical representative of the symmetry orbit: each column's sign
    /// is fixed so its first element is +1, then columns are sorted
    /// lexicographically.  Two matrices are equivalent (same cost) iff
    /// their canonical forms are equal.
    pub fn canonical(&self) -> BinMatrix {
        let mut cols: Vec<Vec<i8>> = (0..self.k)
            .map(|j| {
                let c = self.col(j);
                if c[0] == 1 {
                    c.to_vec()
                } else {
                    c.iter().map(|&v| -v).collect()
                }
            })
            .collect();
        cols.sort();
        let mut data = Vec::with_capacity(self.n * self.k);
        for c in cols {
            data.extend(c);
        }
        BinMatrix::new(self.n, self.k, data)
    }
}

/// Reusable buffers for the masked-Gram–Schmidt cost evaluation: the
/// accepted orthonormal basis (flattened K×N), the working column and
/// the `S·q` product.  [`Problem::cost`] keeps one per thread; pass your
/// own to [`Problem::cost_with`] for explicit control.
pub struct CostScratch {
    /// Accepted orthonormal columns, flattened (up to K rows of N).
    basis: Vec<f64>,
    /// The column currently being orthogonalised.
    v: Vec<f64>,
    /// `S · v` buffer.
    sq: Vec<f64>,
}

impl CostScratch {
    /// Empty scratch; buffers warm up on the first evaluation.
    pub fn new() -> Self {
        CostScratch { basis: Vec::new(), v: Vec::new(), sq: Vec::new() }
    }
}

impl Default for CostScratch {
    fn default() -> Self {
        CostScratch::new()
    }
}

thread_local! {
    /// Per-thread cost scratch: the oracle is evaluated from the main
    /// BBO thread and from pool workers (batched acquisition,
    /// `compress_all` jobs), and each such thread reuses one scratch
    /// across all of its evaluations.
    static COST_SCRATCH: RefCell<CostScratch> =
        RefCell::new(CostScratch::new());
}

/// A compression problem instance: the target matrix plus precomputed
/// quantities for fast cost evaluation.
#[derive(Clone, Debug)]
pub struct Problem {
    /// Target W (N×D).
    pub w: Matrix,
    /// Decomposition rank K.
    pub k: usize,
    /// S = W W^T (N×N).
    pub s: Matrix,
    /// ||W||_F^2.
    pub w_norm_sq: f64,
}

impl Problem {
    /// Problem for target `w` at rank `k` (precomputes S = W Wᵀ).
    ///
    /// Panics on a non-finite entry in `w`; use [`Problem::try_new`] at
    /// boundaries that need a typed error instead (serve 400, CLI).
    pub fn new(w: Matrix, k: usize) -> Self {
        match Problem::try_new(w, k) {
            Ok(p) => p,
            Err(e) => panic!("invalid problem: {e}"),
        }
    }

    /// Fallible [`Problem::new`]: rejects a target matrix containing
    /// NaN/±Inf entries with [`NumericError::NonFiniteInput`] (ISSUE 9)
    /// — a non-finite W would otherwise poison S = W Wᵀ and every cost
    /// the oracle ever reports.
    pub fn try_new(w: Matrix, k: usize) -> Result<Self, NumericError> {
        assert!(k >= 1 && k <= w.rows);
        if let Some(index) = w.data.iter().position(|v| !v.is_finite()) {
            return Err(NumericError::NonFiniteInput { index });
        }
        let wt = w.transpose();
        let s = w.matmul(&wt);
        let w_norm_sq = w.frob_norm_sq();
        Ok(Problem { w, k, s, w_norm_sq })
    }

    /// Target rows N.
    #[inline]
    pub fn n(&self) -> usize {
        self.w.rows
    }

    /// Target columns D.
    #[inline]
    pub fn d(&self) -> usize {
        self.w.cols
    }

    /// Number of binary variables n = N*K of the NLIP formulation.
    #[inline]
    pub fn n_bits(&self) -> usize {
        self.n() * self.k
    }

    /// Black-box cost of a candidate (Eq. 8), pseudoinverse semantics.
    ///
    /// Runs through a per-thread [`CostScratch`], so repeated
    /// evaluations on one thread (the BBO loop, a pool worker in a
    /// batched sweep) allocate nothing after warm-up.
    pub fn cost(&self, m: &BinMatrix) -> f64 {
        COST_SCRATCH.with(|s| self.cost_with(m, &mut s.borrow_mut()))
    }

    /// [`Problem::cost`] with a caller-owned scratch (the explicit
    /// zero-allocation entry point; `cost` itself reuses a thread-local
    /// one).
    pub fn cost_with(&self, m: &BinMatrix, scratch: &mut CostScratch) -> f64 {
        assert_eq!(m.n, self.n());
        assert_eq!(m.k, self.k);
        let n = self.n();
        scratch.basis.clear();
        scratch.v.resize(n, 0.0);
        scratch.sq.resize(n, 0.0);
        let mut captured = 0.0;
        let mut nb = 0usize;
        for j in 0..self.k {
            for (vi, &sp) in scratch.v.iter_mut().zip(m.col(j)) {
                *vi = sp as f64;
            }
            // Two MGS passes for numerical robustness.
            for _ in 0..2 {
                for q in 0..nb {
                    let qrow = &scratch.basis[q * n..(q + 1) * n];
                    let c = dot(qrow, &scratch.v);
                    for (vi, qi) in scratch.v.iter_mut().zip(qrow) {
                        *vi -= c * qi;
                    }
                }
            }
            let nrm2 = dot(&scratch.v, &scratch.v);
            if nrm2 > EPS_RANK {
                let inv = 1.0 / nrm2.sqrt();
                for vi in scratch.v.iter_mut() {
                    *vi *= inv;
                }
                // captured += q^T S q.
                self.s.matvec_into(&scratch.v, &mut scratch.sq);
                captured += dot(&scratch.v, &scratch.sq);
                scratch.basis.extend_from_slice(&scratch.v);
                nb += 1;
            }
        }
        (self.w_norm_sq - captured).max(0.0)
    }

    /// Costs of a whole candidate batch, evaluated concurrently across
    /// `workers` threads of the shared pool in input order — each worker
    /// reuses its thread-local [`CostScratch`], so the sweep is
    /// allocation-free after warm-up.  This is the batched-oracle entry
    /// point behind [`crate::minlp::Oracle::eval_batch`] for [`Problem`].
    pub fn cost_batch(&self, ms: &[BinMatrix], workers: usize) -> Vec<f64> {
        parallel_map(ms.iter().collect(), workers, |m| self.cost(m))
    }

    /// Cost from a flat spin vector (column-major), the BBO interface.
    pub fn cost_spins(&self, x: &[i8]) -> f64 {
        self.cost(&BinMatrix::from_spins(self.n(), self.k, x))
    }

    /// The eliminated real factor `C = (M^T M)^+ M^T W` (Eq. 6).  Falls
    /// back to a tiny ridge when M is rank-deficient (the limit equals the
    /// pseudoinverse solution because `M^T W` lies in range(M^T M)).
    pub fn solve_c(&self, m: &BinMatrix) -> Matrix {
        let md = m.to_matrix();
        let mut g = md.gram(); // K×K
        let a = md.transpose().matmul(&self.w); // K×D
        let mut c = Matrix::zeros(self.k, self.d());
        // Try exact solve; on singular G, ridge-regularise.
        let mut ridge = 0.0;
        loop {
            let mut gr = g.clone();
            for i in 0..self.k {
                gr[(i, i)] += ridge;
            }
            let mut ok = true;
            for col in 0..self.d() {
                let rhs: Vec<f64> = (0..self.k).map(|r| a[(r, col)]).collect();
                match lu_solve(&gr, &rhs) {
                    Some(x) => {
                        for r in 0..self.k {
                            c[(r, col)] = x[r];
                        }
                    }
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok {
                return c;
            }
            ridge = if ridge == 0.0 { 1e-9 } else { ridge * 10.0 };
            if ridge > 1.0 {
                g = md.gram();
                for i in 0..self.k {
                    g[(i, i)] += 1.0;
                }
            }
        }
    }

    /// Reconstruction `V = M C` and explicit residual — the slow-but-direct
    /// check used by tests against the trace-identity fast path.
    pub fn cost_explicit(&self, m: &BinMatrix) -> f64 {
        let c = self.solve_c(m);
        let v = m.to_matrix().matmul(&c);
        self.w.sub(&v).frob_norm_sq()
    }

    /// Paper's residual-error measure:
    /// `(||f(M)|| - ||f(M*)||) / ||W||` given the optimal cost.
    pub fn residual_error(&self, cost: f64, best_cost: f64) -> f64 {
        (cost.max(0.0).sqrt() - best_cost.max(0.0).sqrt())
            / self.w_norm_sq.sqrt()
    }

    /// Normalised absolute error `||f(M)|| / ||W||`.
    pub fn normalised_error(&self, cost: f64) -> f64 {
        cost.max(0.0).sqrt() / self.w_norm_sq.sqrt()
    }
}

/// Compression-rate estimate (paper intro): original N*D floats at
/// `float_bits` vs K*D floats + N*K binary entries (1 bit each).
pub fn compression_ratio(
    n: usize,
    d: usize,
    k: usize,
    float_bits: usize,
) -> f64 {
    let original = (n * d * float_bits) as f64;
    let compressed = (k * d * float_bits + n * k) as f64;
    compressed / original
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_problem(rng: &mut Rng, n: usize, d: usize, k: usize) -> Problem {
        Problem::new(Matrix::from_vec(n, d, rng.normals(n * d)), k)
    }

    fn rand_bin(rng: &mut Rng, n: usize, k: usize) -> BinMatrix {
        BinMatrix::new(n, k, rng.spins(n * k))
    }

    #[test]
    fn trace_identity_matches_explicit_residual() {
        let mut rng = Rng::new(100);
        for _ in 0..50 {
            let p = rand_problem(&mut rng, 8, 20, 3);
            let m = rand_bin(&mut rng, 8, 3);
            let fast = p.cost(&m);
            let slow = p.cost_explicit(&m);
            assert!(
                (fast - slow).abs() < 1e-6 * (1.0 + slow),
                "fast={fast} slow={slow}"
            );
        }
    }

    #[test]
    fn rank_deficient_equals_reduced_k() {
        let mut rng = Rng::new(101);
        let p2 = rand_problem(&mut rng, 8, 15, 2);
        let p3 = Problem::new(p2.w.clone(), 3);
        let m2 = rand_bin(&mut rng, 8, 2);
        // Duplicate first column (and sign-flip variant).
        for dup_sign in [1i8, -1] {
            let mut data = m2.data.clone();
            data.extend(m2.col(0).iter().map(|&v| v * dup_sign));
            let m3 = BinMatrix::new(8, 3, data);
            assert!((p3.cost(&m3) - p2.cost(&m2)).abs() < 1e-8);
        }
    }

    #[test]
    fn k_equals_n_reconstructs_exactly() {
        let mut rng = Rng::new(102);
        // Hadamard basis for N = 4: orthogonal ±1 columns.
        let h = BinMatrix::new(
            4,
            4,
            vec![1, 1, 1, 1, 1, -1, 1, -1, 1, 1, -1, -1, 1, -1, -1, 1],
        );
        let p = rand_problem(&mut rng, 4, 9, 4);
        assert!(p.cost(&h) < 1e-9 * p.w_norm_sq.max(1.0));
    }

    #[test]
    fn cost_invariant_under_symmetry_orbit() {
        let mut rng = Rng::new(103);
        let p = rand_problem(&mut rng, 8, 12, 3);
        let m = rand_bin(&mut rng, 8, 3);
        let base = p.cost(&m);
        for perm in [[0, 1, 2], [1, 0, 2], [2, 1, 0], [1, 2, 0]] {
            for signs in [[1i8, 1, 1], [-1, 1, 1], [1, -1, -1], [-1, -1, -1]]
            {
                let t = m.transformed(&perm, &signs);
                assert!((p.cost(&t) - base).abs() < 1e-9 * (1.0 + base));
            }
        }
    }

    #[test]
    fn canonical_form_identifies_orbit() {
        let mut rng = Rng::new(104);
        let m = rand_bin(&mut rng, 8, 3);
        let canon = m.canonical();
        let t = m.transformed(&[2, 0, 1], &[-1, 1, -1]);
        assert_eq!(t.canonical(), canon);
        // Canonical form has +1 leading entries and sorted columns.
        for j in 0..3 {
            assert_eq!(canon.col(j)[0], 1);
        }
    }

    #[test]
    fn cost_bounds() {
        let mut rng = Rng::new(105);
        let p = rand_problem(&mut rng, 6, 10, 2);
        for _ in 0..20 {
            let m = rand_bin(&mut rng, 6, 2);
            let c = p.cost(&m);
            assert!(c >= 0.0);
            assert!(c <= p.w_norm_sq + 1e-9);
        }
    }

    #[test]
    fn solve_c_gives_least_squares_optimum() {
        // Perturbing C away from solve_c must not lower the residual.
        let mut rng = Rng::new(106);
        let p = rand_problem(&mut rng, 8, 10, 3);
        let m = rand_bin(&mut rng, 8, 3);
        let c = p.solve_c(&m);
        let md = m.to_matrix();
        let base = p.w.sub(&md.matmul(&c)).frob_norm_sq();
        for _ in 0..10 {
            let mut cp = c.clone();
            let i = rng.below(cp.rows);
            let j = rng.below(cp.cols);
            cp[(i, j)] += 0.01 * rng.normal();
            let v = p.w.sub(&md.matmul(&cp)).frob_norm_sq();
            assert!(v >= base - 1e-9);
        }
    }

    #[test]
    fn residual_error_zero_at_optimum() {
        let mut rng = Rng::new(107);
        let p = rand_problem(&mut rng, 5, 8, 2);
        assert_eq!(p.residual_error(2.0, 2.0), 0.0);
        assert!(p.residual_error(3.0, 2.0) > 0.0);
    }

    #[test]
    fn compression_ratio_matches_hand_calc() {
        // 8x100 f32 -> K=3: (3*100*32 + 8*3) / (8*100*32)
        let r = compression_ratio(8, 100, 3, 32);
        assert!((r - (9600.0 + 24.0) / 25600.0).abs() < 1e-12);
    }

    #[test]
    fn cost_with_scratch_matches_thread_local_path_bit_for_bit() {
        let mut rng = Rng::new(109);
        let p = rand_problem(&mut rng, 8, 20, 3);
        let mut scratch = CostScratch::new();
        for _ in 0..20 {
            let m = rand_bin(&mut rng, 8, 3);
            let a = p.cost(&m);
            let b = p.cost_with(&m, &mut scratch);
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn cost_batch_matches_serial_costs() {
        let mut rng = Rng::new(110);
        let p = rand_problem(&mut rng, 8, 20, 3);
        let ms: Vec<BinMatrix> =
            (0..17).map(|_| rand_bin(&mut rng, 8, 3)).collect();
        let serial: Vec<f64> = ms.iter().map(|m| p.cost(m)).collect();
        for workers in [1usize, 2, 4] {
            let batch = p.cost_batch(&ms, workers);
            for (a, b) in serial.iter().zip(&batch) {
                assert_eq!(a.to_bits(), b.to_bits(), "workers {workers}");
            }
        }
    }

    #[test]
    fn spins_roundtrip() {
        let mut rng = Rng::new(108);
        let m = rand_bin(&mut rng, 8, 3);
        let m2 = BinMatrix::from_spins(8, 3, m.as_spins());
        assert_eq!(m, m2);
    }

    #[test]
    fn try_new_rejects_non_finite_entries() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut w = Matrix::zeros(3, 4);
            w[(1, 2)] = bad;
            let err = Problem::try_new(w, 2).unwrap_err();
            // Flat index of (1, 2) in row-major 3×4 storage.
            assert_eq!(err, NumericError::NonFiniteInput { index: 6 });
        }
    }

    #[test]
    fn try_new_accepts_finite_matrix() {
        let mut rng = Rng::new(111);
        let w = Matrix::from_vec(4, 6, rng.normals(24));
        let p = Problem::try_new(w, 2).unwrap();
        assert_eq!(p.n_bits(), 8);
    }

    #[test]
    fn new_panics_on_non_finite_entry() {
        let mut w = Matrix::zeros(2, 2);
        w[(0, 0)] = f64::NAN;
        let out = std::panic::catch_unwind(|| Problem::new(w, 1));
        assert!(out.is_err());
    }
}
