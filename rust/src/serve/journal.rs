//! The daemon's write-ahead request journal — the durability layer
//! that makes `intdecomp serve` restart-transparent.
//!
//! When the daemon runs with `--state DIR` and journaling on, every
//! admitted `compress` request appends one **admitted** line here
//! (schema-versioned JSONL carrying the full [`ModelSpec`] JSON plus
//! its fingerprint) before any work starts, and one terminal
//! **completed** / **cancelled** line when it ends; every line is
//! fsynced before the daemon proceeds.  Per-layer progress does *not*
//! live in the journal: it rides the exact shard checkpoint path — a
//! [`crate::shard::CheckpointLog`] at `DIR/jobs/<fingerprint>.jsonl`,
//! one fsynced [`crate::shard::LayerRecord`] line per finished layer.
//!
//! On restart, [`recover_journal`] scans the journal's **valid
//! prefix** (complete, newline-terminated, parseable lines — the same
//! torn-tail contract as [`crate::shard::recover_log`]; a crash can
//! only tear the final line) and yields each request's latest status.
//! Requests left `admitted` are the daemon's crash debt: the recovery
//! pass re-runs exactly their unfinished layers (the checkpoint log
//! already holds the finished prefix) and marks them completed.
//! Because every record is a pure function of the spec, the finished
//! log — and the report served from it — is byte-identical to an
//! uninterrupted run's.
//!
//! The journal itself carries no extra lockfile: the daemon's state
//! directory is exclusive already (`serve.state` advisory lock at
//! bind), making the daemon the journal's single writer.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::shard::ModelSpec;
use crate::util::json::Json;

/// Schema tag of every journal line; bump on layout changes.
pub const JOURNAL_SCHEMA: &str = "intdecomp-serve-journal-v1";

/// Bind-time recovery policy for a journaled state directory
/// (`--recover on|off|strict`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RecoverMode {
    /// Skip the recovery pass: the journal is appended to but crash
    /// debt is left untouched (it stays serveable on re-request).
    Off,
    /// Recover every valid prefix, silently truncating torn tails,
    /// and finish incomplete requests at bind.  The default.
    #[default]
    On,
    /// Like `On`, but refuse to start if any torn or foreign bytes
    /// had to be dropped from the journal or a checkpoint log.
    Strict,
}

impl RecoverMode {
    /// Parse the `--recover` flag value.
    pub fn parse(s: &str) -> Result<RecoverMode> {
        match s {
            "off" => Ok(RecoverMode::Off),
            "on" => Ok(RecoverMode::On),
            "strict" => Ok(RecoverMode::Strict),
            other => bail!("--recover {other}: expected on, off or strict"),
        }
    }

    /// The flag spelling of this mode.
    pub fn label(self) -> &'static str {
        match self {
            RecoverMode::Off => "off",
            RecoverMode::On => "on",
            RecoverMode::Strict => "strict",
        }
    }
}

/// Life-cycle status of a journaled request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Work was admitted; no terminal marker yet (crash debt when
    /// found at recovery time).
    Admitted,
    /// All layers finished; the checkpoint log holds the full run.
    Completed,
    /// The request was cancelled (client gone or deadline); its
    /// checkpoint prefix is kept but recovery does not replay it.
    Cancelled,
}

impl JobStatus {
    /// The wire spelling of this status.
    pub fn label(self) -> &'static str {
        match self {
            JobStatus::Admitted => "admitted",
            JobStatus::Completed => "completed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    fn parse(s: &str) -> Option<JobStatus> {
        match s {
            "admitted" => Some(JobStatus::Admitted),
            "completed" => Some(JobStatus::Completed),
            "cancelled" => Some(JobStatus::Cancelled),
            _ => None,
        }
    }
}

/// One journaled request: the admitted spec and its latest status.
#[derive(Clone, Debug)]
pub struct JournalEntry {
    /// The spec fingerprint — the request's durable identity.
    pub fingerprint: String,
    /// The full admitted workload (enough to re-run it from nothing).
    pub spec: ModelSpec,
    /// The latest status found in the journal.
    pub status: JobStatus,
}

/// What [`recover_journal`] found in an existing journal.
#[derive(Debug, Default)]
pub struct RecoveredJournal {
    /// One entry per distinct fingerprint, in first-admit order, each
    /// carrying the latest status its lines reached.
    pub entries: Vec<JournalEntry>,
    /// Byte length of the valid prefix.
    pub valid_bytes: u64,
    /// Bytes past the valid prefix (torn tail / foreign garbage);
    /// [`Journal::open`] truncates them.
    pub dropped_bytes: u64,
}

impl RecoveredJournal {
    /// The crash debt: requests admitted but never terminated.
    pub fn incomplete(&self) -> impl Iterator<Item = &JournalEntry> {
        self.entries
            .iter()
            .filter(|e| e.status == JobStatus::Admitted)
    }
}

/// The journal file inside a state directory.
pub fn journal_path(state_dir: &Path) -> PathBuf {
    state_dir.join("journal.jsonl")
}

/// The per-request checkpoint log inside a state directory.
pub fn jobs_log_path(state_dir: &Path, fingerprint: &str) -> PathBuf {
    state_dir.join("jobs").join(format!("{fingerprint}.jsonl"))
}

/// Build one `admitted` journal line (no trailing newline): the full
/// spec JSON rides along so recovery can re-run the request with no
/// other input.
pub fn admitted_line(spec: &ModelSpec, fingerprint: &str) -> String {
    Json::obj(vec![
        ("fingerprint", Json::Str(fingerprint.into())),
        ("schema", Json::Str(JOURNAL_SCHEMA.into())),
        ("spec", spec.to_json()),
        ("status", Json::Str(JobStatus::Admitted.label().into())),
    ])
    .to_string()
}

/// Build one terminal journal line (no trailing newline).
pub fn status_line(fingerprint: &str, status: JobStatus) -> String {
    Json::obj(vec![
        ("fingerprint", Json::Str(fingerprint.into())),
        ("schema", Json::Str(JOURNAL_SCHEMA.into())),
        ("status", Json::Str(status.label().into())),
    ])
    .to_string()
}

/// Parse one journal line into `(fingerprint, status, spec)`.  An
/// `admitted` line must carry a spec whose own fingerprint matches the
/// line's; terminal lines carry none.
fn parse_line(line: &str) -> Result<(String, JobStatus, Option<ModelSpec>)> {
    let j = Json::parse(line).map_err(|e| anyhow!("journal line: {e}"))?;
    match j.get("schema").and_then(Json::as_str) {
        Some(s) if s == JOURNAL_SCHEMA => {}
        other => bail!("journal line: bad schema tag {other:?}"),
    }
    let fp = j
        .get("fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("journal line: missing 'fingerprint'"))?
        .to_string();
    let status = j
        .get("status")
        .and_then(Json::as_str)
        .and_then(JobStatus::parse)
        .ok_or_else(|| anyhow!("journal line: bad 'status'"))?;
    let spec = match status {
        JobStatus::Admitted => {
            let spec = ModelSpec::from_json(
                j.get("spec")
                    .ok_or_else(|| anyhow!("journal line: missing 'spec'"))?,
            )?;
            if spec.fingerprint() != fp {
                bail!(
                    "journal line: spec fingerprint {} != envelope {fp}",
                    spec.fingerprint()
                );
            }
            Some(spec)
        }
        _ => None,
    };
    Ok((fp, status, spec))
}

/// Read the valid prefix of a journal: complete, newline-terminated,
/// parseable lines whose statuses form a consistent history (a
/// terminal marker for a never-admitted fingerprint ends the prefix —
/// admits always precede their terminals, so anything else is
/// corruption).  A missing file is an empty journal.
pub fn recover_journal(path: &Path) -> Result<RecoveredJournal> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(RecoveredJournal::default())
        }
        Err(e) => {
            return Err(e)
                .with_context(|| format!("reading {}", path.display()))
        }
    };
    let mut entries: Vec<JournalEntry> = Vec::new();
    let mut index: BTreeMap<String, usize> = BTreeMap::new();
    let mut valid = 0usize;
    // Raw-byte scan, like `shard::recover_log`: a non-UTF-8 tail is
    // truncated like any other torn line instead of wedging recovery.
    let mut rest = bytes.as_slice();
    'scan: while let Some(nl) = rest.iter().position(|&b| b == b'\n') {
        let parsed = std::str::from_utf8(&rest[..nl])
            .ok()
            .and_then(|line| parse_line(line).ok());
        let Some((fp, status, spec)) = parsed else { break };
        match (index.get(&fp), spec) {
            (None, Some(spec)) => {
                index.insert(fp.clone(), entries.len());
                entries.push(JournalEntry { fingerprint: fp, spec, status });
            }
            (Some(&i), spec) => {
                // Re-admit or terminal transition of a known request.
                entries[i].status = status;
                if let Some(spec) = spec {
                    entries[i].spec = spec;
                }
            }
            // Terminal marker for a fingerprint never admitted.
            (None, None) => break 'scan,
        }
        valid += nl + 1;
        rest = &rest[nl + 1..];
    }
    Ok(RecoveredJournal {
        entries,
        valid_bytes: valid as u64,
        dropped_bytes: (bytes.len() - valid) as u64,
    })
}

/// The append-side journal handle.  Opening recovers the valid
/// prefix, truncates the torn tail and positions for appending;
/// [`Journal::record_admitted`] and friends fsync every line before
/// returning — the write-ahead guarantee the recovery pass trusts.
#[derive(Debug)]
pub struct Journal {
    path: PathBuf,
    file: std::fs::File,
}

impl Journal {
    /// Open (creating if missing) the journal at `path`, returning the
    /// writer and everything the valid prefix held.
    pub fn open(path: &Path) -> Result<(Journal, RecoveredJournal)> {
        let recovered = recover_journal(path)?;
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating {}", parent.display())
                })?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        file.set_len(recovered.valid_bytes)
            .with_context(|| format!("truncating {}", path.display()))?;
        drop(file);
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| {
                format!("opening {} for append", path.display())
            })?;
        Ok((Journal { path: path.to_path_buf(), file }, recovered))
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Journal a request's admission (write-ahead: call before any
    /// layer work starts).
    pub fn record_admitted(
        &mut self,
        spec: &ModelSpec,
        fingerprint: &str,
    ) -> std::io::Result<()> {
        self.append(admitted_line(spec, fingerprint))
    }

    /// Journal a request's completion.
    pub fn record_completed(
        &mut self,
        fingerprint: &str,
    ) -> std::io::Result<()> {
        self.append(status_line(fingerprint, JobStatus::Completed))
    }

    /// Journal a request's cancellation (client gone / deadline).
    pub fn record_cancelled(
        &mut self,
        fingerprint: &str,
    ) -> std::io::Result<()> {
        self.append(status_line(fingerprint, JobStatus::Cancelled))
    }

    fn append(&mut self, mut line: String) -> std::io::Result<()> {
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        self.file.sync_data()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(seed: u64) -> ModelSpec {
        ModelSpec {
            n: 4,
            d: 8,
            k: 2,
            gamma: 0.8,
            instance_seed: 9,
            layers: 2,
            iters: 5,
            restarts: 3,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed,
            cache_key_raw: false,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("intdecomp_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn journal_roundtrips_specs_and_statuses() {
        let dir = tmp("journal_roundtrip");
        let path = journal_path(&dir);
        let a = tiny_spec(1);
        let b = tiny_spec(2);
        let (fa, fb) = (a.fingerprint(), b.fingerprint());
        {
            let (mut j, rec) = Journal::open(&path).unwrap();
            assert!(rec.entries.is_empty());
            j.record_admitted(&a, &fa).unwrap();
            j.record_admitted(&b, &fb).unwrap();
            j.record_completed(&fa).unwrap();
        }
        let rec = recover_journal(&path).unwrap();
        assert_eq!(rec.entries.len(), 2);
        assert_eq!(rec.dropped_bytes, 0);
        assert_eq!(rec.entries[0].status, JobStatus::Completed);
        assert_eq!(rec.entries[0].spec, a);
        assert_eq!(rec.entries[1].status, JobStatus::Admitted);
        let debt: Vec<_> =
            rec.incomplete().map(|e| e.fingerprint.clone()).collect();
        assert_eq!(debt, vec![fb.clone()]);
        // Cancel b on a reopen; no more crash debt.
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.record_cancelled(&fb).unwrap();
        }
        let rec = recover_journal(&path).unwrap();
        assert_eq!(rec.entries[1].status, JobStatus::Cancelled);
        assert_eq!(rec.incomplete().count(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_truncates_torn_tails_and_rejects_foreign_lines() {
        let dir = tmp("journal_torn");
        let path = journal_path(&dir);
        let a = tiny_spec(3);
        let fa = a.fingerprint();
        {
            let (mut j, _) = Journal::open(&path).unwrap();
            j.record_admitted(&a, &fa).unwrap();
        }
        let full = std::fs::read(&path).unwrap();
        // Torn mid-line: the admit survives only when its newline does.
        let mut cut = full.clone();
        cut.extend_from_slice(&full[..full.len() - 9]);
        std::fs::write(&path, &cut).unwrap();
        let rec = recover_journal(&path).unwrap();
        assert_eq!(rec.entries.len(), 1);
        assert_eq!(rec.valid_bytes as usize, full.len());
        assert_eq!(rec.dropped_bytes as usize, full.len() - 9);
        // Journal::open truncates the tail for good.
        drop(Journal::open(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), full);
        // A terminal marker for a never-admitted fingerprint ends the
        // valid prefix (admits precede terminals by construction).
        let orphan = format!(
            "{}\n{}",
            status_line("deadbeef", JobStatus::Completed),
            String::from_utf8(full.clone()).unwrap()
        );
        std::fs::write(&path, orphan).unwrap();
        let rec = recover_journal(&path).unwrap();
        assert_eq!(rec.valid_bytes, 0);
        assert!(rec.entries.is_empty());
        // A spec whose fingerprint disagrees with the envelope is
        // corruption, not a request.
        let lied = admitted_line(&a, "0000000000000000");
        std::fs::write(&path, format!("{lied}\n")).unwrap();
        let rec = recover_journal(&path).unwrap();
        assert!(rec.entries.is_empty());
        assert!(rec.dropped_bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_mode_parses_and_labels() {
        for (s, m) in [
            ("off", RecoverMode::Off),
            ("on", RecoverMode::On),
            ("strict", RecoverMode::Strict),
        ] {
            assert_eq!(RecoverMode::parse(s).unwrap(), m);
            assert_eq!(m.label(), s);
        }
        assert!(RecoverMode::parse("maybe").is_err());
        assert_eq!(RecoverMode::default(), RecoverMode::On);
    }
}
