//! `intdecomp serve` — the long-lived compression daemon.
//!
//! A line-delimited JSON request/response protocol
//! ([`protocol::SERVE_SCHEMA`]) over a TCP or Unix-domain socket, built
//! directly on the existing engine: requests are [`ModelSpec`]-shaped
//! (the spec fingerprint is the request and cache identity), layer
//! results stream back as the exact shard [`LayerRecord`] lines, and
//! the terminal `done` line embeds the [`deterministic_report`] so a
//! served compression is byte-identical to `compress-model --report`.
//!
//! What the daemon adds over the one-shot CLI:
//!
//! * **Warm caches across requests** — a process-wide [`CacheRegistry`]
//!   keyed by instance layer attaches canonical-orbit [`CostCache`]s as
//!   a second lookup level under every job's private cache, so repeated
//!   or overlapping requests skip evaluations earlier requests already
//!   paid for, without perturbing any request's own report.
//! * **Admission control** — [`Admission`] bounds concurrent compress
//!   requests globally, per client (peer IP on TCP) and through an
//!   optional bounded wait queue; excess load gets an explicit `429`
//!   error line instead of an invisible queue, and the connection
//!   survives for a retry.
//! * **Cancellation and deadlines** — a client disconnect cancels its
//!   in-flight request at the next iteration boundary (permit
//!   released, typed `cancelled` trailer written best-effort), and a
//!   per-request `deadline_ms` in the envelope bounds wall time with a
//!   typed `deadline` trailer.  Runs that *complete* stay
//!   byte-identical to the CLI: cancellation checks never touch RNG or
//!   numeric state.
//! * **Bounded memory** — the registry takes a [`CacheBudget`]
//!   (entry/byte caps) and evicts whole per-instance caches LRU-first
//!   after each request; a zero budget disables cross-request caching
//!   entirely.  Slow-loris partial lines and oversized request lines
//!   are cut off with a `400` without disturbing other connections.
//! * **Observability** — a `stats` request reports cache sizes and
//!   eviction totals, hit-rate, queue depth, admission/cancellation
//!   counters and per-request latency percentiles ([`Metrics`]); a
//!   `jobs` request lists every journaled request and its status.
//! * **Crash durability** — with `--state DIR` and journaling on, a
//!   write-ahead [`Journal`] plus per-request
//!   [`crate::shard::CheckpointLog`]s make a SIGKILL'd daemon
//!   restart-transparent: the bind-time recovery pass finishes
//!   interrupted requests (re-running only unfinished layers), warms
//!   the cache registry from the recovered records, and a re-sent
//!   request is served from the durable log with `recovered:true` and
//!   a byte-identical report (see `docs/ARCHITECTURE.md`
//!   § Durability).
//! * **Warm starts across requests** — with `--state DIR`, every
//!   finished layer's surrogate state (dataset sufficient statistics
//!   plus fitted parameters, schema `intdecomp-surrogate-state-v1`)
//!   is persisted in a [`WarmStore`] keyed by
//!   [`ModelSpec::instance_key`]; a later request on the same
//!   *instance* — even with a different spec fingerprint (new seed,
//!   budget or knobs) — seeds its runs from the stored state and
//!   reports `warm:true`/`warm_source` on the `done` line.
//!   Incompatible or corrupt states degrade to a cold start with a
//!   logged warning, never silently.
//! * **Versioned wire schema** — v2 greets every connection with a
//!   `hello` line advertising capabilities (`jobs`, `resume`,
//!   `warm`); requests must tag themselves
//!   `"schema":"intdecomp-serve-v2"`, and v1 clients get a typed
//!   `400` telling them to upgrade.
//!
//! [`ModelSpec`]: crate::shard::ModelSpec
//! [`ModelSpec::instance_key`]: crate::shard::ModelSpec::instance_key
//! [`LayerRecord`]: crate::shard::LayerRecord
//! [`deterministic_report`]: crate::shard::deterministic_report
//! [`CostCache`]: crate::engine::CostCache

pub mod cache;
pub mod journal;
pub mod protocol;
pub mod server;
pub mod warm;

pub use cache::{CacheBudget, CacheRegistry, RegistryStats};
pub use journal::{
    recover_journal, JobStatus, Journal, JournalEntry, RecoverMode,
    RecoveredJournal, JOURNAL_SCHEMA,
};
pub use protocol::{
    bare_request, compress_request, compress_request_with_deadline,
    hello_line, is_hello, Request, SERVE_CAPABILITIES, SERVE_SCHEMA,
};
pub use server::{
    request, Admission, Admit, Endpoint, Metrics, MetricsSnapshot,
    Permit, ResumeStats, ServeConfig, Server, MAX_LINE_BYTES,
};
pub use warm::WarmStore;
