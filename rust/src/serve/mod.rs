//! `intdecomp serve` — the long-lived compression daemon.
//!
//! A line-delimited JSON request/response protocol
//! ([`protocol::SERVE_SCHEMA`]) over a TCP or Unix-domain socket, built
//! directly on the existing engine: requests are [`ModelSpec`]-shaped
//! (the spec fingerprint is the request and cache identity), layer
//! results stream back as the exact shard [`LayerRecord`] lines, and
//! the terminal `done` line embeds the [`deterministic_report`] so a
//! served compression is byte-identical to `compress-model --report`.
//!
//! What the daemon adds over the one-shot CLI:
//!
//! * **Warm caches across requests** — a process-wide [`CacheRegistry`]
//!   keyed by instance layer attaches canonical-orbit [`CostCache`]s as
//!   a second lookup level under every job's private cache, so repeated
//!   or overlapping requests skip evaluations earlier requests already
//!   paid for, without perturbing any request's own report.
//! * **Admission control** — [`Admission`] bounds concurrent compress
//!   requests; excess load gets an explicit `429` error line instead of
//!   an invisible queue, and the connection survives for a retry.
//! * **Observability** — a `stats` request reports cache hit-rate,
//!   queue depth, admission counters and per-request latency
//!   percentiles ([`Metrics`]).
//!
//! [`ModelSpec`]: crate::shard::ModelSpec
//! [`LayerRecord`]: crate::shard::LayerRecord
//! [`deterministic_report`]: crate::shard::deterministic_report
//! [`CostCache`]: crate::engine::CostCache

pub mod cache;
pub mod protocol;
pub mod server;

pub use cache::CacheRegistry;
pub use protocol::{
    bare_request, compress_request, Request, SERVE_SCHEMA,
};
pub use server::{
    request, Admission, Endpoint, Metrics, MetricsSnapshot, Permit,
    ServeConfig, Server,
};
