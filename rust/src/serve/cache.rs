//! The daemon's process-wide cross-request cache store.
//!
//! One canonical-orbit [`CostCache`] per *instance layer*
//! ([`crate::shard::ModelSpec::instance_key`]): the cost is a function
//! of the layer matrix `W` as well as the candidate, so caches are
//! never shared across different instance keys — and within one key,
//! canonical-mode entries are pure functions of the canonical
//! candidate, so sharing them across requests (different seeds,
//! budgets, algorithms) cannot change any result.  Jobs attach these
//! caches as their second level
//! ([`crate::engine::CompressionJob::shared_cache`]), which leaves
//! per-request reports byte-identical to the cold CLI path.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::engine::{CacheStats, CostCache};

/// Registry of shared per-instance-layer caches.
#[derive(Default)]
pub struct CacheRegistry {
    map: Mutex<HashMap<String, Arc<CostCache>>>,
}

impl CacheRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        CacheRegistry::default()
    }

    /// The shared cache for one instance key, created (canonical-orbit
    /// mode) on first use.
    pub fn get(&self, key: &str) -> Arc<CostCache> {
        let mut map = self.map.lock().unwrap();
        map.entry(key.to_string())
            .or_insert_with(|| Arc::new(CostCache::with_canonical_keys()))
            .clone()
    }

    /// Distinct instance keys seen so far.
    pub fn caches(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Aggregate over every cache: (stored entries, hit/miss totals).
    /// The hits are the daemon's *cross-request* savings — evaluations
    /// short-circuited by some earlier request's work (or a concurrent
    /// sibling job's; a request alone in a cold daemon contributes no
    /// shared hits because its per-job local caches absorb repeats
    /// first).
    pub fn stats(&self) -> (usize, CacheStats) {
        let map = self.map.lock().unwrap();
        let mut entries = 0usize;
        let mut total = CacheStats::default();
        for cache in map.values() {
            entries += cache.len();
            let s = cache.stats();
            total.hits += s.hits;
            total.misses += s.misses;
        }
        (entries, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_key_shares_one_cache() {
        let reg = CacheRegistry::new();
        let a = reg.get("n4-l0");
        let b = reg.get("n4-l0");
        let c = reg.get("n4-l1");
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(reg.caches(), 2);
        let (entries, stats) = reg.stats();
        assert_eq!(entries, 0);
        assert_eq!(stats, CacheStats::default());
    }
}
