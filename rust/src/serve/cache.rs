//! The daemon's process-wide cross-request cache store, bounded by an
//! operator budget.
//!
//! One canonical-orbit [`CostCache`] per *instance layer*
//! ([`crate::shard::ModelSpec::instance_key`]): the cost is a function
//! of the layer matrix `W` as well as the candidate, so caches are
//! never shared across different instance keys — and within one key,
//! canonical-mode entries are pure functions of the canonical
//! candidate, so sharing them across requests (different seeds,
//! budgets, algorithms) cannot change any result.  Jobs attach these
//! caches as their second level
//! ([`crate::engine::CompressionJob::shared_cache`]), which leaves
//! per-request reports byte-identical to the cold CLI path.
//!
//! # Bounding
//!
//! A long-lived daemon serving many distinct models would otherwise
//! grow without bound, so the registry takes a [`CacheBudget`]
//! (entry and/or byte caps) and evicts **whole caches, least recently
//! used first** when [`CacheRegistry::enforce`] runs (the server calls
//! it after every request).  Whole-cache eviction is the only unit
//! that preserves the byte-identity contract cheaply: a partially
//! evicted cache would change which lookups hit, but dropping an
//! entire instance's cache just means the next request for it
//! recomputes from cold — same values, same report.  Jobs hold their
//! own `Arc` for the duration of a run, so eviction can never
//! invalidate an in-flight evaluation.  Hit/miss counts of evicted
//! caches are folded into a retired total, keeping the daemon's
//! aggregate counters monotone across evictions.
//!
//! A budget of zero entries (or zero bytes) turns the registry into a
//! pass-through: [`CacheRegistry::get`] returns `None` and jobs run
//! with their local caches only — never an error, never a stored byte.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::engine::{CacheStats, CostCache};

/// Operator-facing registry bound: `None` means unbounded on that
/// axis; `Some(0)` on either axis disables cross-request caching
/// entirely (pass-through mode).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheBudget {
    /// Cap on total stored entries across all caches.
    pub entries: Option<usize>,
    /// Cap on total estimated bytes ([`CostCache::approx_bytes`])
    /// across all caches.
    pub bytes: Option<usize>,
}

impl CacheBudget {
    /// No caps on either axis (the registry never evicts).
    pub fn unbounded() -> Self {
        CacheBudget::default()
    }

    /// True when either axis is capped at zero: nothing may ever be
    /// stored, so the registry hands out no shared caches at all.
    pub fn is_pass_through(&self) -> bool {
        self.entries == Some(0) || self.bytes == Some(0)
    }
}

/// Point-in-time registry accounting, as exposed by the daemon's
/// `stats` reply.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    /// Live caches (distinct instance keys currently resident).
    pub caches: usize,
    /// Entries stored across live caches.
    pub entries: usize,
    /// Estimated bytes across live caches.
    pub bytes: usize,
    /// Whole caches evicted since startup (monotone).
    pub evicted_caches: u64,
    /// Entries dropped with those caches (monotone).
    pub evicted_entries: u64,
    /// Hit/miss totals across live *and* evicted caches (monotone).
    pub cache: CacheStats,
}

struct Slot {
    cache: Arc<CostCache>,
    /// Logical timestamp of the last `get`; smallest = evict first.
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<String, Slot>,
    tick: u64,
    evicted_caches: u64,
    evicted_entries: u64,
    /// Hit/miss totals folded in from evicted caches, so aggregate
    /// counters never move backwards when a cache is dropped.
    retired: CacheStats,
}

/// Registry of shared per-instance-layer caches with LRU eviction
/// under a [`CacheBudget`].
#[derive(Default)]
pub struct CacheRegistry {
    budget: CacheBudget,
    inner: Mutex<Inner>,
}

impl CacheRegistry {
    /// Empty, unbounded registry.
    pub fn new() -> Self {
        CacheRegistry::default()
    }

    /// Empty registry that [`CacheRegistry::enforce`] holds to
    /// `budget`.
    pub fn with_budget(budget: CacheBudget) -> Self {
        CacheRegistry { budget, ..Default::default() }
    }

    /// The configured budget.
    pub fn budget(&self) -> CacheBudget {
        self.budget
    }

    /// The shared cache for one instance key, created (canonical-orbit
    /// mode) on first use and marked most-recently-used.  `None` in
    /// pass-through mode (zero budget): the caller runs the job with
    /// local caches only.
    pub fn get(&self, key: &str) -> Option<Arc<CostCache>> {
        if self.budget.is_pass_through() {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner
            .map
            .entry(key.to_string())
            .or_insert_with(|| Slot {
                cache: Arc::new(CostCache::with_canonical_keys()),
                last_used: 0,
            });
        slot.last_used = tick;
        Some(slot.cache.clone())
    }

    /// Evict least-recently-used caches until the live totals fit the
    /// budget; returns how many caches were dropped.  Runs after each
    /// request rather than inside `get` so a request's own cache is
    /// never pulled out from under it mid-run (jobs also hold their
    /// own `Arc`, making eviction safe regardless).
    pub fn enforce(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut dropped = 0usize;
        loop {
            let (entries, bytes) = live_totals(&inner.map);
            let over_entries = match self.budget.entries {
                Some(cap) => entries > cap,
                None => false,
            };
            let over_bytes = match self.budget.bytes {
                Some(cap) => bytes > cap,
                None => false,
            };
            if !over_entries && !over_bytes {
                break;
            }
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(k, _)| k.clone());
            let Some(key) = victim else { break };
            if let Some(slot) = inner.map.remove(&key) {
                let s = slot.cache.stats();
                inner.retired.hits += s.hits;
                inner.retired.misses += s.misses;
                inner.evicted_entries += slot.cache.len() as u64;
                inner.evicted_caches += 1;
                dropped += 1;
            }
        }
        dropped
    }

    /// Pre-populate the shared cache for one instance key with a known
    /// evaluation — the serve recovery pass calls this with each
    /// journaled layer record's winning candidate and cost, so the
    /// first post-restart request for the same instance hits warm
    /// instead of re-evaluating.  Storing is idempotent (the canonical
    /// key dedupes) and can never change a result: the cached value
    /// *is* the deterministic cost of the candidate.  Returns `false`
    /// in pass-through mode (nothing may be stored).
    pub fn warm(
        &self,
        key: &str,
        candidate: &crate::cost::BinMatrix,
        cost: f64,
    ) -> bool {
        match self.get(key) {
            Some(cache) => {
                cache.get_or_eval(candidate, |_| cost);
                true
            }
            None => false,
        }
    }

    /// Distinct instance keys currently resident.
    pub fn caches(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Aggregate accounting: live sizes plus monotone eviction and
    /// hit/miss totals.  The hits are the daemon's *cross-request*
    /// savings — evaluations short-circuited by some earlier request's
    /// work (or a concurrent sibling job's; a request alone in a cold
    /// daemon contributes no shared hits because its per-job local
    /// caches absorb repeats first).
    pub fn stats(&self) -> RegistryStats {
        let inner = self.inner.lock().unwrap();
        let (entries, bytes) = live_totals(&inner.map);
        let mut cache = inner.retired;
        for slot in inner.map.values() {
            let s = slot.cache.stats();
            cache.hits += s.hits;
            cache.misses += s.misses;
        }
        RegistryStats {
            caches: inner.map.len(),
            entries,
            bytes,
            evicted_caches: inner.evicted_caches,
            evicted_entries: inner.evicted_entries,
            cache,
        }
    }
}

fn live_totals(map: &HashMap<String, Slot>) -> (usize, usize) {
    let mut entries = 0usize;
    let mut bytes = 0usize;
    for slot in map.values() {
        entries += slot.cache.len();
        bytes += slot.cache.approx_bytes();
    }
    (entries, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::BinMatrix;

    /// Store `n` distinct entries in the registry's cache for `key`.
    fn fill(reg: &CacheRegistry, key: &str, n: usize) -> Arc<CostCache> {
        let cache = reg.get(key).expect("budgeted registry refused a get");
        for i in 0..n {
            let spins: Vec<i8> = (0..8)
                .map(|b| if (i >> b) & 1 == 1 { 1 } else { -1 })
                .collect();
            let m = BinMatrix::new(8, 1, spins);
            cache.get_or_eval(&m, |_| i as f64);
        }
        cache
    }

    #[test]
    fn same_key_shares_one_cache() {
        let reg = CacheRegistry::new();
        let a = reg.get("n4-l0").unwrap();
        let b = reg.get("n4-l0").unwrap();
        let c = reg.get("n4-l1").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(reg.caches(), 2);
        let s = reg.stats();
        assert_eq!((s.entries, s.cache), (0, CacheStats::default()));
    }

    #[test]
    fn unbounded_registry_never_evicts() {
        let reg = CacheRegistry::new();
        for l in 0..16 {
            fill(&reg, &format!("k-l{l}"), 4);
        }
        assert_eq!(reg.enforce(), 0);
        let s = reg.stats();
        assert_eq!((s.caches, s.entries), (16, 64));
        assert_eq!(s.evicted_caches, 0);
    }

    #[test]
    fn lru_eviction_drops_the_coldest_key_with_exact_accounting() {
        let budget =
            CacheBudget { entries: Some(8), bytes: None };
        let reg = CacheRegistry::with_budget(budget);
        fill(&reg, "a", 4);
        fill(&reg, "b", 4);
        // Touch "a" so "b" is the LRU victim.
        let _ = reg.get("a");
        fill(&reg, "c", 4); // 12 entries > 8
        assert_eq!(reg.enforce(), 1);
        assert!(reg.get("b").unwrap().is_empty(), "b was evicted");
        assert!(!reg.get("a").unwrap().is_empty(), "a survived");
        assert!(!reg.get("c").unwrap().is_empty(), "c survived");
        let s = reg.stats();
        assert_eq!(s.evicted_caches, 1);
        assert_eq!(s.evicted_entries, 4);
        // 12 misses total (4 per fill); evicting "b" must not lose its
        // 4 from the aggregate.
        assert_eq!(s.cache.misses, 12);
    }

    #[test]
    fn byte_budget_evicts_and_recompute_is_identical() {
        // Each fill(…, 4) entry weighs 8 spins + overhead.
        let per_entry = 8 + 64;
        let budget = CacheBudget {
            entries: None,
            bytes: Some(6 * per_entry),
        };
        let reg = CacheRegistry::with_budget(budget);
        let first = fill(&reg, "a", 4);
        let before: f64 = {
            let spins: Vec<i8> = (0..8)
                .map(|b| if (2usize >> b) & 1 == 1 { 1 } else { -1 })
                .collect();
            first.get_or_eval(&BinMatrix::new(8, 1, spins), |_| {
                panic!("entry 2 must already be cached")
            })
        };
        fill(&reg, "b", 4); // 8 entries * per_entry > budget
        assert!(reg.enforce() >= 1);
        assert!(reg.stats().bytes <= 6 * per_entry);
        // "a" was the LRU victim; refilling recomputes the same value.
        let after: f64 = {
            let cache = fill(&reg, "a", 4);
            let spins: Vec<i8> = (0..8)
                .map(|b| if (2usize >> b) & 1 == 1 { 1 } else { -1 })
                .collect();
            cache.get_or_eval(&BinMatrix::new(8, 1, spins), |_| 2.0)
        };
        assert_eq!(before.to_bits(), after.to_bits());
    }

    #[test]
    fn warm_seeds_the_cache_and_respects_pass_through() {
        let reg = CacheRegistry::new();
        let spins: Vec<i8> = vec![1, -1, 1, 1, -1, -1, 1, -1];
        let m = BinMatrix::new(8, 1, spins.clone());
        assert!(reg.warm("n8-l0", &m, 0.375));
        // The warmed entry short-circuits the evaluation.
        let cache = reg.get("n8-l0").unwrap();
        let got = cache.get_or_eval(&m, |_| panic!("must be warm"));
        assert_eq!(got.to_bits(), 0.375f64.to_bits());
        // Pass-through registries store nothing.
        let off = CacheRegistry::with_budget(CacheBudget {
            entries: Some(0),
            bytes: None,
        });
        assert!(!off.warm("n8-l0", &m, 0.375));
    }

    #[test]
    fn zero_budget_is_pass_through() {
        for budget in [
            CacheBudget { entries: Some(0), bytes: None },
            CacheBudget { entries: None, bytes: Some(0) },
        ] {
            assert!(budget.is_pass_through());
            let reg = CacheRegistry::with_budget(budget);
            assert!(reg.get("k").is_none());
            assert_eq!(reg.enforce(), 0);
            let s = reg.stats();
            assert_eq!((s.caches, s.entries, s.bytes), (0, 0, 0));
        }
        assert!(!CacheBudget::unbounded().is_pass_through());
    }
}
