//! The serve daemon's line-delimited JSON wire format
//! (`intdecomp-serve-v2`).
//!
//! On accept the daemon writes one `hello` line advertising its
//! schema and capabilities (`jobs`, `resume`, `warm`) before reading
//! anything; clients use it to negotiate and must not treat it as a
//! response terminal.  Every *request* line must carry
//! `"schema":"intdecomp-serve-v2"` — v1 clients (no schema member)
//! get a typed `400` telling them to upgrade.
//!
//! One request per line, one or more response lines per request:
//!
//! * `{"type":"compress","spec":{..ModelSpec json..}}` — streams one
//!   [`crate::shard::LayerRecord`] line per finished layer (the exact
//!   shard result-log format, schema `intdecomp-shard-result-v2` with
//!   the per-layer degraded-mode counters,
//!   tagged with the spec fingerprint), then a terminal `done` line
//!   carrying the full deterministic report — byte-identical to
//!   `compress-model --report` for the same spec.  An optional
//!   `"deadline_ms"` member bounds the request's wall time: a request
//!   aborted at the deadline ends with a terminal `deadline` line
//!   instead of `done` (and a client disconnect aborts the run with a
//!   `cancelled` line written best-effort).  The deadline lives in the
//!   request envelope, *not* in the spec, so it can never perturb the
//!   spec fingerprint or the bytes of a run that completes.
//! * `{"type":"stats"}` — one `stats` line: cache hit-rate, queue
//!   depth, admission counters, per-request latency percentiles, the
//!   fault counters (`degraded` requests failed on a typed numeric
//!   error, `panicked` jobs contained at the pool boundary, and a
//!   nested `degradation` block summing the per-layer
//!   `surrogate_failures`/`fallback_proposals`/`rejected_costs`) and
//!   (on a journaled daemon) a nested `resume` block.
//! * `{"type":"jobs"}` — one `jobs` line listing every journaled
//!   request: fingerprint, status (`admitted`/`completed`/
//!   `cancelled`), layers checkpointed and layers requested.  An
//!   un-journaled daemon answers with an empty list.
//! * `{"type":"ping"}` → `pong`; `{"type":"shutdown"}` → `bye` and the
//!   daemon stops accepting.
//!
//! On a journaled daemon the `done` line additionally reports
//! `"recovered"` (true when any layer was served from the durable
//! checkpoint log instead of computed in-request) and
//! `"resumed_layers"` (how many) — metadata only, the `report` bytes
//! are identical either way.  On a daemon with a `--state` directory
//! the `done` line also reports `"warm"` (true when any layer was
//! warm-started from a persisted surrogate state), `"warm_layers"`
//! (how many) and `"warm_source"` (where the states came from) —
//! envelope metadata like the resume fields: the spec fingerprint and
//! the report bytes never depend on them.
//!
//! Every *typed* line (everything but the streamed layer records)
//! carries `"schema":"intdecomp-serve-v2"`.  Errors are
//! `{"type":"error","code":400|429|500,...}` — `429` is the admission
//! rejection: the request was well-formed but the daemon is at its
//! in-flight capacity, and the connection stays usable for a retry.
//! `500` covers a faulted job — a typed numeric failure (e.g. no
//! finite cost was ever observed) or a panic contained at the pool
//! boundary; either way the daemon keeps serving.

use anyhow::{anyhow, Result};

use crate::shard::ModelSpec;
use crate::util::cancel::CancelCause;
use crate::util::json::Json;

/// Schema tag carried by every typed line — responses *and* requests
/// (v2: requests must tag themselves; the tag rides the envelope and
/// never enters the spec fingerprint).
pub const SERVE_SCHEMA: &str = "intdecomp-serve-v2";

/// Capabilities the daemon advertises in its `hello` line, sorted.
pub const SERVE_CAPABILITIES: [&str; 3] = ["jobs", "resume", "warm"];

/// A parsed request line.
#[derive(Debug)]
pub enum Request {
    /// Compress the described workload and stream its records.
    Compress {
        /// The workload (the determinism domain — fingerprinted).
        spec: Box<ModelSpec>,
        /// Optional wall-time bound for this request, in milliseconds.
        deadline_ms: Option<u64>,
    },
    /// Report daemon counters (cache, admission, latency, resume).
    Stats,
    /// List the journaled requests and their statuses.
    Jobs,
    /// Liveness probe.
    Ping,
    /// Stop accepting connections (in-flight requests finish).
    Shutdown,
}

impl Request {
    /// Parse one request line.  v2 requests must tag themselves with
    /// `"schema":"intdecomp-serve-v2"`; an untagged (v1) or
    /// wrong-version line is a typed error so old clients get a `400`
    /// telling them what this daemon speaks instead of a silent
    /// misinterpretation.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).map_err(|e| anyhow!("request: {e}"))?;
        match j.get("schema").and_then(Json::as_str) {
            Some(s) if s == SERVE_SCHEMA => {}
            Some(other) => {
                return Err(anyhow!(
                    "request: schema '{other}' not supported \
                     (this daemon speaks {SERVE_SCHEMA})"
                ))
            }
            None => {
                return Err(anyhow!(
                    "request: missing 'schema' \
                     (this daemon speaks {SERVE_SCHEMA}; v1 clients \
                     must upgrade)"
                ))
            }
        }
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("request: missing 'type'"))?;
        match ty {
            "compress" => {
                let spec = j
                    .get("spec")
                    .ok_or_else(|| anyhow!("request: missing 'spec'"))?;
                let deadline_ms = match j.get("deadline_ms") {
                    None => None,
                    Some(v) => Some(v.as_u64().ok_or_else(|| {
                        anyhow!("request: 'deadline_ms' must be a u64")
                    })?),
                };
                Ok(Request::Compress {
                    spec: Box::new(ModelSpec::from_json(spec)?),
                    deadline_ms,
                })
            }
            "stats" => Ok(Request::Stats),
            "jobs" => Ok(Request::Jobs),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(anyhow!("request: unknown type '{other}'")),
        }
    }
}

/// Build a `compress` request line for `spec` (no trailing newline).
pub fn compress_request(spec: &ModelSpec) -> String {
    Json::obj(vec![
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("spec", spec.to_json()),
        ("type", Json::Str("compress".into())),
    ])
    .to_string()
}

/// Like [`compress_request`] with a per-request wall-time bound.
pub fn compress_request_with_deadline(
    spec: &ModelSpec,
    deadline_ms: u64,
) -> String {
    Json::obj(vec![
        ("deadline_ms", Json::Num(deadline_ms as f64)),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("spec", spec.to_json()),
        ("type", Json::Str("compress".into())),
    ])
    .to_string()
}

/// Build a bare typed request line (`stats`, `ping`, `shutdown`).
pub fn bare_request(ty: &str) -> String {
    Json::obj(vec![
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str(ty.into())),
    ])
    .to_string()
}

/// The greeting the daemon writes on every accepted connection before
/// reading anything: schema version plus the capability list clients
/// negotiate against.
pub fn hello_line() -> String {
    Json::obj(vec![
        (
            "capabilities",
            Json::Arr(
                SERVE_CAPABILITIES
                    .iter()
                    .map(|c| Json::Str((*c).into()))
                    .collect(),
            ),
        ),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("hello".into())),
    ])
    .to_string()
}

/// Whether a line is the daemon's connection greeting.  Clients must
/// check this on the *first* line they read and skip it — `hello`
/// carries a `type` member, so [`is_terminal`] would otherwise end the
/// response stream before any response arrived.
pub fn is_hello(line: &str) -> bool {
    Json::parse(line)
        .ok()
        .and_then(|j| {
            j.get("type").and_then(Json::as_str).map(|t| t == "hello")
        })
        .unwrap_or(false)
}

/// An `error` response line; `code` follows HTTP idiom (`400` bad
/// request, `429` admission rejection, `500` internal).
pub fn error_line(code: u64, message: &str) -> String {
    Json::obj(vec![
        ("code", Json::Num(code as f64)),
        ("error", Json::Str(message.into())),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("error".into())),
    ])
    .to_string()
}

/// The terminal `done` line of a successful compress request.  The
/// embedded `report` string is the full deterministic report — the
/// byte-identity artifact clients diff against `compress-model
/// --report`.  `resumed_layers` counts layers served from the durable
/// checkpoint log rather than computed in-request (`recovered` is its
/// non-zero flag); `warm_layers` counts layers warm-started from a
/// persisted surrogate state (`warm` is its non-zero flag,
/// `warm_source` says where the states came from).  All of them are
/// envelope metadata — the report bytes do not depend on them.
pub fn done_line(
    fingerprint: &str,
    layers: usize,
    report: &str,
    elapsed_s: f64,
    resumed_layers: usize,
    warm_layers: usize,
    warm_source: Option<&str>,
) -> String {
    Json::obj(vec![
        ("elapsed_s", Json::Num(elapsed_s)),
        ("fingerprint", Json::Str(fingerprint.into())),
        ("layers", Json::Num(layers as f64)),
        ("recovered", Json::Bool(resumed_layers > 0)),
        ("report", Json::Str(report.into())),
        ("resumed_layers", Json::Num(resumed_layers as f64)),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("done".into())),
        ("warm", Json::Bool(warm_layers > 0)),
        ("warm_layers", Json::Num(warm_layers as f64)),
        (
            "warm_source",
            match warm_source {
                Some(s) => Json::Str(s.into()),
                None => Json::Null,
            },
        ),
    ])
    .to_string()
}

/// One row of a `jobs` introspection reply (journal-backed).
#[derive(Clone, Debug)]
pub struct JobRow {
    /// The request's spec fingerprint.
    pub fingerprint: String,
    /// Latest journaled status: `admitted`, `completed`, `cancelled`.
    pub status: String,
    /// Layers durably checkpointed so far.
    pub layers_done: usize,
    /// Layers the spec asks for.
    pub layers: usize,
}

/// The `jobs` reply line: every journaled request and where it stands.
pub fn jobs_line(rows: &[JobRow]) -> String {
    let jobs = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("fingerprint", Json::Str(r.fingerprint.clone())),
                ("layers", Json::Num(r.layers as f64)),
                ("layers_done", Json::Num(r.layers_done as f64)),
                ("status", Json::Str(r.status.clone())),
            ])
        })
        .collect::<Vec<_>>();
    Json::obj(vec![
        ("jobs", Json::Arr(jobs)),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("jobs".into())),
    ])
    .to_string()
}

/// The terminal line of an aborted compress request: type `cancelled`
/// (client went away) or `deadline` (its `deadline_ms` elapsed), per
/// [`CancelCause::label`].  `layers_done` counts the record lines
/// already streamed before the abort — the prefix the client did get.
pub fn cancelled_line(
    cause: CancelCause,
    fingerprint: &str,
    layers_done: usize,
    elapsed_s: f64,
) -> String {
    Json::obj(vec![
        ("elapsed_s", Json::Num(elapsed_s)),
        ("fingerprint", Json::Str(fingerprint.into())),
        ("layers_done", Json::Num(layers_done as f64)),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str(cause.label().into())),
    ])
    .to_string()
}

/// The `pong` reply to a ping.
pub fn pong_line() -> String {
    Json::obj(vec![
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("pong".into())),
    ])
    .to_string()
}

/// The `bye` reply acknowledging a shutdown request.
pub fn bye_line() -> String {
    Json::obj(vec![
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("bye".into())),
    ])
    .to_string()
}

/// Whether a response line terminates the current request's response
/// stream.  Streamed layer-record lines have no `"type"` member; every
/// typed line (`done`, `error`, `stats`, `pong`, `bye`) is terminal.
pub fn is_terminal(line: &str) -> bool {
    Json::parse(line)
        .map(|j| j.get("type").is_some())
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            n: 4,
            d: 8,
            k: 2,
            gamma: 0.8,
            instance_seed: 9,
            layers: 2,
            iters: 5,
            restarts: 3,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed: 11,
            cache_key_raw: false,
        }
    }

    #[test]
    fn compress_request_roundtrips_the_spec() {
        let spec = tiny_spec();
        let line = compress_request(&spec);
        match Request::parse(&line).unwrap() {
            Request::Compress { spec: back, deadline_ms } => {
                assert_eq!(*back, spec);
                assert_eq!(deadline_ms, None);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn deadline_rides_the_envelope_not_the_spec() {
        let spec = tiny_spec();
        let line = compress_request_with_deadline(&spec, 250);
        match Request::parse(&line).unwrap() {
            Request::Compress { spec: back, deadline_ms } => {
                assert_eq!(*back, spec);
                assert_eq!(deadline_ms, Some(250));
                // The deadline must not leak into the determinism
                // domain: same fingerprint with and without one.
                assert_eq!(back.fingerprint(), spec.fingerprint());
            }
            other => panic!("wrong request: {other:?}"),
        }
        // Non-integer deadlines are a 400, not a silent default.
        assert!(Request::parse(
            r#"{"deadline_ms":"soon","schema":"intdecomp-serve-v2","spec":{},"type":"compress"}"#
        )
        .is_err());
    }

    #[test]
    fn hello_advertises_schema_and_capabilities() {
        let line = hello_line();
        assert!(is_hello(&line));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SERVE_SCHEMA));
        let caps = j.get("capabilities").unwrap().as_arr().unwrap();
        let caps: Vec<&str> =
            caps.iter().filter_map(Json::as_str).collect();
        assert_eq!(caps, vec!["jobs", "resume", "warm"]);
        // `hello` is typed, so a naive client would treat it as a
        // response terminal — which is exactly why clients must check
        // `is_hello` on the first line.
        assert!(is_terminal(&line));
        // And nothing else is a hello.
        assert!(!is_hello(&pong_line()));
        assert!(!is_hello("torn garbage"));
    }

    #[test]
    fn v1_requests_get_a_typed_upgrade_error() {
        // An old (v1) client sends no schema member: typed 400
        // mentioning what this daemon speaks, not a silent accept.
        let e = Request::parse(r#"{"type":"ping"}"#).unwrap_err();
        assert!(e.to_string().contains("intdecomp-serve-v2"), "{e}");
        // A wrong-version tag is named back to the sender.
        let e = Request::parse(
            r#"{"schema":"intdecomp-serve-v1","type":"ping"}"#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("intdecomp-serve-v1"), "{e}");
        assert!(e.to_string().contains("intdecomp-serve-v2"), "{e}");
    }

    #[test]
    fn bare_requests_parse() {
        assert!(matches!(
            Request::parse(&bare_request("stats")).unwrap(),
            Request::Stats
        ));
        assert!(matches!(
            Request::parse(&bare_request("jobs")).unwrap(),
            Request::Jobs
        ));
        assert!(matches!(
            Request::parse(&bare_request("ping")).unwrap(),
            Request::Ping
        ));
        assert!(matches!(
            Request::parse(&bare_request("shutdown")).unwrap(),
            Request::Shutdown
        ));
    }

    #[test]
    fn bad_requests_are_rejected() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse("{}").is_err());
        assert!(Request::parse(
            r#"{"schema":"intdecomp-serve-v2","type":"frobnicate"}"#
        )
        .is_err());
        // compress without a spec, and with an invalid spec.
        assert!(Request::parse(
            r#"{"schema":"intdecomp-serve-v2","type":"compress"}"#
        )
        .is_err());
        assert!(Request::parse(
            r#"{"schema":"intdecomp-serve-v2","spec":{"n":0},"type":"compress"}"#
        )
        .is_err());
    }

    #[test]
    fn terminal_detection_distinguishes_record_lines() {
        assert!(is_terminal(&error_line(429, "full")));
        assert!(is_terminal(&done_line(
            "f00d", 2, "report\n", 0.1, 0, 0, None
        )));
        assert!(is_terminal(&jobs_line(&[])));
        assert!(is_terminal(&pong_line()));
        assert!(is_terminal(&bye_line()));
        assert!(is_terminal(&cancelled_line(
            CancelCause::DeadlineExceeded,
            "f00d",
            1,
            0.2
        )));
        // A shard record line has no "type" member.
        assert!(!is_terminal(r#"{"schema":"x","job":0}"#));
        assert!(!is_terminal("torn garbage"));
    }

    #[test]
    fn cancelled_line_types_follow_the_cause() {
        let c = cancelled_line(CancelCause::Cancelled, "ab", 0, 0.0);
        let d =
            cancelled_line(CancelCause::DeadlineExceeded, "ab", 3, 1.5);
        let cj = Json::parse(&c).unwrap();
        let dj = Json::parse(&d).unwrap();
        assert_eq!(cj.get("type").unwrap().as_str(), Some("cancelled"));
        assert_eq!(dj.get("type").unwrap().as_str(), Some("deadline"));
        assert_eq!(dj.get("layers_done").unwrap().as_usize(), Some(3));
        assert_eq!(
            dj.get("schema").unwrap().as_str(),
            Some(SERVE_SCHEMA)
        );
    }

    #[test]
    fn done_line_preserves_report_bytes() {
        let report = "layer  shape\nlayer1 4x8\n";
        let line = done_line("f00d", 1, report, 0.25, 0, 0, None);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("report").unwrap().as_str(), Some(report));
        assert_eq!(j.get("fingerprint").unwrap().as_str(), Some("f00d"));
        assert_eq!(j.get("layers").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("recovered").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("resumed_layers").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("warm").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("warm_layers").unwrap().as_usize(), Some(0));
        assert!(matches!(j.get("warm_source"), Some(Json::Null)));
        // A resumed run flags itself but never touches the report.
        let resumed = done_line("f00d", 1, report, 0.25, 1, 0, None);
        let rj = Json::parse(&resumed).unwrap();
        assert_eq!(rj.get("recovered").unwrap().as_bool(), Some(true));
        assert_eq!(rj.get("resumed_layers").unwrap().as_usize(), Some(1));
        assert_eq!(rj.get("report").unwrap().as_str(), Some(report));
    }

    #[test]
    fn warm_metadata_rides_the_done_envelope_not_the_report() {
        // Same fingerprint/report with and without warm layers: the
        // warm fields are metadata, the byte-identity artifact is
        // untouched.
        let report = "layer  shape\nlayer1 4x8\n";
        let cold = done_line("f00d", 2, report, 0.25, 0, 0, None);
        let warm =
            done_line("f00d", 2, report, 0.10, 0, 2, Some("state/warm"));
        let cj = Json::parse(&cold).unwrap();
        let wj = Json::parse(&warm).unwrap();
        assert_eq!(wj.get("warm").unwrap().as_bool(), Some(true));
        assert_eq!(wj.get("warm_layers").unwrap().as_usize(), Some(2));
        assert_eq!(
            wj.get("warm_source").unwrap().as_str(),
            Some("state/warm")
        );
        assert_eq!(
            cj.get("report").unwrap().as_str(),
            wj.get("report").unwrap().as_str()
        );
        assert_eq!(
            cj.get("fingerprint").unwrap().as_str(),
            wj.get("fingerprint").unwrap().as_str()
        );
    }

    #[test]
    fn jobs_line_lists_journaled_requests() {
        let rows = vec![
            JobRow {
                fingerprint: "f00d".into(),
                status: "completed".into(),
                layers_done: 2,
                layers: 2,
            },
            JobRow {
                fingerprint: "beef".into(),
                status: "admitted".into(),
                layers_done: 1,
                layers: 3,
            },
        ];
        let line = jobs_line(&rows);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("type").unwrap().as_str(), Some("jobs"));
        let arr = j.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].get("status").unwrap().as_str(), Some("admitted"));
        assert_eq!(arr[1].get("layers_done").unwrap().as_usize(), Some(1));
        assert_eq!(arr[1].get("layers").unwrap().as_usize(), Some(3));
    }
}
