//! The daemon: socket listener, admission control, request handling.
//!
//! One OS thread per connection owns the write side and processes
//! requests in order; a paired *reader thread* drains the socket into
//! a channel so the daemon notices fault conditions that a blocking
//! `BufReader` would hide — a client that disconnects mid-request
//! (its in-flight run is cancelled at the next iteration boundary), a
//! slow-loris peer dribbling a partial line (timed out with a `400`),
//! or an oversized line (rejected before it can exhaust memory).
//! Compression jobs inside a request fan out through
//! [`Engine::try_compress_each`] onto the process-wide
//! [`crate::util::threadpool::WorkerPool`], so connection threads
//! block cheaply while the pool does the work.
//!
//! Admission control bounds *requests* (not jobs): up to
//! `max_inflight` compress requests run concurrently, with an optional
//! per-client quota (so one client cannot monopolise the daemon) and
//! an optional bounded wait queue; anything beyond those gets an
//! explicit `429` error line and the connection stays usable — clients
//! retry, nothing queues silently.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::cache::{CacheBudget, CacheRegistry};
use super::journal::{self, JobStatus, Journal, RecoverMode};
use super::protocol::{self, Request, SERVE_SCHEMA};
use super::warm::WarmStore;
use crate::bbo::{Algorithm, Degradation, WarmStart};
use crate::cost::BinMatrix;
use crate::engine::{Engine, JobError};
use crate::shard::{
    deterministic_report, recover_log, CheckpointLog, LayerRecord,
    ModelSpec,
};
use crate::util::cancel::{CancelCause, CancelToken};
use crate::util::json::Json;
use crate::util::lockfile::LockFile;
use crate::util::threadpool::default_workers;
use crate::util::timer::Timer;
use crate::util::{mean, percentile};

/// Hard cap on one request line; longer lines get a `400` and the
/// connection is closed (the remainder of the line would be garbage).
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// How often blocked reads and queue waits re-check cancellation.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// Where the daemon listens (and where clients connect).
#[derive(Clone, Debug)]
pub enum Endpoint {
    /// TCP `host:port`; port `0` binds a free port — read the actual
    /// one back via [`Server::local_endpoint`].
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(addr) => write!(f, "{addr}"),
            #[cfg(unix)]
            Endpoint::Unix(path) => write!(f, "{}", path.display()),
        }
    }
}

/// Admission control over in-flight compress requests: a global bound,
/// an optional per-client quota under it, and an optional bounded wait
/// queue.  Rejections are immediate and explicit (`429` to the
/// client); queued waiters poll their [`CancelToken`] so a disconnect
/// or deadline releases the queue slot promptly.
pub struct Admission {
    max: usize,
    per_client: usize,
    queue_cap: usize,
    state: Mutex<AdmState>,
    cv: Condvar,
}

#[derive(Default)]
struct AdmState {
    in_flight: usize,
    queued: usize,
    /// Per-client held slots — running *and* queued, so a client
    /// cannot monopolise the wait queue either.
    clients: HashMap<String, usize>,
}

/// Outcome of [`Admission::acquire`].
pub enum Admit<'a> {
    /// A slot was granted; it is released when the permit drops.
    Granted(Permit<'a>),
    /// The caller's per-client quota is exhausted (global capacity may
    /// still be free — another client would be admitted).
    RejectedClient {
        /// Slots this client already holds (running + queued).
        held: usize,
        /// The per-client quota.
        quota: usize,
    },
    /// Global capacity and the wait queue are both full.
    RejectedFull {
        /// Requests currently running.
        in_flight: usize,
        /// Requests currently waiting.
        queued: usize,
    },
    /// The caller's token tripped while waiting in the queue.
    Cancelled(CancelCause),
}

impl Admission {
    /// Gate admitting at most `max` concurrent requests (`0` rejects
    /// everything — useful to drain or to test rejection paths), with
    /// no per-client quota and no wait queue.
    pub fn new(max: usize) -> Admission {
        Admission::with_limits(max, 0, 0)
    }

    /// Full configuration: `per_client` caps one client's slots
    /// (running + queued; `0` = no per-client cap), `queue_cap` bounds
    /// the wait queue (`0` = reject instead of waiting).
    pub fn with_limits(
        max: usize,
        per_client: usize,
        queue_cap: usize,
    ) -> Admission {
        Admission {
            max,
            per_client: if per_client == 0 { usize::MAX } else { per_client },
            queue_cap,
            state: Mutex::new(AdmState::default()),
            cv: Condvar::new(),
        }
    }

    /// Take a slot for `client`, waiting in the bounded queue when the
    /// daemon is at capacity.  Never blocks past `cancel`: queue waits
    /// poll the token at [`POLL_INTERVAL`].
    pub fn acquire(&self, client: &str, cancel: &CancelToken) -> Admit<'_> {
        if self.max == 0 {
            return Admit::RejectedFull { in_flight: 0, queued: 0 };
        }
        let mut st = self.state.lock().unwrap();
        let held = st.clients.get(client).copied().unwrap_or(0);
        if held >= self.per_client {
            return Admit::RejectedClient { held, quota: self.per_client };
        }
        if st.in_flight >= self.max {
            if st.queued >= self.queue_cap {
                return Admit::RejectedFull {
                    in_flight: st.in_flight,
                    queued: st.queued,
                };
            }
            st.queued += 1;
            *st.clients.entry(client.to_string()).or_insert(0) += 1;
            loop {
                if let Some(cause) = cancel.cause() {
                    st.queued -= 1;
                    release_client(&mut st, client);
                    self.cv.notify_all();
                    return Admit::Cancelled(cause);
                }
                if st.in_flight < self.max {
                    st.queued -= 1;
                    st.in_flight += 1;
                    return Admit::Granted(Permit {
                        adm: self,
                        client: client.to_string(),
                    });
                }
                let (guard, _) = self
                    .cv
                    .wait_timeout(st, POLL_INTERVAL)
                    .unwrap();
                st = guard;
            }
        }
        st.in_flight += 1;
        *st.clients.entry(client.to_string()).or_insert(0) += 1;
        Admit::Granted(Permit { adm: self, client: client.to_string() })
    }

    /// Non-blocking convenience: a slot now or nothing (no queueing,
    /// anonymous client).
    pub fn try_acquire(&self) -> Option<Permit<'_>> {
        match self.acquire("", &CancelToken::never()) {
            Admit::Granted(p) => Some(p),
            _ => None,
        }
    }

    /// Requests currently holding a slot.
    pub fn in_flight(&self) -> usize {
        self.state.lock().unwrap().in_flight
    }

    /// Requests currently waiting in the queue.
    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().queued
    }

    /// The global admission bound.
    pub fn capacity(&self) -> usize {
        self.max
    }

    /// The per-client quota (`usize::MAX` when unlimited).
    pub fn client_quota(&self) -> usize {
        self.per_client
    }

    /// The wait-queue bound.
    pub fn queue_capacity(&self) -> usize {
        self.queue_cap
    }
}

fn release_client(st: &mut AdmState, client: &str) {
    if let Some(n) = st.clients.get_mut(client) {
        *n -= 1;
        if *n == 0 {
            st.clients.remove(client);
        }
    }
}

/// A held admission slot; dropping releases it (and wakes queued
/// waiters).
pub struct Permit<'a> {
    adm: &'a Admission,
    client: String,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        let mut st = self.adm.state.lock().unwrap();
        st.in_flight -= 1;
        release_client(&mut st, &self.client);
        drop(st);
        self.adm.cv.notify_all();
    }
}

/// Per-request latencies kept for the percentile stats; older samples
/// are discarded beyond this window so a long-lived daemon's memory
/// stays bounded.
const LATENCY_WINDOW: usize = 4096;

/// Daemon request counters and latency accounting.
#[derive(Default)]
pub struct Metrics {
    admitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    cancelled: AtomicU64,
    deadline: AtomicU64,
    errors: AtomicU64,
    /// Requests failed with a typed numeric error (`500`).
    degraded: AtomicU64,
    /// Jobs whose panic was contained at the pool boundary (`500`).
    panicked: AtomicU64,
    /// Accumulated [`Degradation::surrogate_failures`] over completed
    /// layers.
    surrogate_failures: AtomicU64,
    /// Accumulated [`Degradation::fallback_proposals`].
    fallback_proposals: AtomicU64,
    /// Accumulated [`Degradation::rejected_costs`].
    rejected_costs: AtomicU64,
    latencies: Mutex<Vec<f64>>,
}

impl Metrics {
    /// Zeroed counters.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    fn admit(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    fn reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    fn error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    fn cancel(&self, cause: CancelCause) {
        match cause {
            CancelCause::Cancelled => {
                self.cancelled.fetch_add(1, Ordering::Relaxed)
            }
            CancelCause::DeadlineExceeded => {
                self.deadline.fetch_add(1, Ordering::Relaxed)
            }
        };
    }

    fn degrade_request(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    fn contain_panic(&self) {
        self.panicked.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one finished layer's degraded-mode counters into the
    /// daemon totals (ISSUE 9).
    fn absorb_degradation(&self, d: Degradation) {
        if !d.any() {
            return;
        }
        self.surrogate_failures
            .fetch_add(d.surrogate_failures, Ordering::Relaxed);
        self.fallback_proposals
            .fetch_add(d.fallback_proposals, Ordering::Relaxed);
        self.rejected_costs.fetch_add(d.rejected_costs, Ordering::Relaxed);
    }

    fn complete(&self, seconds: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut lat = self.latencies.lock().unwrap();
        if lat.len() >= LATENCY_WINDOW {
            lat.drain(..LATENCY_WINDOW / 2);
        }
        lat.push(seconds);
    }

    /// Consistent snapshot of the counters and latency percentiles.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat = self.latencies.lock().unwrap().clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        MetricsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            deadline: self.deadline.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            panicked: self.panicked.load(Ordering::Relaxed),
            surrogate_failures: self
                .surrogate_failures
                .load(Ordering::Relaxed),
            fallback_proposals: self
                .fallback_proposals
                .load(Ordering::Relaxed),
            rejected_costs: self.rejected_costs.load(Ordering::Relaxed),
            latency_count: lat.len(),
            latency_mean_s: mean(&lat),
            latency_p50_s: percentile(&lat, 50.0),
            latency_p99_s: percentile(&lat, 99.0),
        }
    }
}

/// One [`Metrics::snapshot`].
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    /// Compress requests that got a slot.
    pub admitted: u64,
    /// Compress requests turned away with `429`.
    pub rejected: u64,
    /// Compress requests finished successfully.
    pub completed: u64,
    /// Admitted requests aborted because the client went away.
    pub cancelled: u64,
    /// Admitted requests aborted at their `deadline_ms`.
    pub deadline: u64,
    /// Malformed or failed requests.
    pub errors: u64,
    /// Requests failed with a typed numeric error (`500`).
    pub degraded: u64,
    /// Jobs whose panic was contained at the pool boundary (`500`).
    pub panicked: u64,
    /// Surrogate fit/draw failures degraded to random acquisition,
    /// summed over all finished layers.
    pub surrogate_failures: u64,
    /// Candidates proposed by the degraded random fallback.
    pub fallback_proposals: u64,
    /// Non-finite oracle costs quarantined before the dataset.
    pub rejected_costs: u64,
    /// Latency samples in the current window.
    pub latency_count: usize,
    /// Mean request latency over the window (seconds).
    pub latency_mean_s: f64,
    /// Median request latency (seconds).
    pub latency_p50_s: f64,
    /// 99th-percentile request latency (seconds).
    pub latency_p99_s: f64,
}

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listening endpoint.
    pub endpoint: Endpoint,
    /// Maximum concurrent compress requests (excess queues or gets
    /// `429`).
    pub max_inflight: usize,
    /// Per-client cap on held slots — running plus queued (`0` = no
    /// per-client cap).  Clients are keyed by peer IP on TCP; every
    /// Unix-socket connection is its own client.
    pub max_per_client: usize,
    /// Bound on the admission wait queue (`0` = reject immediately
    /// when at capacity, the pre-queue behaviour).
    pub queue: usize,
    /// Engine worker fan-out per request (jobs share the process-wide
    /// pool either way; this caps one request's concurrent jobs).
    pub workers: usize,
    /// Cross-request cache registry budget (unbounded by default; a
    /// zero cap on either axis disables the shared cache entirely).
    pub cache_budget: CacheBudget,
    /// How long a partially received request line may sit before the
    /// connection is rejected as a slow-loris (`0` = never).  Idle
    /// connections *between* lines are unaffected.
    pub line_timeout_ms: u64,
    /// Optional on-disk state directory; when set, an advisory
    /// [`LockFile`] (the `shard work` guard) keeps a second daemon off
    /// the same state.
    pub state_dir: Option<std::path::PathBuf>,
    /// Write-ahead journaling of compress requests (effective only
    /// with `state_dir`; on by default).  Admitted requests and their
    /// per-layer progress survive a SIGKILL and are finished by the
    /// next bind's recovery pass.
    pub journal: bool,
    /// What the bind-time recovery pass does with journaled state:
    /// replay it ([`RecoverMode::On`]), skip it ([`RecoverMode::Off`],
    /// journaling still active) or refuse to start on torn bytes
    /// ([`RecoverMode::Strict`]).
    pub recover: RecoverMode,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            endpoint: Endpoint::Tcp("127.0.0.1:7341".into()),
            max_inflight: 2,
            max_per_client: 0,
            queue: 0,
            workers: default_workers(),
            cache_budget: CacheBudget::unbounded(),
            line_timeout_ms: 10_000,
            state_dir: None,
            journal: true,
            recover: RecoverMode::On,
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        }
    }
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn connect(endpoint: &Endpoint) -> std::io::Result<Conn> {
        match endpoint {
            Endpoint::Tcp(addr) => TcpStream::connect(addr).map(Conn::Tcp),
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                UnixStream::connect(path).map(Conn::Unix)
            }
        }
    }

    fn try_clone(&self) -> std::io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            #[cfg(unix)]
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }

    fn set_read_timeout(
        &self,
        dur: Option<Duration>,
    ) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(dur),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(dur),
        }
    }

    /// Best-effort full shutdown — unblocks a reader thread parked on
    /// this socket (reads return 0/error afterwards).
    fn shutdown(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    /// Admission identity of the peer: its IP for TCP (one quota per
    /// remote host, however many connections it opens), a unique key
    /// per connection for Unix sockets (no peer identity to group by).
    fn client_key(&self, seq: u64) -> String {
        match self {
            Conn::Tcp(s) => match s.peer_addr() {
                Ok(addr) => addr.ip().to_string(),
                Err(_) => format!("tcp#{seq}"),
            },
            #[cfg(unix)]
            Conn::Unix(_) => format!("unix#{seq}"),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

struct Ctx {
    admission: Admission,
    registry: CacheRegistry,
    metrics: Metrics,
    workers: usize,
    line_timeout_ms: u64,
    stop: AtomicBool,
    conn_seq: AtomicU64,
    endpoint: Endpoint,
    durability: Option<Durability>,
    /// Per-instance surrogate-state store (`--state DIR` daemons):
    /// loads seed warm starts, finished layers save back.
    warm: Option<WarmStore>,
}

/// Counters of a journaled daemon's durability layer: what the
/// bind-time recovery pass did, plus layers served from the durable
/// checkpoint logs since.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResumeStats {
    /// Requests found admitted-but-unterminated in the journal at bind
    /// and finished by the recovery pass.
    pub recovered_requests: u64,
    /// Layers the recovery pass had to re-run (the unfinished
    /// remainder of interrupted requests).
    pub replayed_layers: u64,
    /// Layers served straight from a durable checkpoint log instead
    /// of being computed in-request.
    pub resumed_layers: u64,
    /// Torn/garbage bytes truncated from the journal and checkpoint
    /// logs at bind.
    pub dropped_bytes: u64,
}

/// Per-fingerprint status row backing the `jobs` introspection
/// request.
struct JobState {
    status: JobStatus,
    layers_done: usize,
    layers: usize,
}

/// Journaled-daemon state: the write-ahead journal (single writer —
/// the daemon, guarded by the `serve.state` lock), the jobs index for
/// introspection, and the in-process busy set that keeps two
/// concurrent requests for the same fingerprint off one checkpoint
/// log.
struct Durability {
    dir: PathBuf,
    journal: Mutex<Journal>,
    jobs: Mutex<BTreeMap<String, JobState>>,
    busy: Mutex<BTreeSet<String>>,
    recovered_requests: AtomicU64,
    replayed_layers: AtomicU64,
    resumed_layers: AtomicU64,
    dropped_bytes: AtomicU64,
}

impl Durability {
    fn stats(&self) -> ResumeStats {
        ResumeStats {
            recovered_requests: self.recovered_requests.load(Ordering::Relaxed),
            replayed_layers: self.replayed_layers.load(Ordering::Relaxed),
            resumed_layers: self.resumed_layers.load(Ordering::Relaxed),
            dropped_bytes: self.dropped_bytes.load(Ordering::Relaxed),
        }
    }

    fn set_job(&self, fp: &str, status: JobStatus, layers_done: usize, layers: usize) {
        self.jobs.lock().unwrap().insert(
            fp.to_string(),
            JobState { status, layers_done, layers },
        );
    }
}

/// Seed the shared cache with a recovered record's winning candidate:
/// the cost is already known, so later requests on the same instance
/// layer skip that evaluation.  Raw-keyed specs opt out of the shared
/// cache entirely (mirrors `handle_compress`).
fn warm_registry(registry: &CacheRegistry, spec: &ModelSpec, rec: &LayerRecord) {
    if spec.cache_key_raw {
        return;
    }
    let m = BinMatrix::new(rec.n, rec.k, rec.best_x.clone());
    registry.warm(&spec.instance_key(rec.job), &m, rec.best_y);
}

/// Open the journal and replay its crash debt: requests admitted but
/// never terminated are finished off their checkpoint prefix (only
/// unfinished layers re-run), every recovered record warms the shared
/// cache, and the jobs index is rebuilt for introspection.
fn recover_state(
    dir: &Path,
    mode: RecoverMode,
    workers: usize,
    registry: &CacheRegistry,
) -> Result<Durability> {
    let jpath = journal::journal_path(dir);
    if mode == RecoverMode::Strict {
        // Read-only pre-scan: strict mode must refuse before
        // `Journal::open` would truncate the torn tail.
        let scan = journal::recover_journal(&jpath)?;
        if scan.dropped_bytes > 0 {
            bail!(
                "{}: {} torn/garbage bytes in the journal (--recover strict)",
                jpath.display(),
                scan.dropped_bytes
            );
        }
    }
    let (journal_w, recovered) = Journal::open(&jpath)?;
    let journal_w = Mutex::new(journal_w);
    let mut dropped = recovered.dropped_bytes;
    let mut jobs = BTreeMap::new();
    let mut recovered_requests = 0u64;
    let mut replayed = 0u64;
    for entry in &recovered.entries {
        let fp = &entry.fingerprint;
        let lpath = journal::jobs_log_path(dir, fp);
        let layers_done;
        let mut status = entry.status;
        if entry.status == JobStatus::Admitted && mode != RecoverMode::Off {
            // Crash debt: finish the request durably before serving.
            // Two-phase open: strict mode must see torn bytes before
            // `commit` would truncate them.
            let mut log = CheckpointLog::recover(&lpath, fp)
                .with_context(|| format!("recovering job {fp}"))?;
            if mode == RecoverMode::Strict && log.dropped_bytes() > 0 {
                bail!(
                    "{}: {} torn/garbage bytes in the checkpoint log (--recover strict)",
                    lpath.display(),
                    log.dropped_bytes()
                );
            }
            dropped += log.dropped_bytes();
            log.commit()
                .with_context(|| format!("truncating job {fp}"))?;
            let done: BTreeSet<usize> =
                log.records().iter().map(|r| r.job).collect();
            for rec in log.records() {
                warm_registry(registry, &entry.spec, rec);
            }
            let todo: Vec<usize> = (0..entry.spec.layers)
                .filter(|l| !done.contains(l))
                .collect();
            if !todo.is_empty() {
                let mut engine_jobs = Vec::with_capacity(todo.len());
                for &layer in &todo {
                    let mut job = entry.spec.job(layer)?;
                    if !entry.spec.cache_key_raw {
                        job.shared_cache =
                            registry.get(&entry.spec.instance_key(layer));
                    }
                    engine_jobs.push(job);
                }
                // Recovery stays on the infallible entry point: a
                // panic here is a startup failure the operator should
                // see, not a request to degrade.
                let eng =
                    Engine::new(entry.spec.engine_config(workers, false));
                let mut werr: Option<std::io::Error> = None;
                eng.compress_each(engine_jobs, |i, result| {
                    let rec = LayerRecord::from_result(todo[i], &result);
                    if werr.is_none() {
                        if let Err(e) = log.append(&rec) {
                            werr = Some(e);
                        }
                    }
                });
                if let Some(e) = werr {
                    return Err(e)
                        .with_context(|| format!("replaying job {fp}"));
                }
            }
            journal_w.lock().unwrap().record_completed(fp)?;
            eprintln!(
                "serve: resumed {fp}: {} layers re-run, {} recovered from checkpoint",
                todo.len(),
                done.len()
            );
            recovered_requests += 1;
            replayed += todo.len() as u64;
            layers_done = entry.spec.layers;
            status = JobStatus::Completed;
        } else {
            // Terminated (or recovery off): read-only scan for the
            // jobs index and cache warming; bytes are left untouched.
            let scan = recover_log(&lpath, fp)?;
            if mode == RecoverMode::Strict && scan.dropped_bytes > 0 {
                bail!(
                    "{}: {} torn/garbage bytes in the checkpoint log (--recover strict)",
                    lpath.display(),
                    scan.dropped_bytes
                );
            }
            layers_done = scan.records.len();
            for rec in &scan.records {
                warm_registry(registry, &entry.spec, rec);
            }
        }
        jobs.insert(
            fp.clone(),
            JobState { status, layers_done, layers: entry.spec.layers },
        );
    }
    if recovered_requests > 0 {
        eprintln!(
            "serve: recovery pass finished {recovered_requests} interrupted request(s), {replayed} layers re-run"
        );
    }
    Ok(Durability {
        dir: dir.to_path_buf(),
        journal: journal_w,
        jobs: Mutex::new(jobs),
        busy: Mutex::new(BTreeSet::new()),
        recovered_requests: AtomicU64::new(recovered_requests),
        replayed_layers: AtomicU64::new(replayed),
        resumed_layers: AtomicU64::new(0),
        dropped_bytes: AtomicU64::new(dropped),
    })
}

/// The serve daemon: bind once, then [`Server::run`] until a
/// `shutdown` request.
pub struct Server {
    listener: Listener,
    ctx: Arc<Ctx>,
    _lock: Option<LockFile>,
}

impl Server {
    /// Bind the endpoint (taking the state lock first when configured)
    /// without serving yet.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let lock = match &cfg.state_dir {
            Some(dir) => {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
                Some(LockFile::acquire(&dir.join("serve.state"))?)
            }
            None => None,
        };
        let registry = CacheRegistry::with_budget(cfg.cache_budget);
        // Recovery runs before the listener exists: by the time a
        // client can connect, every interrupted request is finished
        // and durable.  The state lock above makes this daemon the
        // journal's single writer.
        let durability = match (&cfg.state_dir, cfg.journal) {
            (Some(dir), true) => Some(recover_state(
                dir,
                cfg.recover,
                cfg.workers.max(1),
                &registry,
            )?),
            _ => None,
        };
        // The warm store needs only the state directory, not the
        // journal: surrogate states are useful even on a daemon run
        // with journaling off.
        let warm = match &cfg.state_dir {
            Some(dir) => Some(WarmStore::open(dir)?),
            None => None,
        };
        let (listener, endpoint) = match &cfg.endpoint {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .with_context(|| format!("binding tcp {addr}"))?;
                let actual = l
                    .local_addr()
                    .with_context(|| format!("resolving {addr}"))?
                    .to_string();
                (Listener::Tcp(l), Endpoint::Tcp(actual))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let l = bind_unix(path)?;
                (Listener::Unix(l), Endpoint::Unix(path.clone()))
            }
        };
        Ok(Server {
            listener,
            ctx: Arc::new(Ctx {
                admission: Admission::with_limits(
                    cfg.max_inflight,
                    cfg.max_per_client,
                    cfg.queue,
                ),
                registry,
                metrics: Metrics::new(),
                workers: cfg.workers.max(1),
                line_timeout_ms: cfg.line_timeout_ms,
                stop: AtomicBool::new(false),
                conn_seq: AtomicU64::new(0),
                endpoint,
                durability,
                warm,
            }),
            _lock: lock,
        })
    }

    /// The resolved endpoint (actual port for `host:0` TCP binds) —
    /// what clients should connect to.
    pub fn local_endpoint(&self) -> &Endpoint {
        &self.ctx.endpoint
    }

    /// Durability counters of a journaled daemon (`None` without a
    /// journal): what the bind-time recovery pass did, plus layers
    /// served from the durable logs since.
    pub fn resume_stats(&self) -> Option<ResumeStats> {
        self.ctx.durability.as_ref().map(|d| d.stats())
    }

    /// Accept and serve connections until a `shutdown` request.  Each
    /// connection gets its own thread; in-flight requests on other
    /// connections finish writing before their threads exit, but
    /// `run` itself returns as soon as the listener stops.
    pub fn run(&self) -> Result<()> {
        loop {
            let conn = match self.listener.accept() {
                Ok(c) => c,
                Err(e) => {
                    if self.ctx.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    return Err(e).context("accepting connection");
                }
            };
            if self.ctx.stop.load(Ordering::SeqCst) {
                break;
            }
            let ctx = self.ctx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(conn, &ctx);
            });
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Endpoint::Unix(path) = &self.ctx.endpoint {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Bind a Unix socket, reclaiming a stale socket file (left by a
/// crashed daemon) after probing that nothing answers on it.
#[cfg(unix)]
fn bind_unix(path: &std::path::Path) -> Result<UnixListener> {
    match UnixListener::bind(path) {
        Ok(l) => Ok(l),
        Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
            if UnixStream::connect(path).is_ok() {
                bail!(
                    "{}: a serve daemon is already listening",
                    path.display()
                );
            }
            std::fs::remove_file(path)
                .with_context(|| format!("removing stale {}", path.display()))?;
            UnixListener::bind(path)
                .with_context(|| format!("binding unix {}", path.display()))
        }
        Err(e) => {
            Err(e).with_context(|| format!("binding unix {}", path.display()))
        }
    }
}

/// What the reader thread feeds the connection's request loop.
enum ConnEvent {
    /// One complete request line (newline stripped).
    Line(String),
    /// A partial line sat unfinished past the slow-loris timeout.
    SlowLine,
    /// A single line exceeded [`MAX_LINE_BYTES`].
    Oversized,
    /// Clean close or read error — the peer is gone.
    Eof,
}

/// Drain the socket into `tx`, watching for the fault conditions the
/// request loop cannot see while it is busy: on EOF/error the current
/// request's token (in `cancel_slot`) is tripped *immediately*, which
/// is what turns a client disconnect into a cancelled run instead of
/// hours of work written to a dead socket.
fn reader_loop(
    mut rd: Conn,
    tx: mpsc::Sender<ConnEvent>,
    cancel_slot: Arc<Mutex<Option<CancelToken>>>,
    peer_gone: Arc<AtomicBool>,
    line_timeout_ms: u64,
) {
    // On EOF/error: flag first, then trip whatever token is current.
    // `handle_line` re-checks the flag right after publishing a fresh
    // token, so a request whose client vanished before it even started
    // is cancelled too, whichever order the two threads ran in.
    let gone = |slot: &Mutex<Option<CancelToken>>| {
        peer_gone.store(true, Ordering::SeqCst);
        if let Some(tok) = slot.lock().unwrap().as_ref() {
            tok.cancel();
        }
    };
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut partial_since: Option<Instant> = None;
    loop {
        match rd.read(&mut chunk) {
            Ok(0) => {
                gone(&cancel_slot);
                let _ = tx.send(ConnEvent::Eof);
                return;
            }
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
                    let rest = buf.split_off(pos + 1);
                    buf.pop(); // the newline
                    if buf.last() == Some(&b'\r') {
                        buf.pop(); // CRLF clients, as BufRead::lines
                    }
                    if buf.len() > MAX_LINE_BYTES {
                        let _ = tx.send(ConnEvent::Oversized);
                        return;
                    }
                    let line =
                        String::from_utf8_lossy(&buf).into_owned();
                    buf = rest;
                    if tx.send(ConnEvent::Line(line)).is_err() {
                        return;
                    }
                }
                if buf.len() > MAX_LINE_BYTES {
                    let _ = tx.send(ConnEvent::Oversized);
                    return;
                }
                partial_since = if buf.is_empty() {
                    None
                } else {
                    partial_since.or_else(|| Some(Instant::now()))
                };
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if let (Some(t0), true) =
                    (partial_since, line_timeout_ms > 0)
                {
                    if t0.elapsed()
                        >= Duration::from_millis(line_timeout_ms)
                    {
                        let _ = tx.send(ConnEvent::SlowLine);
                        return;
                    }
                }
            }
            Err(_) => {
                gone(&cancel_slot);
                let _ = tx.send(ConnEvent::Eof);
                return;
            }
        }
    }
}

fn handle_conn(conn: Conn, ctx: &Ctx) -> std::io::Result<()> {
    let seq = ctx.conn_seq.fetch_add(1, Ordering::Relaxed);
    let client = conn.client_key(seq);
    let reader_conn = conn.try_clone()?;
    reader_conn.set_read_timeout(Some(POLL_INTERVAL))?;
    let cancel_slot: Arc<Mutex<Option<CancelToken>>> =
        Arc::new(Mutex::new(None));
    let peer_gone = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel();
    let reader = {
        let slot = cancel_slot.clone();
        let gone = peer_gone.clone();
        let timeout = ctx.line_timeout_ms;
        std::thread::spawn(move || {
            reader_loop(reader_conn, tx, slot, gone, timeout)
        })
    };
    let mut writer = conn;
    // v2 greeting: schema + capabilities, written before reading
    // anything so clients can negotiate.  Best-effort — a peer that
    // vanished already surfaces as EOF below.
    let _ = writeln!(writer, "{}", protocol::hello_line());
    let _ = writer.flush();
    let mut result: std::io::Result<()> = Ok(());
    loop {
        match rx.recv() {
            Err(_) | Ok(ConnEvent::Eof) => break,
            Ok(ConnEvent::Oversized) => {
                ctx.metrics.error();
                let _ = writeln!(
                    writer,
                    "{}",
                    protocol::error_line(
                        400,
                        &format!(
                            "request line exceeds {MAX_LINE_BYTES} bytes"
                        ),
                    )
                );
                let _ = writer.flush();
                break;
            }
            Ok(ConnEvent::SlowLine) => {
                ctx.metrics.error();
                let _ = writeln!(
                    writer,
                    "{}",
                    protocol::error_line(
                        400,
                        &format!(
                            "request line not completed within {} ms",
                            ctx.line_timeout_ms
                        ),
                    )
                );
                let _ = writer.flush();
                break;
            }
            Ok(ConnEvent::Line(line)) => {
                if line.trim().is_empty() {
                    continue;
                }
                let step = handle_line(
                    &line,
                    &mut writer,
                    ctx,
                    &client,
                    &cancel_slot,
                    &peer_gone,
                )
                .and_then(|shutdown| {
                    writer.flush()?;
                    Ok(shutdown)
                });
                match step {
                    Ok(false) => {}
                    Ok(true) => break,
                    Err(e) => {
                        result = Err(e);
                        break;
                    }
                }
            }
        }
    }
    // Unblock and reap the reader before the thread exits.
    writer.shutdown();
    let _ = reader.join();
    result
}

fn handle_line(
    line: &str,
    out: &mut Conn,
    ctx: &Ctx,
    client: &str,
    cancel_slot: &Mutex<Option<CancelToken>>,
    peer_gone: &AtomicBool,
) -> std::io::Result<bool> {
    match Request::parse(line) {
        Err(e) => {
            ctx.metrics.error();
            writeln!(out, "{}", protocol::error_line(400, &format!("{e:#}")))?;
        }
        Ok(Request::Ping) => writeln!(out, "{}", protocol::pong_line())?,
        Ok(Request::Stats) => writeln!(out, "{}", stats_line(ctx))?,
        Ok(Request::Jobs) => writeln!(out, "{}", jobs_reply(ctx))?,
        Ok(Request::Shutdown) => {
            writeln!(out, "{}", protocol::bye_line())?;
            out.flush()?;
            ctx.stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop so it observes the stop flag.
            let _ = Conn::connect(&ctx.endpoint);
            return Ok(true);
        }
        Ok(Request::Compress { spec, deadline_ms }) => {
            let cancel = match deadline_ms {
                Some(ms) => {
                    CancelToken::with_deadline(Duration::from_millis(ms))
                }
                None => CancelToken::never(),
            };
            // Publish the token so the reader thread can trip it the
            // moment the peer disappears; retire it afterwards so a
            // disconnect between requests cancels nothing stale.  The
            // flag re-check closes the race where the peer vanished
            // before this request was even picked up.
            *cancel_slot.lock().unwrap() = Some(cancel.clone());
            if peer_gone.load(Ordering::SeqCst) {
                cancel.cancel();
            }
            let r =
                handle_compress(&spec, &cancel, out, ctx, client);
            *cancel_slot.lock().unwrap() = None;
            r?;
        }
    }
    Ok(false)
}

fn handle_compress(
    spec: &ModelSpec,
    cancel: &CancelToken,
    out: &mut Conn,
    ctx: &Ctx,
    client: &str,
) -> std::io::Result<()> {
    let permit = match ctx.admission.acquire(client, cancel) {
        Admit::Granted(p) => p,
        Admit::RejectedClient { held, quota } => {
            ctx.metrics.reject();
            let msg = format!(
                "client quota reached ({held} of {quota} requests held \
                 by {client}); retry later"
            );
            writeln!(out, "{}", protocol::error_line(429, &msg))?;
            return Ok(());
        }
        Admit::RejectedFull { in_flight, queued } => {
            ctx.metrics.reject();
            let msg = format!(
                "at capacity ({in_flight} of {} requests in flight, \
                 {queued} of {} queued); retry later",
                ctx.admission.capacity(),
                ctx.admission.queue_capacity(),
            );
            writeln!(out, "{}", protocol::error_line(429, &msg))?;
            return Ok(());
        }
        Admit::Cancelled(cause) => {
            ctx.metrics.cancel(cause);
            writeln!(
                out,
                "{}",
                protocol::cancelled_line(
                    cause,
                    &spec.fingerprint(),
                    0,
                    0.0,
                )
            )?;
            return Ok(());
        }
    };
    ctx.metrics.admit();
    let timer = Timer::start();
    let fp = spec.fingerprint();
    // Pre-start check: a deadline that expired while queued (or a
    // `deadline_ms` of ~0) must not launch any job — the permit is
    // released on return, never leaked.
    if let Some(cause) = cancel.cause() {
        ctx.metrics.cancel(cause);
        drop(permit);
        writeln!(
            out,
            "{}",
            protocol::cancelled_line(cause, &fp, 0, timer.seconds())
        )?;
        return Ok(());
    }
    // Durable attach (journaled daemons only): the fingerprint's
    // checkpoint log carries any prior progress, so layers already on
    // disk are streamed back instead of recomputed.  A concurrent
    // identical request or a failed open degrades to plain serving.
    let mut durable = ctx
        .durability
        .as_ref()
        .and_then(|d| DurableReq::begin(d, spec, &fp));
    let recovered: Vec<LayerRecord> = match durable.as_mut() {
        Some(d) => {
            let recs = d.log.take_records();
            d.resumed = recs.len();
            recs
        }
        None => Vec::new(),
    };
    let resumed = recovered.len();
    let done_layers: BTreeSet<usize> =
        recovered.iter().map(|r| r.job).collect();
    let mut todo: Vec<usize> = Vec::new();
    let mut jobs = Vec::with_capacity(spec.layers);
    // Surrogate warm starts (`--state` daemons): the expected state
    // kind comes from the spec's algorithm; a stored state that does
    // not match it (or the instance's bit width) degrades to a cold
    // start with a logged warning instead of a failed request.
    let expected_kind = Algorithm::by_name(&spec.algo)
        .and_then(|a| a.state_kind());
    let mut warm_layers = 0usize;
    for layer in 0..spec.layers {
        if done_layers.contains(&layer) {
            continue;
        }
        match spec.job(layer) {
            Ok(mut job) => {
                job.cancel = cancel.clone();
                // Cross-request warm store: per instance-layer, only
                // for canonical-key specs (exact-key jobs drop the
                // shared level anyway — see `run_job`), and only when
                // the registry's budget allows caching at all.
                if !spec.cache_key_raw {
                    job.shared_cache =
                        ctx.registry.get(&spec.instance_key(layer));
                }
                if let Some(ws) = &ctx.warm {
                    job.export_state = true;
                    let key = spec.instance_key(layer);
                    if let Some(w) = ws.load(&key) {
                        if w.state.n_bits == spec.n * spec.k
                            && w.state
                                .compatible_kind(expected_kind.as_deref())
                        {
                            job.warm_start = Some(w);
                            warm_layers += 1;
                        } else {
                            eprintln!(
                                "serve: warm: {key}: stored state does \
                                 not fit the spec (kind/bits); cold \
                                 start"
                            );
                        }
                    }
                }
                todo.push(layer);
                jobs.push(job);
            }
            Err(e) => {
                ctx.metrics.error();
                writeln!(
                    out,
                    "{}",
                    protocol::error_line(400, &format!("{e:#}"))
                )?;
                return Ok(());
            }
        }
    }
    // Write-ahead admit: journaled before any layer runs, so a crash
    // from here on leaves exactly the state the bind-time recovery
    // pass finishes.  Requests served entirely from the log write
    // nothing.
    if let Some(d) = durable.as_mut() {
        if !jobs.is_empty() && !d.record_admitted(spec) {
            durable = None;
        }
    }
    let mut records: Vec<LayerRecord> = Vec::with_capacity(spec.layers);
    let mut io_err: Option<std::io::Error> = None;
    // Stream the recovered prefix first; the lines are byte-identical
    // to freshly computed ones because records are pure functions of
    // the spec.
    // A strict-serialisation failure on a record (non-finite float
    // field — can't happen for a completed run, which guarantees a
    // finite best cost, but handled defensively) is treated like a
    // dead peer: the stream is aborted and the request fails.
    let emit =
        |rec: &LayerRecord,
         io_err: &mut Option<std::io::Error>,
         out: &mut Conn,
         cancel: &CancelToken| {
            if io_err.is_some() {
                return;
            }
            let step = rec
                .to_json_line(&fp)
                .map_err(std::io::Error::other)
                .and_then(|line| writeln!(out, "{line}"));
            if let Err(e) = step {
                *io_err = Some(e);
                // The write side is dead: stop burning pool time on a
                // stream nobody reads.
                cancel.cancel();
            }
        };
    for rec in recovered {
        emit(&rec, &mut io_err, out, cancel);
        records.push(rec);
    }
    let outcome = if jobs.is_empty() {
        Ok(())
    } else {
        // `contain_panics`: a panicking job must become a typed `500`
        // on this request, never take the daemon down (ISSUE 9).
        let eng = Engine::new(spec.engine_config(ctx.workers, true));
        eng.try_compress_each(jobs, |i, result| {
            ctx.metrics.absorb_degradation(result.run.degradation);
            let rec = LayerRecord::from_result(todo[i], &result);
            // Persist the layer's end-of-run surrogate state so later
            // requests on the same instance warm-start from it.  A
            // save failure costs future warmth, never this request.
            if let (Some(ws), Some(st)) = (&ctx.warm, &result.state) {
                let key = spec.instance_key(todo[i]);
                let w = WarmStart::new(st.clone()).with_prev_best(
                    result.run.best_x.clone(),
                    result.run.best_y,
                );
                if let Err(e) = ws.save(&key, &w) {
                    eprintln!("serve: warm: {key}: save failed: {e}");
                }
            }
            // Checkpoint (append + fsync) before the client sees the
            // line: whatever was streamed is always durable.
            if let Some(d) = durable.as_mut() {
                d.append(&rec);
            }
            emit(&rec, &mut io_err, out, cancel);
            records.push(rec);
        })
    };
    // Release the slot before the (possibly dead-socket) trailer write
    // and the registry sweep — queued waiters should not wait on I/O.
    drop(permit);
    match outcome {
        Err(JobError::Cancelled(cause)) => {
            if let Some(d) = durable.as_mut() {
                d.finish_cancelled();
            }
            ctx.metrics.cancel(cause);
            // Best-effort: on a disconnect this line goes nowhere.
            let _ = writeln!(
                out,
                "{}",
                protocol::cancelled_line(
                    cause,
                    &fp,
                    records.len(),
                    timer.seconds(),
                )
            );
            ctx.registry.enforce();
            match io_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        }
        Err(
            err @ (JobError::Numeric(_)
            | JobError::Panicked { .. }
            | JobError::Warm(_)),
        ) => {
            // A faulted job: typed `500`, daemon keeps serving.  The
            // journal entry is terminated so the bind-time recovery
            // pass does not replay a job that would fault again.
            // (`Warm` is belt-and-braces: the compatibility pre-check
            // above should keep a bad stored state from ever reaching
            // the engine.)
            if let Some(d) = durable.as_mut() {
                d.finish_cancelled();
            }
            match &err {
                JobError::Panicked { .. } => ctx.metrics.contain_panic(),
                JobError::Numeric(_) => ctx.metrics.degrade_request(),
                _ => {}
            }
            ctx.metrics.error();
            let _ = writeln!(
                out,
                "{}",
                protocol::error_line(500, &format!("{err}"))
            );
            ctx.registry.enforce();
            match io_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        }
        Ok(()) => {
            // Every layer is on disk by now, so the journal terminal
            // marker is correct whether or not the peer survived.
            if let Some(d) = durable.as_mut() {
                d.finish_completed();
            }
            if let Some(e) = io_err {
                // All jobs finished but the peer vanished before the
                // tail could be written: account it as a cancellation.
                ctx.metrics.cancel(CancelCause::Cancelled);
                ctx.registry.enforce();
                return Err(e);
            }
            // Recovered prefix + freshly computed remainder, merged
            // into layer order (a no-op for uninterrupted runs).
            records.sort_by_key(|r| r.job);
            let report = deterministic_report(&records);
            let warm_src = ctx
                .warm
                .as_ref()
                .map(|w| w.dir().display().to_string());
            writeln!(
                out,
                "{}",
                protocol::done_line(
                    &fp,
                    records.len(),
                    &report,
                    timer.seconds(),
                    resumed,
                    warm_layers,
                    if warm_layers > 0 {
                        warm_src.as_deref()
                    } else {
                        None
                    },
                )
            )?;
            ctx.metrics.complete(timer.seconds());
            ctx.registry.enforce();
            Ok(())
        }
    }
}

/// One request's handle on the durability layer: the open checkpoint
/// log (exclusive via its lockfile plus the in-process busy set) and
/// the journal bookkeeping around it.  Dropping releases the busy
/// slot on every exit path.
struct DurableReq<'a> {
    dur: &'a Durability,
    fp: String,
    log: CheckpointLog,
    layers: usize,
    resumed: usize,
    admitted: bool,
    append_failed: bool,
    appended: usize,
}

impl<'a> DurableReq<'a> {
    /// Attach the request to its durable log, or `None` to degrade to
    /// plain (un-journaled) serving: an identical request is already
    /// in flight, or opening the log failed — availability beats
    /// durability for a live request.
    fn begin(
        dur: &'a Durability,
        spec: &ModelSpec,
        fp: &str,
    ) -> Option<DurableReq<'a>> {
        if !dur.busy.lock().unwrap().insert(fp.to_string()) {
            return None;
        }
        match CheckpointLog::open(&journal::jobs_log_path(&dur.dir, fp), fp)
        {
            Ok(log) => Some(DurableReq {
                dur,
                fp: fp.to_string(),
                log,
                layers: spec.layers,
                resumed: 0,
                admitted: false,
                append_failed: false,
                appended: 0,
            }),
            Err(e) => {
                eprintln!(
                    "serve: journal: {fp}: {e:#}; serving without durability"
                );
                dur.busy.lock().unwrap().remove(fp);
                None
            }
        }
    }

    /// Write-ahead marker: the full spec goes into the journal before
    /// any layer runs.  `false` means the write failed and the caller
    /// should degrade to plain serving.
    fn record_admitted(&mut self, spec: &ModelSpec) -> bool {
        match self
            .dur
            .journal
            .lock()
            .unwrap()
            .record_admitted(spec, &self.fp)
        {
            Ok(()) => {
                self.admitted = true;
                self.dur.set_job(
                    &self.fp,
                    JobStatus::Admitted,
                    self.resumed,
                    self.layers,
                );
                true
            }
            Err(e) => {
                eprintln!(
                    "serve: journal: {}: {e}; serving without durability",
                    self.fp
                );
                false
            }
        }
    }

    /// Checkpoint one computed record (append + fsync).  A failure
    /// stops checkpointing — the admit marker stays, so a later
    /// recovery pass re-runs whatever is missing — without failing
    /// the live request.
    fn append(&mut self, rec: &LayerRecord) {
        if self.append_failed {
            return;
        }
        match self.log.append(rec) {
            Ok(()) => self.appended += 1,
            Err(e) => {
                eprintln!(
                    "serve: journal: {}: checkpoint append failed: {e}",
                    self.fp
                );
                self.append_failed = true;
            }
        }
    }

    fn finish_completed(&mut self) {
        self.dur
            .resumed_layers
            .fetch_add(self.resumed as u64, Ordering::Relaxed);
        if self.admitted && !self.append_failed {
            if let Err(e) =
                self.dur.journal.lock().unwrap().record_completed(&self.fp)
            {
                eprintln!("serve: journal: {}: {e}", self.fp);
                return;
            }
            self.dur.set_job(
                &self.fp,
                JobStatus::Completed,
                self.layers,
                self.layers,
            );
        }
    }

    fn finish_cancelled(&mut self) {
        if self.admitted {
            if let Err(e) =
                self.dur.journal.lock().unwrap().record_cancelled(&self.fp)
            {
                eprintln!("serve: journal: {}: {e}", self.fp);
                return;
            }
            self.dur.set_job(
                &self.fp,
                JobStatus::Cancelled,
                self.resumed + self.appended,
                self.layers,
            );
        }
    }
}

impl Drop for DurableReq<'_> {
    fn drop(&mut self) {
        self.dur.busy.lock().unwrap().remove(&self.fp);
    }
}

/// The `jobs` introspection reply: one row per journaled fingerprint
/// (always empty without a journal).
fn jobs_reply(ctx: &Ctx) -> String {
    let rows: Vec<protocol::JobRow> = match &ctx.durability {
        None => Vec::new(),
        Some(d) => d
            .jobs
            .lock()
            .unwrap()
            .iter()
            .map(|(fp, st)| protocol::JobRow {
                fingerprint: fp.clone(),
                status: st.status.label().to_string(),
                layers_done: st.layers_done,
                layers: st.layers,
            })
            .collect(),
    };
    protocol::jobs_line(&rows)
}

fn stats_line(ctx: &Ctx) -> String {
    let reg = ctx.registry.stats();
    let budget = ctx.registry.budget();
    let m = ctx.metrics.snapshot();
    let opt_num = |v: Option<usize>| match v {
        Some(n) => Json::Num(n as f64),
        None => Json::Null,
    };
    Json::obj(vec![
        ("admitted", Json::Num(m.admitted as f64)),
        ("cache_budget_bytes", opt_num(budget.bytes)),
        ("cache_budget_entries", opt_num(budget.entries)),
        ("cache_bytes", Json::Num(reg.bytes as f64)),
        ("cache_caches", Json::Num(reg.caches as f64)),
        ("cache_entries", Json::Num(reg.entries as f64)),
        ("cache_evicted_caches", Json::Num(reg.evicted_caches as f64)),
        (
            "cache_evicted_entries",
            Json::Num(reg.evicted_entries as f64),
        ),
        ("cache_hit_rate", Json::Num(reg.cache.hit_rate())),
        ("cache_hits", Json::Num(reg.cache.hits as f64)),
        ("cache_misses", Json::Num(reg.cache.misses as f64)),
        ("cancelled", Json::Num(m.cancelled as f64)),
        ("completed", Json::Num(m.completed as f64)),
        ("deadline", Json::Num(m.deadline as f64)),
        (
            "degradation",
            Json::obj(vec![
                (
                    "fallback_proposals",
                    Json::Num(m.fallback_proposals as f64),
                ),
                ("rejected_costs", Json::Num(m.rejected_costs as f64)),
                (
                    "surrogate_failures",
                    Json::Num(m.surrogate_failures as f64),
                ),
            ]),
        ),
        ("degraded", Json::Num(m.degraded as f64)),
        ("errors", Json::Num(m.errors as f64)),
        ("panicked", Json::Num(m.panicked as f64)),
        ("inflight", Json::Num(ctx.admission.in_flight() as f64)),
        ("latency_count", Json::Num(m.latency_count as f64)),
        ("latency_mean_s", Json::Num(m.latency_mean_s)),
        ("latency_p50_s", Json::Num(m.latency_p50_s)),
        ("latency_p99_s", Json::Num(m.latency_p99_s)),
        ("max_inflight", Json::Num(ctx.admission.capacity() as f64)),
        (
            "max_per_client",
            match ctx.admission.client_quota() {
                usize::MAX => Json::Null,
                q => Json::Num(q as f64),
            },
        ),
        ("queue", Json::Num(ctx.admission.queue_capacity() as f64)),
        ("queued", Json::Num(ctx.admission.queued() as f64)),
        ("rejected", Json::Num(m.rejected as f64)),
        (
            "resume",
            match &ctx.durability {
                None => Json::Null,
                Some(d) => {
                    let r = d.stats();
                    Json::obj(vec![
                        (
                            "dropped_bytes",
                            Json::Num(r.dropped_bytes as f64),
                        ),
                        (
                            "recovered_requests",
                            Json::Num(r.recovered_requests as f64),
                        ),
                        (
                            "replayed_layers",
                            Json::Num(r.replayed_layers as f64),
                        ),
                        (
                            "resumed_layers",
                            Json::Num(r.resumed_layers as f64),
                        ),
                    ])
                }
            },
        ),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("stats".into())),
        ("workers", Json::Num(ctx.workers as f64)),
    ])
    .to_string()
}

/// Client side: send one request line to a daemon and collect the
/// response lines, up to and including the terminal typed line
/// (`done`, `cancelled`, `deadline`, `stats`, `pong`, `bye` or
/// `error`).
///
/// Speaks v2: a leading `hello` greeting (which is typed, and would
/// otherwise read as an instant response terminal) is consumed and
/// dropped before the response stream proper.  Against a pre-hello
/// daemon the first line is simply a response line and is kept — the
/// client degrades gracefully rather than demanding a greeting.
pub fn request(endpoint: &Endpoint, line: &str) -> Result<Vec<String>> {
    let mut conn = Conn::connect(endpoint)
        .with_context(|| format!("connecting to {endpoint}"))?;
    conn.write_all(line.as_bytes())?;
    conn.write_all(b"\n")?;
    conn.flush()?;
    let reader = BufReader::new(conn.try_clone()?);
    let mut lines = Vec::new();
    let mut first = true;
    for l in reader.lines() {
        let l = l?;
        if l.trim().is_empty() {
            continue;
        }
        if std::mem::take(&mut first) && protocol::is_hello(&l) {
            continue;
        }
        let terminal = protocol::is_terminal(&l);
        lines.push(l);
        if terminal {
            return Ok(lines);
        }
    }
    bail!("connection closed before a terminal response line");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_counts_and_releases_slots() {
        let adm = Admission::new(2);
        assert_eq!((adm.capacity(), adm.in_flight()), (2, 0));
        let p1 = adm.try_acquire().unwrap();
        let p2 = adm.try_acquire().unwrap();
        assert_eq!(adm.in_flight(), 2);
        assert!(adm.try_acquire().is_none(), "over capacity");
        drop(p1);
        assert_eq!(adm.in_flight(), 1);
        let p3 = adm.try_acquire().unwrap();
        assert!(adm.try_acquire().is_none());
        drop(p2);
        drop(p3);
        assert_eq!(adm.in_flight(), 0);
    }

    #[test]
    fn zero_capacity_rejects_everything() {
        let adm = Admission::new(0);
        assert!(adm.try_acquire().is_none());
        assert!(matches!(
            adm.acquire("a", &CancelToken::never()),
            Admit::RejectedFull { .. }
        ));
    }

    #[test]
    fn per_client_quota_spares_other_clients() {
        let adm = Admission::with_limits(4, 1, 0);
        let tok = CancelToken::never();
        let _a = match adm.acquire("alice", &tok) {
            Admit::Granted(p) => p,
            _ => panic!("first slot must be granted"),
        };
        // Alice is at quota although global capacity remains.
        match adm.acquire("alice", &tok) {
            Admit::RejectedClient { held, quota } => {
                assert_eq!((held, quota), (1, 1));
            }
            _ => panic!("alice must be quota-rejected"),
        }
        // Bob is unaffected.
        assert!(matches!(adm.acquire("bob", &tok), Admit::Granted(_)));
        assert_eq!(adm.in_flight(), 2);
    }

    #[test]
    fn quota_frees_up_when_the_permit_drops() {
        let adm = Admission::with_limits(2, 1, 0);
        let tok = CancelToken::never();
        let p = match adm.acquire("c", &tok) {
            Admit::Granted(p) => p,
            _ => panic!("grant"),
        };
        assert!(matches!(
            adm.acquire("c", &tok),
            Admit::RejectedClient { .. }
        ));
        drop(p);
        assert!(matches!(adm.acquire("c", &tok), Admit::Granted(_)));
    }

    #[test]
    fn queue_admits_after_a_release() {
        let adm = Arc::new(Admission::with_limits(1, 0, 1));
        let tok = CancelToken::never();
        let p = match adm.acquire("a", &tok) {
            Admit::Granted(p) => p,
            _ => panic!("grant"),
        };
        // Drop the held permit shortly after the waiter queues.
        let adm2 = Arc::clone(&adm);
        let waiter = std::thread::spawn(move || {
            matches!(
                adm2.acquire("b", &CancelToken::never()),
                Admit::Granted(_)
            )
        });
        while adm.queued() == 0 {
            std::thread::yield_now();
        }
        drop(p);
        assert!(waiter.join().unwrap(), "queued waiter must be granted");
        assert_eq!(adm.queued(), 0);
    }

    #[test]
    fn queue_overflow_rejects_with_depths() {
        let adm = Arc::new(Admission::with_limits(1, 0, 1));
        let tok = CancelToken::never();
        let _p = match adm.acquire("a", &tok) {
            Admit::Granted(p) => p,
            _ => panic!("grant"),
        };
        let adm2 = Arc::clone(&adm);
        let queued_tok = CancelToken::never();
        let qt = queued_tok.clone();
        let waiter = std::thread::spawn(move || {
            match adm2.acquire("b", &qt) {
                Admit::Cancelled(cause) => Some(cause),
                _ => None,
            }
        });
        while adm.queued() == 0 {
            std::thread::yield_now();
        }
        // Queue of 1 is full: the next caller bounces immediately.
        match adm.acquire("c", &tok) {
            Admit::RejectedFull { in_flight, queued } => {
                assert_eq!((in_flight, queued), (1, 1));
            }
            _ => panic!("queue overflow must reject"),
        }
        // Cancel the waiter so the test tears down promptly.
        queued_tok.cancel();
        assert_eq!(waiter.join().unwrap(), Some(CancelCause::Cancelled));
        assert_eq!((adm.queued(), adm.in_flight()), (0, 1));
    }

    #[test]
    fn expired_deadline_cancels_a_queued_waiter() {
        let adm = Admission::with_limits(1, 0, 4);
        let _p = match adm.acquire("a", &CancelToken::never()) {
            Admit::Granted(p) => p,
            _ => panic!("grant"),
        };
        let tok = CancelToken::with_deadline(Duration::from_millis(0));
        assert!(matches!(
            adm.acquire("b", &tok),
            Admit::Cancelled(CancelCause::DeadlineExceeded)
        ));
        assert_eq!(adm.queued(), 0);
    }

    #[test]
    fn metrics_percentiles_over_the_window() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.complete(i as f64 / 100.0);
        }
        m.reject();
        m.error();
        m.cancel(CancelCause::Cancelled);
        m.cancel(CancelCause::DeadlineExceeded);
        m.cancel(CancelCause::DeadlineExceeded);
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.errors, 1);
        assert_eq!((s.cancelled, s.deadline), (1, 2));
        assert_eq!(s.latency_count, 100);
        assert!((s.latency_p50_s - 0.5).abs() < 1e-12);
        assert!((s.latency_p99_s - 0.99).abs() < 1e-12);
        assert!((s.latency_mean_s - 0.505).abs() < 1e-12);
    }

    #[test]
    fn latency_window_stays_bounded() {
        let m = Metrics::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            m.complete(i as f64);
        }
        let s = m.snapshot();
        assert!(s.latency_count <= LATENCY_WINDOW);
        assert_eq!(s.completed as usize, LATENCY_WINDOW + 10);
    }
}
