//! The daemon: socket listener, admission control, request handling.
//!
//! One OS thread per connection reads request lines and answers them
//! in order; compression jobs inside a request fan out through
//! [`Engine::compress_each`] onto the process-wide
//! [`crate::util::threadpool::WorkerPool`], so connection threads
//! block cheaply while the pool does the work.  Admission control
//! bounds *requests* (not jobs): up to `max_inflight` compress
//! requests run concurrently, later ones get an explicit `429` error
//! line and the connection stays usable — clients retry, nothing
//! queues silently.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::cache::CacheRegistry;
use super::protocol::{self, Request, SERVE_SCHEMA};
use crate::engine::{Engine, EngineConfig};
use crate::shard::{deterministic_report, LayerRecord, ModelSpec};
use crate::util::json::Json;
use crate::util::lockfile::LockFile;
use crate::util::threadpool::default_workers;
use crate::util::timer::Timer;
use crate::util::{mean, percentile};

/// Where the daemon listens (and where clients connect).
#[derive(Clone, Debug)]
pub enum Endpoint {
    /// TCP `host:port`; port `0` binds a free port — read the actual
    /// one back via [`Server::local_endpoint`].
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(addr) => write!(f, "{addr}"),
            #[cfg(unix)]
            Endpoint::Unix(path) => write!(f, "{}", path.display()),
        }
    }
}

/// Counting-semaphore admission control over in-flight compress
/// requests.  [`Admission::try_acquire`] never blocks: a full daemon
/// answers `429` instead of queueing work invisibly.
pub struct Admission {
    max: usize,
    cur: AtomicUsize,
}

impl Admission {
    /// Gate admitting at most `max` concurrent requests (`0` rejects
    /// everything — useful to drain or to test rejection paths).
    pub fn new(max: usize) -> Admission {
        Admission { max, cur: AtomicUsize::new(0) }
    }

    /// Take a slot if one is free.  The slot is released when the
    /// returned [`Permit`] drops.
    pub fn try_acquire(&self) -> Option<Permit<'_>> {
        loop {
            let c = self.cur.load(Ordering::Acquire);
            if c >= self.max {
                return None;
            }
            if self
                .cur
                .compare_exchange(
                    c,
                    c + 1,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return Some(Permit { inner: self });
            }
        }
    }

    /// Requests currently holding a slot (the queue-depth stat).
    pub fn in_flight(&self) -> usize {
        self.cur.load(Ordering::Acquire)
    }

    /// The admission bound.
    pub fn capacity(&self) -> usize {
        self.max
    }
}

/// A held admission slot; dropping releases it.
pub struct Permit<'a> {
    inner: &'a Admission,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.inner.cur.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Per-request latencies kept for the percentile stats; older samples
/// are discarded beyond this window so a long-lived daemon's memory
/// stays bounded.
const LATENCY_WINDOW: usize = 4096;

/// Daemon request counters and latency accounting.
#[derive(Default)]
pub struct Metrics {
    admitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    latencies: Mutex<Vec<f64>>,
}

impl Metrics {
    /// Zeroed counters.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    fn admit(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    fn reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    fn error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    fn complete(&self, seconds: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut lat = self.latencies.lock().unwrap();
        if lat.len() >= LATENCY_WINDOW {
            lat.drain(..LATENCY_WINDOW / 2);
        }
        lat.push(seconds);
    }

    /// Consistent snapshot of the counters and latency percentiles.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat = self.latencies.lock().unwrap().clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        MetricsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            latency_count: lat.len(),
            latency_mean_s: mean(&lat),
            latency_p50_s: percentile(&lat, 50.0),
            latency_p99_s: percentile(&lat, 99.0),
        }
    }
}

/// One [`Metrics::snapshot`].
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    /// Compress requests that got a slot.
    pub admitted: u64,
    /// Compress requests turned away with `429`.
    pub rejected: u64,
    /// Compress requests finished successfully.
    pub completed: u64,
    /// Malformed or failed requests.
    pub errors: u64,
    /// Latency samples in the current window.
    pub latency_count: usize,
    /// Mean request latency over the window (seconds).
    pub latency_mean_s: f64,
    /// Median request latency (seconds).
    pub latency_p50_s: f64,
    /// 99th-percentile request latency (seconds).
    pub latency_p99_s: f64,
}

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listening endpoint.
    pub endpoint: Endpoint,
    /// Maximum concurrent compress requests (excess gets `429`).
    pub max_inflight: usize,
    /// Engine worker fan-out per request (jobs share the process-wide
    /// pool either way; this caps one request's concurrent jobs).
    pub workers: usize,
    /// Optional on-disk state directory; when set, an advisory
    /// [`LockFile`] (the `shard work` guard) keeps a second daemon off
    /// the same state.
    pub state_dir: Option<std::path::PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            endpoint: Endpoint::Tcp("127.0.0.1:7341".into()),
            max_inflight: 2,
            workers: default_workers(),
            state_dir: None,
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
        }
    }
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn connect(endpoint: &Endpoint) -> std::io::Result<Conn> {
        match endpoint {
            Endpoint::Tcp(addr) => TcpStream::connect(addr).map(Conn::Tcp),
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                UnixStream::connect(path).map(Conn::Unix)
            }
        }
    }

    fn try_clone(&self) -> std::io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            #[cfg(unix)]
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

struct Ctx {
    admission: Admission,
    registry: CacheRegistry,
    metrics: Metrics,
    workers: usize,
    stop: AtomicBool,
    endpoint: Endpoint,
}

/// The serve daemon: bind once, then [`Server::run`] until a
/// `shutdown` request.
pub struct Server {
    listener: Listener,
    ctx: Arc<Ctx>,
    _lock: Option<LockFile>,
}

impl Server {
    /// Bind the endpoint (taking the state lock first when configured)
    /// without serving yet.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let lock = match &cfg.state_dir {
            Some(dir) => {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
                Some(LockFile::acquire(&dir.join("serve.state"))?)
            }
            None => None,
        };
        let (listener, endpoint) = match &cfg.endpoint {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .with_context(|| format!("binding tcp {addr}"))?;
                let actual = l
                    .local_addr()
                    .with_context(|| format!("resolving {addr}"))?
                    .to_string();
                (Listener::Tcp(l), Endpoint::Tcp(actual))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let l = bind_unix(path)?;
                (Listener::Unix(l), Endpoint::Unix(path.clone()))
            }
        };
        Ok(Server {
            listener,
            ctx: Arc::new(Ctx {
                admission: Admission::new(cfg.max_inflight),
                registry: CacheRegistry::new(),
                metrics: Metrics::new(),
                workers: cfg.workers.max(1),
                stop: AtomicBool::new(false),
                endpoint,
            }),
            _lock: lock,
        })
    }

    /// The resolved endpoint (actual port for `host:0` TCP binds) —
    /// what clients should connect to.
    pub fn local_endpoint(&self) -> &Endpoint {
        &self.ctx.endpoint
    }

    /// Accept and serve connections until a `shutdown` request.  Each
    /// connection gets its own thread; in-flight requests on other
    /// connections finish writing before their threads exit, but
    /// `run` itself returns as soon as the listener stops.
    pub fn run(&self) -> Result<()> {
        loop {
            let conn = match self.listener.accept() {
                Ok(c) => c,
                Err(e) => {
                    if self.ctx.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    return Err(e).context("accepting connection");
                }
            };
            if self.ctx.stop.load(Ordering::SeqCst) {
                break;
            }
            let ctx = self.ctx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(conn, &ctx);
            });
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Endpoint::Unix(path) = &self.ctx.endpoint {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Bind a Unix socket, reclaiming a stale socket file (left by a
/// crashed daemon) after probing that nothing answers on it.
#[cfg(unix)]
fn bind_unix(path: &std::path::Path) -> Result<UnixListener> {
    match UnixListener::bind(path) {
        Ok(l) => Ok(l),
        Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
            if UnixStream::connect(path).is_ok() {
                bail!(
                    "{}: a serve daemon is already listening",
                    path.display()
                );
            }
            std::fs::remove_file(path)
                .with_context(|| format!("removing stale {}", path.display()))?;
            UnixListener::bind(path)
                .with_context(|| format!("binding unix {}", path.display()))
        }
        Err(e) => {
            Err(e).with_context(|| format!("binding unix {}", path.display()))
        }
    }
}

fn handle_conn(conn: Conn, ctx: &Ctx) -> std::io::Result<()> {
    let reader = BufReader::new(conn.try_clone()?);
    let mut writer = conn;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let shutdown = handle_line(&line, &mut writer, ctx)?;
        writer.flush()?;
        if shutdown {
            break;
        }
    }
    Ok(())
}

fn handle_line(
    line: &str,
    out: &mut Conn,
    ctx: &Ctx,
) -> std::io::Result<bool> {
    match Request::parse(line) {
        Err(e) => {
            ctx.metrics.error();
            writeln!(out, "{}", protocol::error_line(400, &format!("{e:#}")))?;
        }
        Ok(Request::Ping) => writeln!(out, "{}", protocol::pong_line())?,
        Ok(Request::Stats) => writeln!(out, "{}", stats_line(ctx))?,
        Ok(Request::Shutdown) => {
            writeln!(out, "{}", protocol::bye_line())?;
            out.flush()?;
            ctx.stop.store(true, Ordering::SeqCst);
            // Unblock the accept loop so it observes the stop flag.
            let _ = Conn::connect(&ctx.endpoint);
            return Ok(true);
        }
        Ok(Request::Compress(spec)) => handle_compress(&spec, out, ctx)?,
    }
    Ok(false)
}

fn handle_compress(
    spec: &ModelSpec,
    out: &mut Conn,
    ctx: &Ctx,
) -> std::io::Result<()> {
    let Some(permit) = ctx.admission.try_acquire() else {
        ctx.metrics.reject();
        let msg = format!(
            "at capacity ({} of {} requests in flight); retry later",
            ctx.admission.in_flight(),
            ctx.admission.capacity()
        );
        writeln!(out, "{}", protocol::error_line(429, &msg))?;
        return Ok(());
    };
    ctx.metrics.admit();
    let timer = Timer::start();
    let fp = spec.fingerprint();
    let mut jobs = Vec::with_capacity(spec.layers);
    for layer in 0..spec.layers {
        match spec.job(layer) {
            Ok(mut job) => {
                // Cross-request warm store: per instance-layer, and
                // only for canonical-key specs (exact-key jobs drop
                // the shared level anyway — see `run_job`).
                if !spec.cache_key_raw {
                    job.shared_cache =
                        Some(ctx.registry.get(&spec.instance_key(layer)));
                }
                jobs.push(job);
            }
            Err(e) => {
                ctx.metrics.error();
                writeln!(
                    out,
                    "{}",
                    protocol::error_line(400, &format!("{e:#}"))
                )?;
                return Ok(());
            }
        }
    }
    let eng = Engine::new(EngineConfig {
        workers: ctx.workers,
        restart_workers: spec.restart_workers,
        batch_size: 1, // per-job cfg carries the spec's batch size
    });
    let mut records: Vec<LayerRecord> = Vec::with_capacity(spec.layers);
    let mut io_err: Option<std::io::Error> = None;
    eng.compress_each(jobs, |i, result| {
        let rec = LayerRecord::from_result(i, &result);
        if io_err.is_none() {
            if let Err(e) = writeln!(out, "{}", rec.to_json_line(&fp)) {
                io_err = Some(e);
            }
        }
        records.push(rec);
    });
    if let Some(e) = io_err {
        return Err(e);
    }
    let report = deterministic_report(&records);
    writeln!(
        out,
        "{}",
        protocol::done_line(&fp, records.len(), &report, timer.seconds())
    )?;
    ctx.metrics.complete(timer.seconds());
    drop(permit);
    Ok(())
}

fn stats_line(ctx: &Ctx) -> String {
    let (entries, cache) = ctx.registry.stats();
    let m = ctx.metrics.snapshot();
    Json::obj(vec![
        ("admitted", Json::Num(m.admitted as f64)),
        ("cache_caches", Json::Num(ctx.registry.caches() as f64)),
        ("cache_entries", Json::Num(entries as f64)),
        ("cache_hit_rate", Json::Num(cache.hit_rate())),
        ("cache_hits", Json::Num(cache.hits as f64)),
        ("cache_misses", Json::Num(cache.misses as f64)),
        ("completed", Json::Num(m.completed as f64)),
        ("errors", Json::Num(m.errors as f64)),
        ("inflight", Json::Num(ctx.admission.in_flight() as f64)),
        ("latency_count", Json::Num(m.latency_count as f64)),
        ("latency_mean_s", Json::Num(m.latency_mean_s)),
        ("latency_p50_s", Json::Num(m.latency_p50_s)),
        ("latency_p99_s", Json::Num(m.latency_p99_s)),
        ("max_inflight", Json::Num(ctx.admission.capacity() as f64)),
        ("rejected", Json::Num(m.rejected as f64)),
        ("schema", Json::Str(SERVE_SCHEMA.into())),
        ("type", Json::Str("stats".into())),
        ("workers", Json::Num(ctx.workers as f64)),
    ])
    .to_string()
}

/// Client side: send one request line to a daemon and collect the
/// response lines, up to and including the terminal typed line
/// (`done`, `stats`, `pong`, `bye` or `error`).
pub fn request(endpoint: &Endpoint, line: &str) -> Result<Vec<String>> {
    let mut conn = Conn::connect(endpoint)
        .with_context(|| format!("connecting to {endpoint}"))?;
    conn.write_all(line.as_bytes())?;
    conn.write_all(b"\n")?;
    conn.flush()?;
    let reader = BufReader::new(conn.try_clone()?);
    let mut lines = Vec::new();
    for l in reader.lines() {
        let l = l?;
        if l.trim().is_empty() {
            continue;
        }
        let terminal = protocol::is_terminal(&l);
        lines.push(l);
        if terminal {
            return Ok(lines);
        }
    }
    bail!("connection closed before a terminal response line");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_counts_and_releases_slots() {
        let adm = Admission::new(2);
        assert_eq!((adm.capacity(), adm.in_flight()), (2, 0));
        let p1 = adm.try_acquire().unwrap();
        let p2 = adm.try_acquire().unwrap();
        assert_eq!(adm.in_flight(), 2);
        assert!(adm.try_acquire().is_none(), "over capacity");
        drop(p1);
        assert_eq!(adm.in_flight(), 1);
        let p3 = adm.try_acquire().unwrap();
        assert!(adm.try_acquire().is_none());
        drop(p2);
        drop(p3);
        assert_eq!(adm.in_flight(), 0);
    }

    #[test]
    fn zero_capacity_rejects_everything() {
        let adm = Admission::new(0);
        assert!(adm.try_acquire().is_none());
    }

    #[test]
    fn metrics_percentiles_over_the_window() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.complete(i as f64 / 100.0);
        }
        m.reject();
        m.error();
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.errors, 1);
        assert_eq!(s.latency_count, 100);
        assert!((s.latency_p50_s - 0.5).abs() < 1e-12);
        assert!((s.latency_p99_s - 0.99).abs() < 1e-12);
        assert!((s.latency_mean_s - 0.505).abs() < 1e-12);
    }

    #[test]
    fn latency_window_stays_bounded() {
        let m = Metrics::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            m.complete(i as f64);
        }
        let s = m.snapshot();
        assert!(s.latency_count <= LATENCY_WINDOW);
        assert_eq!(s.completed as usize, LATENCY_WINDOW + 10);
    }
}
