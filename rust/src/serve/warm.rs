//! Durable per-instance surrogate-state store backing serve warm
//! starts (ISSUE 10).
//!
//! Lives under `STATE_DIR/warm/`, one JSON document per
//! [`ModelSpec::instance_key`] — the *instance* identity (shape,
//! gamma, instance seed, layer), deliberately not the spec
//! fingerprint: a re-tuned request (different run seed, iteration
//! budget or algorithm knobs) has a new fingerprint but the same
//! instance, and that is exactly the case warm starting pays off.
//!
//! Durability follows the checkpoint-log discipline with the primitive
//! that fits a single-document file: write to a temporary sibling,
//! `fsync`, then atomically rename over the old state, so a crash
//! leaves either the previous state or the new one — never a torn
//! file.  The daemon's `serve.state` lockfile already guarantees a
//! single writer for the whole state directory.  A corrupt or
//! incompatible document on load is *never* a silent cold start: the
//! store logs a warning naming the key and the typed parse error, then
//! serves cold.
//!
//! [`ModelSpec::instance_key`]: crate::shard::ModelSpec::instance_key

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::bbo::WarmStart;

/// The on-disk store: a directory of `{instance_key}.json` warm-start
/// documents.
pub struct WarmStore {
    dir: PathBuf,
}

impl WarmStore {
    /// Open (creating if needed) the store under `state_dir/warm`.
    pub fn open(state_dir: &Path) -> Result<WarmStore> {
        let dir = state_dir.join("warm");
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(WarmStore { dir })
    }

    /// The store's directory — reported as `warm_source` in `done`
    /// lines so operators can see where states came from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, key: &str) -> PathBuf {
        // Instance keys are `n{..}-d{..}-...` — alphanumerics and
        // dashes only, safe as file names without escaping.
        self.dir.join(format!("{key}.json"))
    }

    /// Load the stored warm start for an instance key.  `None` means
    /// cold: no state yet (silent — the normal first-contact case) or
    /// a corrupt/unreadable document (logged with the typed error,
    /// never silent).
    pub fn load(&self, key: &str) -> Option<WarmStart> {
        let path = self.path(key);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return None;
            }
            Err(e) => {
                eprintln!(
                    "serve: warm: {key}: reading {}: {e}; cold start",
                    path.display()
                );
                return None;
            }
        };
        match WarmStart::parse(&text) {
            Ok(w) => Some(w),
            Err(e) => {
                eprintln!(
                    "serve: warm: {key}: corrupt state ({e}); cold start"
                );
                None
            }
        }
    }

    /// Persist a warm start for an instance key: temp sibling +
    /// `fsync` + atomic rename, so concurrent readers and crashes see
    /// either the old state or the new one.
    pub fn save(&self, key: &str, warm: &WarmStart) -> std::io::Result<()> {
        let text = warm
            .to_string_strict()
            .map_err(std::io::Error::other)?;
        let path = self.path(key);
        let tmp = self.dir.join(format!("{key}.json.tmp"));
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(text.as_bytes())?;
            f.write_all(b"\n")?;
            f.sync_data()?;
        }
        fs::rename(&tmp, &path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bbo::SurrogateState;
    use crate::surrogate::Dataset;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "intdecomp-warmstore-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_warm() -> WarmStart {
        let mut data = Dataset::new(4);
        data.push(vec![1, -1, 1, -1], 2.5);
        data.push(vec![-1, -1, 1, 1], -0.75);
        let state =
            SurrogateState { n_bits: 4, dataset: data, surrogate: None };
        WarmStart::new(state).with_prev_best(vec![-1, -1, 1, 1], -0.75)
    }

    #[test]
    fn save_then_load_round_trips_bit_for_bit() {
        let dir = tmpdir("roundtrip");
        let store = WarmStore::open(&dir).unwrap();
        let warm = sample_warm();
        store.save("n4-test-l0", &warm).unwrap();
        let back = store.load("n4-test-l0").unwrap();
        assert_eq!(
            back.to_string_strict().unwrap(),
            warm.to_string_strict().unwrap()
        );
        let (x, y) = back.prev_best.unwrap();
        assert_eq!(x, vec![-1, -1, 1, 1]);
        assert_eq!(y.to_bits(), (-0.75f64).to_bits());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_key_is_a_silent_cold_start() {
        let dir = tmpdir("missing");
        let store = WarmStore::open(&dir).unwrap();
        assert!(store.load("never-saved").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_state_degrades_to_cold_not_a_crash() {
        let dir = tmpdir("corrupt");
        let store = WarmStore::open(&dir).unwrap();
        fs::write(store.dir().join("bad.json"), b"{torn garb").unwrap();
        assert!(store.load("bad").is_none());
        // Wrong schema tag is typed-rejected, not misread.
        fs::write(
            store.dir().join("vx.json"),
            br#"{"schema":"intdecomp-surrogate-state-v999"}"#,
        )
        .unwrap();
        assert!(store.load("vx").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_replaces_atomically_and_leaves_no_tmp() {
        let dir = tmpdir("replace");
        let store = WarmStore::open(&dir).unwrap();
        let warm = sample_warm();
        store.save("k", &warm).unwrap();
        let richer = {
            let mut w = sample_warm();
            w.state.dataset.push(vec![1, 1, 1, 1], 9.0);
            w
        };
        store.save("k", &richer).unwrap();
        let back = store.load("k").unwrap();
        assert_eq!(back.state.dataset.len(), 3);
        assert!(!store.dir().join("k.json.tmp").exists());
        let _ = fs::remove_dir_all(&dir);
    }
}
