//! Micro-benchmark substrate (criterion is not vendored; DESIGN.md §6).
//!
//! Wall-clock harness with warmup, repetition and robust statistics; used
//! by `rust/benches/paper_benches.rs` (`cargo bench`) and the Table-2
//! experiment.

use crate::util::timer::Timer;

/// Statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Timed repetitions.
    pub reps: usize,
    /// Mean seconds per rep.
    pub mean_s: f64,
    /// Fastest rep (seconds).
    pub min_s: f64,
    /// Slowest rep (seconds).
    pub max_s: f64,
    /// Standard deviation across reps (seconds).
    pub stddev_s: f64,
    /// Work items per rep, for throughput reporting (0 = n/a).
    pub items_per_rep: usize,
}

impl BenchStats {
    /// Work items per second (None when items_per_rep is 0).
    pub fn throughput(&self) -> Option<f64> {
        if self.items_per_rep > 0 && self.mean_s > 0.0 {
            Some(self.items_per_rep as f64 / self.mean_s)
        } else {
            None
        }
    }

    /// One formatted report line.
    pub fn report(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:.2} M items/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:.2} k items/s", t / 1e3),
            Some(t) => format!("  {t:.2} items/s"),
            None => String::new(),
        };
        format!(
            "{:<40} mean {:>10.4} ms  min {:>10.4} ms  ±{:>8.4} ms  ({} reps){}",
            self.name,
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.stddev_s * 1e3,
            self.reps,
            tput
        )
    }
}

/// Benchmark runner: warms up, then times `reps` calls of `f`.
pub struct Bencher {
    /// Untimed warmup calls before measuring.
    pub warmup: usize,
    /// Timed repetitions.
    pub reps: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, reps: 10 }
    }
}

impl Bencher {
    /// Harness with the given warmup and repetition counts.
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bencher { warmup, reps: reps.max(1) }
    }

    /// Time `f`; `items` is the per-rep work-item count for throughput.
    pub fn run<T>(
        &self,
        name: &str,
        items: usize,
        mut f: impl FnMut() -> T,
    ) -> BenchStats {
        for _ in 0..self.warmup {
            let _ = std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t = Timer::start();
            let _ = std::hint::black_box(f());
            times.push(t.seconds());
        }
        let mean = crate::util::mean(&times);
        BenchStats {
            name: name.to_string(),
            reps: self.reps,
            mean_s: mean,
            min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: times.iter().cloned().fold(0.0, f64::max),
            stddev_s: crate::util::stddev(&times),
            items_per_rep: items,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let b = Bencher::new(1, 5);
        let s = b.run("spin", 1000, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.reps, 5);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s + 1e-12);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report().contains("spin"));
    }

    #[test]
    fn zero_items_has_no_throughput() {
        let b = Bencher::new(0, 2);
        let s = b.run("noop", 0, || 1);
        assert!(s.throughput().is_none());
    }
}
