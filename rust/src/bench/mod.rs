//! Micro-benchmark substrate (criterion is not vendored; DESIGN.md §6).
//!
//! Wall-clock harness with warmup, repetition and robust statistics; used
//! by `rust/benches/paper_benches.rs` (`cargo bench`), the `intdecomp
//! bench` CLI subcommand and the Table-2 experiment.
//!
//! Results serialise to `BENCH_<label>.json` at the repository root
//! ([`write_json`] / [`validate_json`], schema [`BENCH_SCHEMA`]) so the
//! perf trajectory is tracked in-tree from ISSUE 3 onward: run the bench
//! before and after a change and commit both files.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::timer::Timer;

/// Schema tag written into every `BENCH_*.json`; bump on layout changes.
/// v2 (ISSUE 4) adds the `sweeps_per_rep` / `sweeps_per_sec` pair to
/// every result row — the solver-throughput metric of the replica-major
/// engine rows (`solver/... sweeps ...`).
/// v3 (ISSUE 6) adds nearest-rank `p50_s` / `p99_s` per-rep latency
/// percentiles to every row — the tail metric the serve-daemon rows
/// (`serve/...`) exist for.
pub const BENCH_SCHEMA: &str = "intdecomp-bench-v3";

/// Statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Timed repetitions.
    pub reps: usize,
    /// Mean seconds per rep.
    pub mean_s: f64,
    /// Fastest rep (seconds).
    pub min_s: f64,
    /// Slowest rep (seconds).
    pub max_s: f64,
    /// Standard deviation across reps (seconds).
    pub stddev_s: f64,
    /// Median rep (nearest-rank, seconds).
    pub p50_s: f64,
    /// 99th-percentile rep (nearest-rank, seconds; equals the slowest
    /// rep at the harness's small rep counts).
    pub p99_s: f64,
    /// Work items per rep, for throughput reporting (0 = n/a).
    pub items_per_rep: usize,
    /// Solver panel-row sweeps per rep, for `sweeps_per_sec` reporting
    /// (0 = not a solver-throughput row).
    pub sweeps_per_rep: usize,
}

impl BenchStats {
    /// Work items per second (None when items_per_rep is 0).
    pub fn throughput(&self) -> Option<f64> {
        if self.items_per_rep > 0 && self.mean_s > 0.0 {
            Some(self.items_per_rep as f64 / self.mean_s)
        } else {
            None
        }
    }

    /// Solver panel-row sweeps per second (None when `sweeps_per_rep`
    /// is 0) — the replica-engine throughput metric of the
    /// `solver/... sweeps ...` rows.
    pub fn sweeps_per_sec(&self) -> Option<f64> {
        if self.sweeps_per_rep > 0 && self.mean_s > 0.0 {
            Some(self.sweeps_per_rep as f64 / self.mean_s)
        } else {
            None
        }
    }

    /// JSON object of this row (one `results[]` element of the
    /// `BENCH_*.json` schema).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("reps", Json::Num(self.reps as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("min_s", Json::Num(self.min_s)),
            ("max_s", Json::Num(self.max_s)),
            ("stddev_s", Json::Num(self.stddev_s)),
            ("p50_s", Json::Num(self.p50_s)),
            ("p99_s", Json::Num(self.p99_s)),
            ("items_per_rep", Json::Num(self.items_per_rep as f64)),
            (
                "throughput_per_s",
                match self.throughput() {
                    Some(t) => Json::Num(t),
                    None => Json::Null,
                },
            ),
            ("sweeps_per_rep", Json::Num(self.sweeps_per_rep as f64)),
            (
                "sweeps_per_sec",
                match self.sweeps_per_sec() {
                    Some(t) => Json::Num(t),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// One formatted report line.
    pub fn report(&self) -> String {
        let tput = match (self.sweeps_per_sec(), self.throughput()) {
            (Some(s), _) if s >= 1e6 => {
                format!("  {:.2} M sweeps/s", s / 1e6)
            }
            (Some(s), _) if s >= 1e3 => {
                format!("  {:.2} k sweeps/s", s / 1e3)
            }
            (Some(s), _) => format!("  {s:.2} sweeps/s"),
            (None, Some(t)) if t >= 1e6 => {
                format!("  {:.2} M items/s", t / 1e6)
            }
            (None, Some(t)) if t >= 1e3 => {
                format!("  {:.2} k items/s", t / 1e3)
            }
            (None, Some(t)) => format!("  {t:.2} items/s"),
            (None, None) => String::new(),
        };
        format!(
            "{:<40} mean {:>10.4} ms  min {:>10.4} ms  ±{:>8.4} ms  ({} reps){}",
            self.name,
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.stddev_s * 1e3,
            self.reps,
            tput
        )
    }
}

/// Benchmark runner: warms up, then times `reps` calls of `f`.
pub struct Bencher {
    /// Untimed warmup calls before measuring.
    pub warmup: usize,
    /// Timed repetitions.
    pub reps: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, reps: 10 }
    }
}

impl Bencher {
    /// Harness with the given warmup and repetition counts.
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bencher { warmup, reps: reps.max(1) }
    }

    /// Time `f`; `items` is the per-rep work-item count for throughput.
    pub fn run<T>(
        &self,
        name: &str,
        items: usize,
        mut f: impl FnMut() -> T,
    ) -> BenchStats {
        for _ in 0..self.warmup {
            let _ = std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t = Timer::start();
            let _ = std::hint::black_box(f());
            times.push(t.seconds());
        }
        let mean = crate::util::mean(&times);
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchStats {
            name: name.to_string(),
            reps: self.reps,
            mean_s: mean,
            min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: times.iter().cloned().fold(0.0, f64::max),
            stddev_s: crate::util::stddev(&times),
            p50_s: crate::util::percentile(&sorted, 50.0),
            p99_s: crate::util::percentile(&sorted, 99.0),
            items_per_rep: items,
            sweeps_per_rep: 0,
        }
    }

    /// Time `f` like [`Bencher::run`], additionally recording
    /// `sweeps` solver panel-row sweeps per rep so the row reports
    /// `sweeps_per_sec` (the replica-engine throughput rows).
    pub fn run_sweeps<T>(
        &self,
        name: &str,
        items: usize,
        sweeps: usize,
        f: impl FnMut() -> T,
    ) -> BenchStats {
        let mut s = self.run(name, items, f);
        s.sweeps_per_rep = sweeps;
        s
    }
}

/// `BENCH_<label>.json` at the repository root (one level above the
/// crate manifest) — the canonical location the perf trajectory lives
/// at, shared by `cargo bench` and the `bench` CLI subcommand.  When the
/// binary runs outside its build checkout (the compile-time manifest
/// path no longer exists), falls back to the current directory.
pub fn default_json_path(label: &str) -> PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .filter(|p| p.is_dir())
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."));
    root.join(format!("BENCH_{label}.json"))
}

/// Serialise one bench run (all its [`BenchStats`] rows) to `path` in
/// the [`BENCH_SCHEMA`] layout.  Key order is deterministic (BTreeMap
/// underneath), so diffs between trajectory snapshots stay readable.
pub fn write_json(
    path: impl AsRef<Path>,
    label: &str,
    quick: bool,
    stats: &[BenchStats],
) -> std::io::Result<()> {
    let created = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let j = Json::obj(vec![
        ("schema", Json::Str(BENCH_SCHEMA.into())),
        ("label", Json::Str(label.into())),
        ("quick", Json::Bool(quick)),
        ("created_unix", Json::Num(created as f64)),
        (
            "results",
            Json::Arr(stats.iter().map(BenchStats::to_json).collect()),
        ),
    ]);
    std::fs::write(path, j.to_string() + "\n")
}

/// Validate `BENCH_*.json` text against the [`BENCH_SCHEMA`] layout;
/// returns the result-row count.  The CI bench smoke runs this on its
/// own output so the schema cannot rot silently.
///
/// v2 checks: every row carries a numeric `sweeps_per_rep`, and every
/// row with `sweeps_per_rep > 0` (the solver-throughput rows) carries a
/// numeric `sweeps_per_sec`.  v3 adds: every row carries numeric
/// `p50_s` / `p99_s` latency percentiles.
pub fn validate_json(text: &str) -> Result<usize, String> {
    let j = Json::parse(text)?;
    match j.get("schema").and_then(Json::as_str) {
        Some(s) if s == BENCH_SCHEMA => {}
        other => return Err(format!("bad schema tag {other:?}")),
    }
    if j.get("label").and_then(Json::as_str).is_none() {
        return Err("missing string 'label'".into());
    }
    let rows = j
        .get("results")
        .and_then(Json::as_arr)
        .ok_or("missing array 'results'")?;
    for (i, r) in rows.iter().enumerate() {
        if r.get("name").and_then(Json::as_str).is_none() {
            return Err(format!("results[{i}]: missing string 'name'"));
        }
        for key in [
            "reps",
            "mean_s",
            "min_s",
            "max_s",
            "stddev_s",
            "p50_s",
            "p99_s",
            "items_per_rep",
            "sweeps_per_rep",
        ] {
            if r.get(key).and_then(Json::as_f64).is_none() {
                return Err(format!(
                    "results[{i}]: missing numeric '{key}'"
                ));
            }
        }
        let sweeps = r
            .get("sweeps_per_rep")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        if sweeps > 0.0
            && r.get("sweeps_per_sec").and_then(Json::as_f64).is_none()
        {
            return Err(format!(
                "results[{i}]: solver-throughput row lacks numeric \
                 'sweeps_per_sec'"
            ));
        }
    }
    Ok(rows.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let b = Bencher::new(1, 5);
        let s = b.run("spin", 1000, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.reps, 5);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s + 1e-12);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p99_s);
        assert!(s.p99_s <= s.max_s + 1e-12);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report().contains("spin"));
    }

    #[test]
    fn zero_items_has_no_throughput() {
        let b = Bencher::new(0, 2);
        let s = b.run("noop", 0, || 1);
        assert!(s.throughput().is_none());
        assert!(s.sweeps_per_sec().is_none());
    }

    #[test]
    fn sweeps_rows_report_sweeps_per_sec() {
        let b = Bencher::new(0, 3);
        let s = b.run_sweeps("solver/sa sweeps n=32 r=8", 8, 800, || {
            std::hint::black_box(1 + 1)
        });
        assert_eq!(s.sweeps_per_rep, 800);
        let sps = s.sweeps_per_sec().unwrap();
        assert!(sps > 0.0);
        assert!(s.report().contains("sweeps/s"));
        let j = s.to_json();
        assert_eq!(j.get("sweeps_per_rep").and_then(Json::as_f64), Some(800.0));
        assert!(j.get("sweeps_per_sec").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn json_roundtrip_validates() {
        let b = Bencher::new(0, 2);
        let s1 = b.run("row-a", 10, || 1);
        let s2 = b.run("row-b", 0, || 2);
        let dir = std::env::temp_dir().join("intdecomp_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        write_json(&path, "test", true, &[s1, s2]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(validate_json(&text), Ok(2));
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("label").unwrap().as_str(), Some("test"));
        assert_eq!(j.get("quick"), Some(&Json::Bool(true)));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        assert!(validate_json("not json").is_err());
        assert!(validate_json("{}").is_err());
        // Pre-v3 documents (old schema tag) are rejected.
        assert!(validate_json(
            r#"{"schema":"intdecomp-bench-v2","label":"x","results":[]}"#
        )
        .is_err());
        assert!(validate_json(
            r#"{"schema":"intdecomp-bench-v3","label":"x","results":[{}]}"#
        )
        .is_err());
        assert_eq!(
            validate_json(
                r#"{"schema":"intdecomp-bench-v3","label":"x","results":[]}"#
            ),
            Ok(0)
        );
    }

    #[test]
    fn validate_requires_percentiles_and_sweeps_per_sec() {
        // A v3 row missing p50_s/p99_s is rejected.
        let old_row = r#"{"name":"x","reps":1,"mean_s":0.1,"min_s":0.1,
            "max_s":0.1,"stddev_s":0.0,"items_per_rep":1,
            "sweeps_per_rep":0}"#;
        let doc = format!(
            r#"{{"schema":"intdecomp-bench-v3","label":"x","results":[{old_row}]}}"#
        );
        let err = validate_json(&doc).unwrap_err();
        assert!(err.contains("p50_s"), "{err}");
        // A solver-throughput row missing sweeps_per_sec is rejected.
        let row = r#"{"name":"solver/sa sweeps n=32 r=1","reps":1,
            "mean_s":0.1,"min_s":0.1,"max_s":0.1,"stddev_s":0.0,
            "p50_s":0.1,"p99_s":0.1,"items_per_rep":1,
            "sweeps_per_rep":100}"#;
        let doc = format!(
            r#"{{"schema":"intdecomp-bench-v3","label":"x","results":[{row}]}}"#
        );
        let err = validate_json(&doc).unwrap_err();
        assert!(err.contains("sweeps_per_sec"), "{err}");
    }

    #[test]
    fn default_path_targets_repo_root() {
        let p = default_json_path("x");
        assert!(p.ends_with("BENCH_x.json"));
        // One level above the crate manifest (rust/..).
        assert!(!p.to_string_lossy().contains("rust/BENCH"));
    }
}
