//! Cooperative cancellation: a cheap, cloneable token checked at loop
//! boundaries.
//!
//! A [`CancelToken`] is a shared flag (client disconnect, explicit
//! abort) plus an optional deadline instant (per-request
//! `deadline_ms`).  Long-running loops poll [`CancelToken::cause`] at
//! their iteration boundaries and unwind with a [`CancelCause`] —
//! nothing is interrupted mid-step, so every run that *completes* is
//! byte-identical to one executed without a token (the checks never
//! touch RNG state or any numeric path).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Why a run was aborted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelCause {
    /// The token was cancelled explicitly (e.g. the requesting client
    /// disconnected).
    Cancelled,
    /// The token's deadline passed.
    DeadlineExceeded,
}

impl CancelCause {
    /// Wire label of the cause — the serve daemon's terminal line type
    /// (`"cancelled"` / `"deadline"`).
    pub fn label(self) -> &'static str {
        match self {
            CancelCause::Cancelled => "cancelled",
            CancelCause::DeadlineExceeded => "deadline",
        }
    }
}

impl std::fmt::Display for CancelCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Shared cancellation token: an `Arc<AtomicBool>` plus an optional
/// deadline.  Clones observe the same flag; the deadline is fixed at
/// construction.  The default token never cancels.
///
/// ```
/// use intdecomp::util::cancel::{CancelCause, CancelToken};
///
/// let tok = CancelToken::never();
/// assert_eq!(tok.cause(), None);
/// let peer = tok.clone();
/// peer.cancel();
/// assert_eq!(tok.cause(), Some(CancelCause::Cancelled));
/// ```
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that only cancels when [`CancelToken::cancel`] is called
    /// (never, if nobody holds a clone).
    pub fn never() -> CancelToken {
        CancelToken::default()
    }

    /// A token that additionally cancels once `timeout` has elapsed
    /// from now.  A `timeout` too large to represent is treated as no
    /// deadline.
    pub fn with_deadline(timeout: Duration) -> CancelToken {
        CancelToken {
            flag: Arc::new(AtomicBool::new(false)),
            deadline: Instant::now().checked_add(timeout),
        }
    }

    /// Trip the shared flag; every clone observes it on its next
    /// [`CancelToken::cause`] check.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    /// Why the holder should stop, if it should.  The explicit flag
    /// wins over the deadline when both hold.
    pub fn cause(&self) -> Option<CancelCause> {
        if self.flag.load(Ordering::Acquire) {
            return Some(CancelCause::Cancelled);
        }
        match self.deadline {
            Some(d) if Instant::now() >= d => {
                Some(CancelCause::DeadlineExceeded)
            }
            _ => None,
        }
    }

    /// Convenience: is the token tripped (flag or deadline)?
    pub fn is_cancelled(&self) -> bool {
        self.cause().is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_token_never_cancels() {
        let tok = CancelToken::never();
        assert_eq!(tok.cause(), None);
        assert!(!tok.is_cancelled());
    }

    #[test]
    fn clones_share_the_flag() {
        let tok = CancelToken::never();
        let other = tok.clone();
        other.cancel();
        assert_eq!(tok.cause(), Some(CancelCause::Cancelled));
        assert!(tok.is_cancelled());
    }

    #[test]
    fn deadline_trips_after_the_timeout() {
        let tok = CancelToken::with_deadline(Duration::from_millis(0));
        assert_eq!(tok.cause(), Some(CancelCause::DeadlineExceeded));
        let far = CancelToken::with_deadline(Duration::from_secs(3600));
        assert_eq!(far.cause(), None);
    }

    #[test]
    fn explicit_cancel_wins_over_deadline() {
        let tok = CancelToken::with_deadline(Duration::from_millis(0));
        tok.cancel();
        assert_eq!(tok.cause(), Some(CancelCause::Cancelled));
    }

    #[test]
    fn huge_deadline_degrades_to_never() {
        let tok = CancelToken::with_deadline(Duration::MAX);
        assert_eq!(tok.cause(), None);
    }

    #[test]
    fn cause_labels_are_the_wire_types() {
        assert_eq!(CancelCause::Cancelled.label(), "cancelled");
        assert_eq!(CancelCause::DeadlineExceeded.label(), "deadline");
        assert_eq!(CancelCause::Cancelled.to_string(), "cancelled");
    }
}
