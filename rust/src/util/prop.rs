//! Tiny property-testing substrate (proptest is not vendored).
//!
//! `for_all(cases, |rng| ...)` runs a property closure against many
//! independently seeded RNGs; a failing case panics with the seed so it can
//! be replayed exactly (`replay(seed, ...)`).  No shrinking — the
//! generators used in this repo are small enough that the seed alone is an
//! actionable repro.

use super::rng::Rng;

/// Run `prop` for `cases` seeds; panics with the failing seed on error.
pub fn for_all(cases: u64, prop: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let mut rng = Rng::new(seed);
                prop(&mut rng);
            }),
        );
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    err.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Replay a single failing seed.
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        for_all(20, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn reports_failing_seed() {
        for_all(5, |rng| {
            assert!(rng.f64() < -1.0, "impossible");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut v1 = Vec::new();
        let mut v2 = Vec::new();
        replay(99, |rng| v1.push(rng.next_u64()));
        replay(99, |rng| v2.push(rng.next_u64()));
        assert_eq!(v1, v2);
    }
}
