//! Scoped parallel-map over std threads.
//!
//! The experiment harness and the compression engine fan independent work
//! (BBO runs, Ising-solver restarts, whole-layer compression jobs) across
//! workers pulling from a shared queue, preserving input order in the
//! output.
//!
//! Panic policy: a panicking worker does not poison unrelated work — the
//! first panic payload is captured, the remaining queue is drained so the
//! other workers wind down, and the payload is re-raised on the calling
//! thread with `std::panic::resume_unwind`, exactly as if the closure had
//! panicked inline.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Mutex;

/// Map `f` over `items` using up to `workers` OS threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = workers.max(1);
    if workers == 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let done: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    let first_panic: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((idx, item)) => {
                        // Catch panics outside any lock so no mutex is
                        // ever poisoned by user code.
                        match catch_unwind(AssertUnwindSafe(|| f(item))) {
                            Ok(out) => {
                                done.lock().unwrap().push((idx, out));
                            }
                            Err(payload) => {
                                let mut first =
                                    first_panic.lock().unwrap();
                                if first.is_none() {
                                    *first = Some(payload);
                                }
                                // Stop handing out work; in-flight items
                                // on other workers finish normally.
                                queue.lock().unwrap().clear();
                                break;
                            }
                        }
                    }
                    None => break,
                }
            });
        }
    });

    if let Some(payload) = first_panic.into_inner().unwrap() {
        resume_unwind(payload);
    }
    let mut done = done.into_inner().unwrap();
    debug_assert_eq!(done.len(), n);
    done.sort_by_key(|&(idx, _)| idx);
    done.into_iter().map(|(_, r)| r).collect()
}

/// Number of workers to use by default (leave one core for the runtime).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = parallel_map(items, 4, |x| x * 3);
        assert_eq!(out, (0..97).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn heavier_than_workers() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x % 7);
        assert_eq!(out.len(), 1000);
        assert_eq!(out[700], 0);
    }

    #[test]
    #[should_panic(expected = "boom 13")]
    fn worker_panic_propagates_payload() {
        let _ = parallel_map((0..64).collect::<Vec<i32>>(), 4, |x| {
            if x == 13 {
                panic!("boom {x}");
            }
            x * 2
        });
    }

    #[test]
    #[should_panic(expected = "inline boom")]
    fn inline_path_panic_propagates_too() {
        // workers == 1 takes the inline map; the panic must look the same.
        let _ = parallel_map(vec![1, 2], 1, |x| {
            if x == 2 {
                panic!("inline boom");
            }
            x
        });
    }

    #[test]
    fn survives_after_a_previous_panicked_call() {
        // A panicked parallel_map must not leave behind state that breaks
        // the next call (no poisoned shared mutexes).
        let r = catch_unwind(|| {
            parallel_map(vec![1, 2, 3, 4], 2, |x| {
                if x == 3 {
                    panic!("once");
                }
                x
            })
        });
        assert!(r.is_err());
        let ok = parallel_map(vec![1, 2, 3, 4], 2, |x| x + 1);
        assert_eq!(ok, vec![2, 3, 4, 5]);
    }
}
