//! Scoped parallel-map over std threads.
//!
//! The experiment harness fans independent BBO runs across workers; on this
//! single-core testbed the win is overlap with PJRT-internal threads, but
//! the structure is what a multi-core deployment would use.

/// Map `f` over `items` using up to `workers` OS threads, preserving order.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = workers.max(1);
    if workers == 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = std::sync::Mutex::new(work);
    let slots_mx = std::sync::Mutex::new(&mut slots);

    std::thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((idx, item)) => {
                        let out = f(item);
                        slots_mx.lock().unwrap()[idx] = Some(out);
                    }
                    None => break,
                }
            });
        }
    });

    slots.into_iter().map(|s| s.expect("worker panicked")).collect()
}

/// Number of workers to use by default (leave one core for the runtime).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = parallel_map(items, 4, |x| x * 3);
        assert_eq!(out, (0..97).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn heavier_than_workers() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x % 7);
        assert_eq!(out.len(), 1000);
        assert_eq!(out[700], 0);
    }
}
