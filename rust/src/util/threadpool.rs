//! Persistent worker pool and the `parallel_map` fan-out built on it.
//!
//! PR 1 fanned work out with per-call scoped threads; at paper scale the
//! BBO loop performs thousands of iterations, each spawning (and joining)
//! a fresh set of OS threads for the Ising-restart fan-out.  This module
//! replaces that with one long-lived [`WorkerPool`]: threads are spawned
//! once, jobs are pushed onto a shared queue, and every layer of the
//! engine — Ising-solver restarts, batched candidate evaluation, and
//! whole-model [`crate::engine::Engine::compress_all`] jobs — reuses the
//! same pool across all BBO iterations through [`parallel_map`] /
//! [`WorkerPool::map`].
//!
//! Deadlock freedom: `map` calls nest (a compression job running on the
//! pool fans its solver restarts back onto the same pool).  Two rules
//! keep that safe on a bounded pool: the calling thread always works
//! through its own batch alongside the workers, and while it waits for
//! in-flight items it *reclaims its own* still-queued runner tickets
//! (tagged with the batch's identity) and runs them inline instead of
//! blocking idle.  Every batch therefore drains through threads that are
//! already committed to it, so a `map` completes even when every pool
//! thread is busy — by induction over the nesting depth — and a waiting
//! caller never executes unrelated work (a queued `submit` job can block
//! without hanging anyone, and foreign batches never run inside a
//! caller's timing window).
//!
//! Panic policy (same contract as the PR 1 scoped version): a panicking
//! item does not poison unrelated work — the first panic payload is
//! captured, the batch's remaining items are skipped, and the payload is
//! re-raised on the calling thread with `std::panic::resume_unwind`,
//! exactly as if the closure had panicked inline.  The pool itself
//! survives: no worker thread ever unwinds.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// A type-erased job on the pool's shared queue.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// A queued job plus the identity of the `map` batch it serves
/// (`0` for standalone `submit`/`run` jobs, which are never reclaimed
/// by waiting `map` callers).
struct QueueTask {
    batch: usize,
    run: Task,
}

/// State shared between the pool handle and its worker threads.
struct Shared {
    /// FIFO job queue workers pull from.
    queue: Mutex<VecDeque<QueueTask>>,
    /// Signalled when a job is pushed or the pool shuts down.
    work_cv: Condvar,
    /// Set once by `Drop`; workers drain the queue and exit.
    shutdown: AtomicBool,
}

/// A persistent pool of worker threads with job submission and result
/// channels.
///
/// Threads are spawned once in [`WorkerPool::new`] and live until the
/// pool is dropped, so per-iteration fan-outs pay a queue push instead
/// of a thread spawn.  Three entry points:
///
/// * [`WorkerPool::submit`] — fire-and-forget job submission;
/// * [`WorkerPool::run`] — job submission with an
///   [`std::sync::mpsc`] result channel;
/// * [`WorkerPool::map`] — ordered parallel map over owned items with
///   borrowed closures (the engine's workhorse; [`parallel_map`] is this
///   on the [`WorkerPool::global`] pool).
///
/// # Examples
///
/// ```
/// use intdecomp::util::threadpool::WorkerPool;
///
/// let pool = WorkerPool::new(4);
/// // Ordered map: results come back in input order.
/// let squares = pool.map((0..8).collect::<Vec<u64>>(), 4, |x| x * x);
/// assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
/// // Result channel: receive the job's output when it finishes.
/// let rx = pool.run(|| 21 * 2);
/// assert_eq!(rx.recv().unwrap(), 42);
/// ```
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `workers` (at least 1) persistent threads.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("intdecomp-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, workers, handles }
    }

    /// The process-wide pool, created on first use and reused for the
    /// rest of the process — this is the pool all BBO iterations and
    /// engine jobs share.  Sized at [`default_workers`]` - 1` threads
    /// (minimum 1): a `map` caller always participates in its own
    /// batch, so pool threads + caller saturate the cores without
    /// oversubscribing them.
    ///
    /// ```
    /// use intdecomp::util::threadpool::WorkerPool;
    ///
    /// let doubled =
    ///     WorkerPool::global().map(vec![1, 2, 3], 2, |x: i32| 2 * x);
    /// assert_eq!(doubled, vec![2, 4, 6]);
    /// ```
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            WorkerPool::new(default_workers().saturating_sub(1).max(1))
        })
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Fire-and-forget job submission.  The job runs on some worker
    /// thread; a panicking job is caught and discarded so the worker
    /// survives (use [`WorkerPool::run`] to observe failures).
    ///
    /// ```
    /// use intdecomp::util::threadpool::WorkerPool;
    /// use std::sync::mpsc::channel;
    ///
    /// let (tx, rx) = channel();
    /// WorkerPool::global().submit(move || tx.send(7).unwrap());
    /// assert_eq!(rx.recv().unwrap(), 7);
    /// ```
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.enqueue(
            0,
            Box::new(move || {
                let _ = catch_unwind(AssertUnwindSafe(job));
            }),
        );
    }

    /// Submit a job and get a result channel: the receiver yields the
    /// job's output when it completes.  If the job panics the sender is
    /// dropped without sending, so `recv()` returns `Err` instead of
    /// hanging.
    ///
    /// ```
    /// use intdecomp::util::threadpool::WorkerPool;
    ///
    /// let pool = WorkerPool::new(2);
    /// let rx = pool.run(|| "done");
    /// assert_eq!(rx.recv().unwrap(), "done");
    /// ```
    pub fn run<R, F>(&self, job: F) -> Receiver<R>
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        let (tx, rx) = channel();
        self.submit(move || {
            let _ = tx.send(job());
        });
        rx
    }

    /// Map `f` over `items` with up to `cap` of them in flight at once,
    /// preserving input order in the output.
    ///
    /// The closure may borrow from the caller's stack (the call blocks
    /// until every spawned task has finished with it).  The calling
    /// thread participates as one of the runners and, while waiting,
    /// reclaims its own still-queued runner tickets, so the call makes
    /// progress even when the pool is saturated, nested `map` calls
    /// from inside `f` cannot deadlock, and no unrelated queued work
    /// ever runs on the calling thread.  Effective parallelism is
    /// `min(cap, items.len(), pool workers + 1)`.
    ///
    /// `cap == 1` (or fewer than two items) short-circuits to a plain
    /// inline `map` on the calling thread — bit-for-bit the legacy
    /// serial path, with no queue traffic at all.
    ///
    /// ```
    /// use intdecomp::util::threadpool::WorkerPool;
    ///
    /// let pool = WorkerPool::new(3);
    /// let sum: i64 = pool
    ///     .map((0..100).collect::<Vec<i64>>(), 8, |x| x + 1)
    ///     .into_iter()
    ///     .sum();
    /// assert_eq!(sum, 5050);
    /// ```
    pub fn map<T, R, F>(&self, items: Vec<T>, cap: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let cap = cap.max(1);
        let n = items.len();
        if cap == 1 || n <= 1 {
            return items.into_iter().map(f).collect();
        }
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        // The caller is one runner; the rest are tickets on the pool.
        let extra = cap.min(n) - 1;
        let gate = Arc::new(Gate {
            remaining: AtomicUsize::new(n),
            live_runners: AtomicUsize::new(extra),
            queued: AtomicUsize::new(extra),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        });
        let batch = Batch {
            items: Mutex::new(items.into_iter().enumerate().collect()),
            results: Mutex::new(slots),
            f: &f,
            cancelled: AtomicBool::new(false),
            panic: Mutex::new(None),
        };
        // The batch's address tags its tickets on the queue; tickets
        // are always fully consumed before `map` returns, so the tag
        // cannot outlive the batch it names.
        let batch_id = &batch as *const Batch<'_, T, R, F> as usize;
        for _ in 0..extra {
            let b: &Batch<'_, T, R, F> = &batch;
            let g = Arc::clone(&gate);
            let ticket: Box<dyn FnOnce() + Send + '_> =
                Box::new(move || {
                    g.queued.fetch_sub(1, Ordering::SeqCst);
                    run_items(b, &g);
                    g.finish_runner();
                });
            // SAFETY: the ticket borrows `batch` and `f` from this
            // stack frame; the gate it signals through is its own Arc
            // clone, never reached via the borrow.  Inside the ticket,
            // every access to the borrowed data happens strictly before
            // the gate decrement that accounts for it (items/results/f
            // before each `finish_item`, nothing after `finish_runner`),
            // and `map` does not return until `remaining == 0` AND
            // `live_runners == 0` (SeqCst RMW chain, so those accesses
            // happen-before the caller's exit).  The erased lifetime
            // therefore never outlives the borrowed data.  No code
            // between here and the wait loop can panic: every mutex in
            // the pool is only ever locked around plain queue/slot
            // operations (user closures run outside all locks), so the
            // `.unwrap()`s on lock results never fire.
            let ticket: Task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Task>(
                    ticket,
                )
            };
            self.enqueue(batch_id, ticket);
        }
        // Work through the batch on this thread too.
        run_items(&batch, &gate);
        // Wait for in-flight items and for every ticket to finish.
        // Tickets of THIS batch that are still queued are reclaimed and
        // run inline — that alone guarantees liveness under nesting
        // (every batch drains through threads already committed to it),
        // without ever pulling unrelated work into this call.
        loop {
            if gate.done() {
                break;
            }
            // Scan the queue only while some of our tickets may still
            // be sitting on it; afterwards every wait iteration is a
            // pair of atomic loads plus the condvar.
            if gate.queued.load(Ordering::SeqCst) > 0 {
                let own = {
                    let mut q = self.shared.queue.lock().unwrap();
                    match q.iter().position(|t| t.batch == batch_id) {
                        Some(i) => q.remove(i),
                        None => None,
                    }
                };
                if let Some(task) = own {
                    (task.run)();
                    continue;
                }
            }
            let guard = gate.lock.lock().unwrap();
            if gate.done() {
                break;
            }
            // Timeout as a belt-and-braces liveness guard; the normal
            // wake-up is the notify in `finish_item`/`finish_runner`.
            let _ = gate
                .cv
                .wait_timeout(guard, Duration::from_millis(5))
                .unwrap();
        }
        if let Some(payload) = batch.panic.into_inner().unwrap() {
            resume_unwind(payload);
        }
        let slots = batch.results.into_inner().unwrap();
        slots
            .into_iter()
            .map(|r| r.expect("every mapped item produced a result"))
            .collect()
    }

    /// Push a task tagged with its batch identity (`0` = standalone
    /// job) and wake one worker.  Notifying while the queue lock is
    /// held closes the race with a worker that is between its
    /// empty-queue check and its `wait`.
    fn enqueue(&self, batch: usize, run: Task) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(QueueTask { batch, run });
        self.shared.work_cv.notify_one();
    }
}

impl Drop for WorkerPool {
    /// Drains the queue, then joins every worker.  Jobs already
    /// submitted still run to completion before the pool goes away.
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            // Lock before notifying so no worker is between its
            // shutdown check and its wait when the signal fires.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker thread body: pop and run tasks until shutdown drains the
/// queue.  Tasks are pre-wrapped so they never unwind into this loop.
fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        match task {
            Some(t) => (t.run)(),
            None => return,
        }
    }
}

/// Completion gate of one `map` call.  Lives in an `Arc` so every
/// ticket owns a strong reference: the decrement that releases the
/// waiting caller, and the notify that follows it, only ever touch
/// reference-counted memory — never the stack-allocated [`Batch`] the
/// caller is about to destroy.
struct Gate {
    /// Items not yet finished (started or not).
    remaining: AtomicUsize,
    /// Pool tickets that have not yet run to completion.
    live_runners: AtomicUsize,
    /// Tickets still sitting on the pool queue (decremented when a
    /// ticket starts running); lets the waiter skip the queue scan once
    /// all of its tickets are running or done.
    queued: AtomicUsize,
    /// Lock/condvar pair the caller waits on for completion.
    lock: Mutex<()>,
    cv: Condvar,
}

impl Gate {
    /// All items finished and all pool tickets done with the batch.
    fn done(&self) -> bool {
        self.remaining.load(Ordering::SeqCst) == 0
            && self.live_runners.load(Ordering::SeqCst) == 0
    }

    /// Mark one item finished; wake the waiting caller only on the
    /// zero transition (earlier wakes can't change its `done` check).
    fn finish_item(&self) {
        if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// Mark one pool ticket finished; wake the waiting caller only on
    /// the zero transition.
    fn finish_runner(&self) {
        if self.live_runners.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

/// One `map` call's borrowed state: its private item queue, result
/// slots and panic bookkeeping.  Only touched *before* the gate
/// decrement that accounts for the touching runner, so the caller can
/// safely destroy it once [`Gate::done`] holds.
struct Batch<'a, T, R, F> {
    /// Items not yet started, with their output index.
    items: Mutex<VecDeque<(usize, T)>>,
    /// One slot per item, filled in input order.
    results: Mutex<Vec<Option<R>>>,
    /// The map closure, shared by every runner.
    f: &'a F,
    /// Set on the first panic; remaining items are then skipped.
    cancelled: AtomicBool,
    /// First panic payload, re-raised by the caller.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// Runner body shared by the caller and the pool tickets: pull items
/// from the batch queue until it is empty.  `gate` is the runner's own
/// (owned or caller-held) handle, NOT reached through `batch`, so the
/// wake-up after the final item decrement never dereferences the batch.
fn run_items<T, R, F>(batch: &Batch<'_, T, R, F>, gate: &Gate)
where
    F: Fn(T) -> R,
{
    loop {
        let next = batch.items.lock().unwrap().pop_front();
        let Some((idx, item)) = next else { break };
        if batch.cancelled.load(Ordering::SeqCst) {
            // A sibling panicked: count the item done without running.
            gate.finish_item();
            continue;
        }
        // Catch panics outside any lock so no mutex is ever poisoned
        // by user code.
        match catch_unwind(AssertUnwindSafe(|| (batch.f)(item))) {
            Ok(out) => {
                batch.results.lock().unwrap()[idx] = Some(out);
            }
            Err(payload) => {
                let mut first = batch.panic.lock().unwrap();
                if first.is_none() {
                    *first = Some(payload);
                }
                batch.cancelled.store(true, Ordering::SeqCst);
            }
        }
        gate.finish_item();
    }
}

/// Map `f` over `items` using up to `workers` threads of the
/// process-wide [`WorkerPool::global`] pool, preserving input order.
///
/// This is the crate-wide fan-out primitive: solver restarts, batched
/// candidate evaluation, per-run experiment fan-outs and engine
/// compression jobs all route through it, so they all share one set of
/// long-lived threads instead of spawning their own.
///
/// `workers == 1` (or a single item) runs inline on the calling thread
/// and is bit-for-bit the legacy serial path.
///
/// ```
/// use intdecomp::util::threadpool::parallel_map;
///
/// let tripled = parallel_map(vec![1, 2, 3], 4, |x: i32| x * 3);
/// assert_eq!(tripled, vec![3, 6, 9]);
/// ```
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    WorkerPool::global().map(items, workers, f)
}

/// Number of workers to use by default (all available cores).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = parallel_map(items, 4, |x| x * 3);
        assert_eq!(out, (0..97).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn heavier_than_workers() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x % 7);
        assert_eq!(out.len(), 1000);
        assert_eq!(out[700], 0);
    }

    #[test]
    #[should_panic(expected = "boom 13")]
    fn worker_panic_propagates_payload() {
        let _ = parallel_map((0..64).collect::<Vec<i32>>(), 4, |x| {
            if x == 13 {
                panic!("boom {x}");
            }
            x * 2
        });
    }

    #[test]
    #[should_panic(expected = "inline boom")]
    fn inline_path_panic_propagates_too() {
        // workers == 1 takes the inline map; the panic must look the same.
        let _ = parallel_map(vec![1, 2], 1, |x| {
            if x == 2 {
                panic!("inline boom");
            }
            x
        });
    }

    #[test]
    fn survives_after_a_previous_panicked_call() {
        // A panicked map must not leave behind state that breaks the
        // next call on the same (global) pool.
        let r = catch_unwind(|| {
            parallel_map(vec![1, 2, 3, 4], 2, |x| {
                if x == 3 {
                    panic!("once");
                }
                x
            })
        });
        assert!(r.is_err());
        let ok = parallel_map(vec![1, 2, 3, 4], 2, |x| x + 1);
        assert_eq!(ok, vec![2, 3, 4, 5]);
    }

    #[test]
    fn pool_is_reused_across_many_maps() {
        // Thousands of fan-outs on one pool: the per-iteration pattern
        // of the BBO loop.  With per-call thread spawning this test is
        // painfully slow; on the persistent pool it is instant.
        let pool = WorkerPool::new(4);
        let mut acc = 0u64;
        for round in 0..2000u64 {
            let out =
                pool.map((0..8).collect::<Vec<u64>>(), 4, |x| x + round);
            acc += out.iter().sum::<u64>();
        }
        assert_eq!(acc, (0..2000u64).map(|r| 8 * r + 28).sum::<u64>());
    }

    #[test]
    fn caller_participates_when_pool_is_saturated() {
        // A 1-thread pool whose only worker is parked on a slow job:
        // map still completes because the caller runs items itself.
        let pool = WorkerPool::new(1);
        let (started_tx, started_rx) = channel();
        let (tx, rx) = channel::<()>();
        pool.submit(move || {
            // Hold the worker until the map below has finished.
            started_tx.send(()).unwrap();
            let _ = rx.recv();
        });
        // Make sure the worker really is parked on the blocking job
        // before mapping, so the pool is guaranteed saturated.
        started_rx.recv().unwrap();
        let out = pool.map(vec![1, 2, 3, 4], 4, |x: i32| x * x);
        assert_eq!(out, vec![1, 4, 9, 16]);
        tx.send(()).unwrap();
    }

    #[test]
    fn map_never_reclaims_unrelated_submit_jobs() {
        // A *queued* (not yet running) submit job that blocks must not
        // be pulled inline by a waiting map call — the map completes
        // and the job stays queued for a worker.
        let pool = WorkerPool::new(1);
        let (hold_tx, hold_rx) = channel::<()>();
        let (started_tx, started_rx) = channel();
        let (blocked_tx, blocked_rx) = channel::<()>();
        let (done_tx, done_rx) = channel();
        // Occupy the only worker...
        pool.submit(move || {
            started_tx.send(()).unwrap();
            let _ = hold_rx.recv();
        });
        started_rx.recv().unwrap();
        // ...then queue a second blocking job behind it.
        pool.submit(move || {
            let _ = blocked_rx.recv();
            done_tx.send(()).unwrap();
        });
        // The map must finish on the caller thread alone, without
        // touching either submit job.
        let out = pool.map(vec![5, 6, 7], 3, |x: i32| x - 5);
        assert_eq!(out, vec![0, 1, 2]);
        // Unblock both jobs; the queued one still runs to completion.
        hold_tx.send(()).unwrap();
        blocked_tx.send(()).unwrap();
        done_rx.recv().unwrap();
    }

    #[test]
    fn nested_maps_do_not_deadlock() {
        let pool = WorkerPool::new(2);
        let out = pool.map((0..6).collect::<Vec<u64>>(), 6, |i| {
            pool.map((0..5).collect::<Vec<u64>>(), 5, |j| 10 * i + j)
                .into_iter()
                .sum::<u64>()
        });
        assert_eq!(out[2], 20 + 21 + 22 + 23 + 24);
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn run_returns_result_over_channel() {
        let pool = WorkerPool::new(2);
        let rx = pool.run(|| 6 * 7);
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn run_panic_surfaces_as_recv_error() {
        let pool = WorkerPool::new(2);
        let rx = pool.run(|| -> i32 { panic!("job failed") });
        assert!(rx.recv().is_err());
        // The worker survived the panic and keeps serving jobs.
        assert_eq!(pool.run(|| 1).recv().unwrap(), 1);
    }

    #[test]
    fn drop_completes_submitted_jobs() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = channel();
        for i in 0..16 {
            let tx = tx.clone();
            pool.submit(move || tx.send(i).unwrap());
        }
        drop(tx);
        drop(pool); // joins workers after the queue drains
        let got: Vec<i32> = rx.iter().collect();
        assert_eq!(got.len(), 16);
    }

    #[test]
    fn map_results_are_worker_count_invariant() {
        let serial = parallel_map((0..50).collect::<Vec<i64>>(), 1, |x| {
            x * x - 3 * x
        });
        for workers in [2, 3, 8] {
            let par = parallel_map(
                (0..50).collect::<Vec<i64>>(),
                workers,
                |x| x * x - 3 * x,
            );
            assert_eq!(par, serial, "workers = {workers}");
        }
    }
}
