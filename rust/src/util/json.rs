//! Minimal JSON substrate: enough to read `artifacts/meta.json` and write
//! experiment results.  Supports the full JSON value grammar minus exotic
//! escapes (\uXXXX is decoded for the BMP; surrogate pairs are joined).
//!
//! **Round-trip contract** (ISSUE 6 — relied on by the shard result
//! logs, shard manifests and the serve protocol):
//!
//! * Finite floats serialise with Rust's shortest round-trip formatting;
//!   whole numbers below `1e15` drop the fraction (`42`, not `42.0`) —
//!   **except negative zero**, which serialises as `-0.0` so the sign
//!   bit survives a serialise→parse→serialise cycle bit-exactly.
//! * `\u` escapes forming an **unpaired surrogate** (a high surrogate
//!   not immediately followed by a `\u`-escaped low surrogate, or a
//!   bare low surrogate) are a parse **error** — never silently
//!   dropped.  Paired surrogates decode to the astral-plane scalar.
//! * [`Json::as_usize`] / [`Json::as_u64`] accept exact whole numbers
//!   only (`1.9` and `-3.0` are rejected, not truncated or saturated).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Typed serialisation error of [`Json::to_string_strict`]: the value
/// tree holds a NaN/±Inf number, which JSON cannot represent and a
/// schema boundary must not round-trip into `null`.
#[derive(Clone, Debug, PartialEq)]
pub struct NonFiniteJson {
    /// Dotted object path to the offending number ("" at the root;
    /// array indices are not tracked).
    pub path: String,
    /// The non-finite value itself.
    pub value: f64,
}

impl std::fmt::Display for NonFiniteJson {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.path.is_empty() {
            write!(f, "non-finite number ({}) in JSON output", self.value)
        } else {
            write!(
                f,
                "non-finite number ({}) at '{}' in JSON output",
                self.value, self.path
            )
        }
    }
}

impl std::error::Error for NonFiniteJson {}

/// JSON value tree (object keys ordered for deterministic output).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (keys ordered for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Object member lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Exact whole-number value as usize.  Delegates to
    /// [`Json::as_u64`], so fractional (`1.9`), negative (`-3.0`) and
    /// beyond-2⁵³ values are rejected rather than truncated or
    /// saturated — numeric config/meta/manifest fields read through
    /// this accessor fail loudly on malformed input.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    /// Exact unsigned integer value, if this is a non-negative whole
    /// number small enough for f64 to carry exactly (≤ 2⁵³) — the
    /// round-trip-safe accessor the shard manifests use for seeds.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x)
                if *x >= 0.0
                    && x.fract() == 0.0
                    && *x <= 9_007_199_254_740_992.0 =>
            {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// Boolean value, if this is `true` or `false`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialise to compact JSON text.
    ///
    /// **Lossy for non-finite numbers**: JSON has no NaN/Inf, so a
    /// non-finite [`Json::Num`] is emitted as `null` — acceptable for
    /// display-only output, but a silent data loss at a schema boundary
    /// (a cost round-tripping into `null` would corrupt a checkpoint).
    /// Durable/schema writes use [`Json::to_string_strict`] instead.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// [`Json::to_string`] that *fails* on non-finite numbers instead
    /// of silently emitting `null` (ISSUE 9).  This is the entry point
    /// for every schema boundary — shard result/checkpoint lines, bench
    /// rows, serve stats — where a NaN/Inf reaching the serialiser is a
    /// bug upstream that must surface as a typed error, not a corrupted
    /// record.
    pub fn to_string_strict(&self) -> Result<String, NonFiniteJson> {
        self.check_finite(&mut Vec::new())?;
        Ok(self.to_string())
    }

    fn check_finite<'a>(
        &'a self,
        path: &mut Vec<&'a str>,
    ) -> Result<(), NonFiniteJson> {
        match self {
            Json::Num(x) if !x.is_finite() => Err(NonFiniteJson {
                path: path.join("."),
                value: *x,
            }),
            Json::Arr(v) => {
                for x in v {
                    x.check_finite(path)?;
                }
                Ok(())
            }
            Json::Obj(m) => {
                for (k, v) in m {
                    path.push(k);
                    let out = v.check_finite(path);
                    path.pop();
                    out?;
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == 0.0 && x.is_sign_negative() {
                        // Keep the sign bit: `-0.0 as i64` is 0, which
                        // would break the bit-exact float round trip.
                        out.push_str("-0.0");
                    } else if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse JSON text.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes: Vec<char> = text.chars().collect();
        let mut p = Parser { s: &bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.s.len() {
            return Err(format!("trailing garbage at char {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    s: &'a [char],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: char) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{c}' at char {}", self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        for c in word.chars() {
            self.eat(c)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some('n') => self.lit("null", Json::Null),
            Some('t') => self.lit("true", Json::Bool(true)),
            Some('f') => self.lit("false", Json::Bool(false)),
            Some('"') => self.string().map(Json::Str),
            Some('[') => self.array(),
            Some('{') => self.object(),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at char {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat('"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        '"' => out.push('"'),
                        '\\' => out.push('\\'),
                        '/' => out.push('/'),
                        'n' => out.push('\n'),
                        't' => out.push('\t'),
                        'r' => out.push('\r'),
                        'b' => out.push('\u{8}'),
                        'f' => out.push('\u{c}'),
                        'u' => {
                            let code = self.hex4()?;
                            if (0xD800..0xDC00).contains(&code) {
                                // A high surrogate is only valid when
                                // the very next escape is a low
                                // surrogate; anything else (string
                                // end, ordinary char, non-low escape)
                                // is a hard error — silently dropping
                                // it would lose data on round trip.
                                if self.peek() != Some('\\') {
                                    return Err(
                                        "unpaired high surrogate".into(),
                                    );
                                }
                                self.i += 1;
                                if self.peek() != Some('u') {
                                    return Err(
                                        "unpaired high surrogate".into(),
                                    );
                                }
                                self.i += 1;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(
                                        "unpaired high surrogate".into(),
                                    );
                                }
                                let c = 0x10000
                                    + ((code - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(c).ok_or("bad surrogate")?,
                                );
                            } else if (0xDC00..0xE000).contains(&code) {
                                return Err("unpaired low surrogate".into());
                            } else {
                                out.push(
                                    char::from_u32(code)
                                        .ok_or("bad codepoint")?,
                                );
                            }
                        }
                        _ => return Err(format!("bad escape \\{e}")),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut code = 0u32;
        for _ in 0..4 {
            let h = self.peek().ok_or("bad \\u")?;
            self.i += 1;
            code = code * 16 + h.to_digit(16).ok_or("bad hex")?;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, '-' | '+' | '.' | 'e' | 'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let text: String = self.s[start..self.i].iter().collect();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat('[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(',') => self.i += 1,
                Some(']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat('{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some('}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(',') => self.i += 1,
                Some('}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.5)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("c", Json::Str("hi \"there\"\n".into())),
            ("d", Json::obj(vec![("x", Json::Num(-3.0))])),
        ]);
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn parse_meta_like() {
        let text = r#"{"n": 8, "d": 100, "kfms": [8, 12],
                       "feature_order": "bias, linear"}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("kfms").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("feature_order").unwrap().as_str(),
            Some("bias, linear")
        );
    }

    #[test]
    fn parse_numbers() {
        for (t, v) in [
            ("0", 0.0),
            ("-1.25", -1.25),
            ("3e2", 300.0),
            ("1.5E-3", 0.0015),
        ] {
            assert_eq!(Json::parse(t).unwrap(), Json::Num(v));
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulla").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn surrogate_pair_escape_decodes_astral_scalar() {
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn unpaired_surrogates_are_parse_errors() {
        // High surrogate at string end.
        assert!(Json::parse(r#""\ud83d""#).is_err());
        // High surrogate followed by an ordinary char.
        assert!(Json::parse(r#""\ud83dX""#).is_err());
        // High surrogate followed by a non-\u escape.
        assert!(Json::parse(r#""\ud83d\n""#).is_err());
        // High surrogate followed by a non-low \u escape.
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err());
        // Two high surrogates in a row.
        assert!(Json::parse(r#""\ud83d\ud83d""#).is_err());
        // Bare low surrogate.
        assert!(Json::parse(r#""\udc00""#).is_err());
    }

    #[test]
    fn integers_serialise_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn negative_zero_roundtrips_bit_exactly() {
        assert_eq!(Json::Num(-0.0).to_string(), "-0.0");
        assert_eq!(Json::Num(0.0).to_string(), "0");
        let back = Json::parse("-0.0").unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // And a second serialise produces the same bytes.
        assert_eq!(Json::Num(back).to_string(), "-0.0");
    }

    #[test]
    fn as_usize_is_exact_only() {
        assert_eq!(Json::Num(8.0).as_usize(), Some(8));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(1.9).as_usize(), None);
        assert_eq!(Json::Num(-3.0).as_usize(), None);
        assert_eq!(Json::Num(1e18).as_usize(), None); // beyond 2^53
        assert_eq!(Json::Str("7".into()).as_usize(), None);
    }

    #[test]
    fn as_u64_is_exact_only() {
        assert_eq!(Json::Num(5005.0).as_u64(), Some(5005));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(1e18).as_u64(), None); // beyond 2^53
        assert_eq!(Json::Str("7".into()).as_u64(), None);
    }

    #[test]
    fn as_bool_only_on_bools() {
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Bool(false).as_bool(), Some(false));
        assert_eq!(Json::Num(1.0).as_bool(), None);
    }

    #[test]
    fn strict_write_matches_lossy_write_on_finite_trees() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.5)),
            ("b", Json::Arr(vec![Json::Num(-0.0), Json::Str("x".into())])),
            ("c", Json::Null),
        ]);
        assert_eq!(j.to_string_strict().unwrap(), j.to_string());
    }

    #[test]
    fn strict_write_rejects_nested_nan_with_dotted_path() {
        let j = Json::obj(vec![(
            "a",
            Json::obj(vec![("b", Json::Num(f64::NAN))]),
        )]);
        let err = j.to_string_strict().unwrap_err();
        assert_eq!(err.path, "a.b");
        assert!(err.value.is_nan());
        assert!(err.to_string().contains("a.b"));
    }

    #[test]
    fn strict_write_rejects_infinity_inside_arrays() {
        let j = Json::obj(vec![(
            "rows",
            Json::Arr(vec![Json::Num(1.0), Json::Num(f64::INFINITY)]),
        )]);
        let err = j.to_string_strict().unwrap_err();
        assert_eq!(err.path, "rows");
        assert_eq!(err.value, f64::INFINITY);
    }

    #[test]
    fn strict_write_rejects_root_non_finite() {
        let err = Json::Num(f64::NEG_INFINITY).to_string_strict().unwrap_err();
        assert_eq!(err.path, "");
        assert_eq!(err.value, f64::NEG_INFINITY);
    }

    #[test]
    fn lossy_write_still_emits_null_for_non_finite() {
        // to_string() keeps the display-only lossy contract; strict is
        // the schema-boundary writer.
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
