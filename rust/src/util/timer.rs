//! Wall-clock timing helpers for the experiment harness and benches.

use std::time::Instant;

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since `start`.
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed since `start`.
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.seconds())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.seconds();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = t.seconds();
        assert!(b >= a);
        assert!(b >= 0.002);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
