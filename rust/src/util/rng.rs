//! Deterministic pseudo-random substrate.
//!
//! Core generator is xoshiro256++ seeded through SplitMix64 — fast, well
//! tested statistically, and trivially reproducible across runs (every
//! experiment takes an explicit seed).  On top of it sit the distributions
//! the surrogate samplers need: normal, gamma / inverse-gamma (Marsaglia &
//! Tsang), half-Cauchy (inverse CDF), exponential, plus ±1 spin vectors and
//! Fisher–Yates shuffling.

/// xoshiro256++ generator with distribution helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller deviate.
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent child stream (for per-run / per-thread RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Fill `out` with the next raw 64-bit outputs, in stream order —
    /// the batched sibling of [`Rng::next_u64`].  `fill_u64s` followed by
    /// consuming the buffer front-to-back is bit-identical to calling
    /// `next_u64` once per element, which is what lets the replica
    /// engine's buffered draw source ([`crate::solvers::replica`]) batch
    /// the Metropolis uniforms per sweep without changing any stream.
    pub fn fill_u64s(&mut self, out: &mut [u64]) {
        for o in out.iter_mut() {
            *o = self.next_u64();
        }
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free multiply-shift; bias < 2^-64 * n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Fair coin.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Random spin ±1.
    #[inline]
    pub fn spin(&mut self) -> i8 {
        if self.bool() {
            1
        } else {
            -1
        }
    }

    /// Vector of n random spins.
    pub fn spins(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.spin()).collect()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Guard against log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Vector of n standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n];
        self.fill_normals(&mut out);
        out
    }

    /// Fill `out` with standard normals — the allocation-free sibling of
    /// [`Rng::normals`], consuming the identical stream (the posterior
    /// scratch path relies on that equivalence).
    pub fn fill_normals(&mut self, out: &mut [f64]) {
        for o in out.iter_mut() {
            *o = self.normal();
        }
    }

    /// Exponential with rate 1.
    pub fn exp(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang, with the shape < 1 boost.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Boost: X ~ Gamma(a+1), U^(1/a) * X ~ Gamma(a).
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v3 * scale;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3 * scale;
            }
        }
    }

    /// Inverse-gamma(shape, scale): 1 / Gamma(shape, 1/scale).
    pub fn inv_gamma(&mut self, shape: f64, scale: f64) -> f64 {
        1.0 / self.gamma(shape, 1.0 / scale)
    }

    /// Half-Cauchy(0, scale) via inverse CDF: scale * tan(pi U / 2).
    pub fn half_cauchy(&mut self, scale: f64) -> f64 {
        let u = self.f64();
        scale * (std::f64::consts::FRAC_PI_2 * u).tan()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices drawn from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        assert!((acc / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        assert!((m1 / n as f64).abs() < 0.02);
        assert!((m2 / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(13);
        for &(shape, scale) in &[(0.5, 2.0), (1.0, 1.0), (3.5, 0.5)] {
            let n = 100_000;
            let mut acc = 0.0;
            for _ in 0..n {
                acc += r.gamma(shape, scale);
            }
            let want = shape * scale;
            assert!(
                (acc / n as f64 - want).abs() < 0.05 * want.max(0.2),
                "shape={shape} scale={scale}"
            );
        }
    }

    #[test]
    fn inv_gamma_mean() {
        // mean = scale / (shape - 1) for shape > 1.
        let mut r = Rng::new(17);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += r.inv_gamma(3.0, 4.0);
        }
        assert!((acc / n as f64 - 2.0).abs() < 0.05);
    }

    #[test]
    fn half_cauchy_median() {
        let mut r = Rng::new(19);
        let n = 100_000;
        let mut below = 0usize;
        for _ in 0..n {
            assert!(r.half_cauchy(2.0) >= 0.0);
            if r.half_cauchy(2.0) < 2.0 {
                below += 1;
            }
        }
        // Median of half-Cauchy(0, s) is s.
        assert!((below as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(23);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(31);
        for _ in 0..100 {
            let idx = r.sample_indices(20, 8);
            assert_eq!(idx.len(), 8);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
        }
    }

    #[test]
    fn fill_u64s_matches_sequential_draws() {
        let mut a = Rng::new(41);
        let mut b = Rng::new(41);
        let mut buf = [0u64; 37];
        a.fill_u64s(&mut buf);
        for &v in &buf {
            assert_eq!(v, b.next_u64());
        }
        // Post-fill state is the same as after the equivalent draws.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn spins_are_pm_one() {
        let mut r = Rng::new(37);
        let v = r.spins(1000);
        assert!(v.iter().all(|&s| s == 1 || s == -1));
        let ones = v.iter().filter(|&&s| s == 1).count();
        assert!(ones > 400 && ones < 600);
    }
}
