//! Advisory PID lockfiles guarding single-writer on-disk state.
//!
//! Two `shard work` processes pointed at the same result log would
//! interleave appends and corrupt the valid prefix that
//! [`crate::shard::recover_log`] trusts, so the worker (and the serve
//! daemon, for its state directory) takes an advisory lock first and
//! fails fast with a clear error when another live process holds it.
//!
//! The lock is a sidecar file created with `O_EXCL` holding the owner's
//! PID.  A lock whose owner is no longer alive (checked via
//! `/proc/<pid>` on Linux) or whose contents are unparseable is *stale*
//! and is taken over — a SIGKILLed worker must never wedge a resume.
//! Like all advisory locks this guards against accidents, not
//! adversaries: a process that ignores the protocol can still write.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A held advisory lock; releasing is dropping (the sidecar file is
/// removed).  After a crash the file lingers, but the dead PID inside
/// makes it stale, so the next acquirer reclaims it.
#[derive(Debug)]
pub struct LockFile {
    path: PathBuf,
}

impl LockFile {
    /// The sidecar lockfile path guarding `target` (the target path
    /// with `.lock` appended, so `out.jsonl` → `out.jsonl.lock`).
    pub fn path_for(target: &Path) -> PathBuf {
        let mut os = target.as_os_str().to_os_string();
        os.push(".lock");
        PathBuf::from(os)
    }

    /// Acquire the advisory lock guarding `target`.  Fails fast —
    /// without blocking — when another live process holds it; silently
    /// takes over stale locks (dead owner, unreadable contents).
    pub fn acquire(target: &Path) -> Result<LockFile> {
        let path = Self::path_for(target);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating {}", parent.display())
                })?;
            }
        }
        // The takeover (unlink + retry create) can race another
        // acquirer doing the same; a handful of retries settles it.
        for _ in 0..16 {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    let _ = f.sync_data();
                    return Ok(LockFile { path });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::AlreadyExists =>
                {
                    match std::fs::read_to_string(&path) {
                        Ok(body) => match body.trim().parse::<u32>() {
                            Ok(pid) if pid_alive(pid) => bail!(
                                "{}: held by live process {pid} — another \
                                 worker is using {}; if that pid is stale \
                                 (non-Linux host), remove the lockfile",
                                path.display(),
                                target.display(),
                            ),
                            // Dead owner or garbage contents: stale.
                            _ => {
                                let _ = std::fs::remove_file(&path);
                            }
                        },
                        // Holder released between create and read.
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::NotFound => {}
                        Err(e) => {
                            return Err(e).with_context(|| {
                                format!("reading {}", path.display())
                            })
                        }
                    }
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("creating {}", path.display())
                    })
                }
            }
        }
        bail!(
            "{}: could not acquire after repeated takeover races",
            path.display()
        );
    }

    /// The sidecar file this lock holds.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for LockFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Whether `pid` names a live process.  On Linux this is a `/proc`
/// lookup; elsewhere we conservatively report alive, so stale locks on
/// such hosts need manual removal (the error message says so).
fn pid_alive(pid: u32) -> bool {
    if cfg!(target_os = "linux") {
        Path::new("/proc").join(pid.to_string()).exists()
    } else {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("intdecomp_lockfile");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn second_acquire_fails_while_held_and_succeeds_after_drop() {
        let target = tmp("log_a.jsonl");
        let lock = LockFile::acquire(&target).unwrap();
        assert!(lock.path().exists());
        let held = LockFile::acquire(&target);
        assert!(held.is_err());
        assert!(held
            .unwrap_err()
            .to_string()
            .contains("held by live process"));
        drop(lock);
        assert!(!LockFile::path_for(&target).exists());
        let again = LockFile::acquire(&target).unwrap();
        drop(again);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn stale_lock_with_dead_pid_is_taken_over() {
        let target = tmp("log_b.jsonl");
        // PID near the 32-bit cap: far above kernel.pid_max, so no
        // live process can own it.
        std::fs::write(LockFile::path_for(&target), "4294967294\n")
            .unwrap();
        let lock = LockFile::acquire(&target).unwrap();
        drop(lock);
    }

    #[test]
    fn unparseable_lock_is_taken_over() {
        let target = tmp("log_c.jsonl");
        std::fs::write(LockFile::path_for(&target), "not a pid").unwrap();
        let lock = LockFile::acquire(&target).unwrap();
        drop(lock);
    }
}
