//! Deterministic fault injection for the robustness tests (ISSUE 9).
//!
//! A [`FaultPlan`] schedules numeric faults by zero-based call index:
//! Cholesky failures in the posterior draw, NaN oracle costs, and an
//! injected panic.  [`FaultyOracle`] and [`FaultyPosterior`] wrap the
//! real implementations and execute the plan with atomic call counters,
//! so the same plan injects the same faults at the same points on every
//! run — the fault tests assert *exact* degradation counts, not "some
//! fault happened".
//!
//! These wrappers are test instrumentation, not production code paths:
//! nothing in the library constructs them outside `#[cfg(test)]` code
//! and the integration tests.  `FaultyOracle::eval_batch` deliberately
//! evaluates serially so call indices are assigned in candidate order
//! regardless of the worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::linalg::{CholeskyError, Matrix, NumericError};
use crate::minlp::Oracle;
use crate::surrogate::blr::{PosteriorBackend, PosteriorScratch};

/// A deterministic schedule of numeric faults, by zero-based call index
/// of the wrapper that executes it.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Posterior-draw call indices that fail with a synthetic
    /// [`NumericError::PosteriorNotSpd`] (consumed by
    /// [`FaultyPosterior`]).
    pub cholesky_fail: Vec<usize>,
    /// Oracle evaluation indices that return `NaN` instead of the true
    /// cost (consumed by [`FaultyOracle`]).
    pub nan_cost: Vec<usize>,
    /// Oracle evaluation index at which to `panic!` (consumed by
    /// [`FaultyOracle`]) — exercises the engine's panic containment.
    pub panic_at: Option<usize>,
}

impl FaultPlan {
    /// The all-clear plan: wrappers pass every call through untouched.
    /// Runs under an empty plan must stay bit-identical to unwrapped
    /// runs — the fault tests assert exactly that.
    pub fn none() -> Self {
        Self::default()
    }
}

/// An [`Oracle`] wrapper that injects the `nan_cost` / `panic_at`
/// entries of a [`FaultPlan`], counting evaluations in candidate order.
pub struct FaultyOracle<'a> {
    inner: &'a dyn Oracle,
    plan: FaultPlan,
    calls: AtomicUsize,
}

impl<'a> FaultyOracle<'a> {
    /// Wrap `inner` under `plan` with the call counter at zero.
    pub fn new(inner: &'a dyn Oracle, plan: FaultPlan) -> Self {
        Self { inner, plan, calls: AtomicUsize::new(0) }
    }

    /// Evaluations observed so far (including the faulted ones).
    pub fn evals(&self) -> usize {
        self.calls.load(Ordering::SeqCst)
    }
}

impl Oracle for FaultyOracle<'_> {
    fn n_bits(&self) -> usize {
        self.inner.n_bits()
    }

    fn eval(&self, x: &[i8]) -> f64 {
        let idx = self.calls.fetch_add(1, Ordering::SeqCst);
        if self.plan.panic_at == Some(idx) {
            panic!("injected oracle panic at evaluation {idx}");
        }
        if self.plan.nan_cost.contains(&idx) {
            return f64::NAN;
        }
        self.inner.eval(x)
    }

    // Serial on purpose: batch evaluation must assign call indices in
    // candidate order, or the plan would fire nondeterministically
    // under the thread pool.
    fn eval_batch(&self, xs: &[Vec<i8>], _workers: usize) -> Vec<f64> {
        xs.iter().map(|x| self.eval(x)).collect()
    }

    fn equivalents(&self, x: &[i8]) -> Vec<Vec<i8>> {
        self.inner.equivalents(x)
    }
}

/// Shared draw counters of a [`FaultyPosterior`], cloneable before the
/// backend is moved into a `Backends` factory so the test can read them
/// after the run.
#[derive(Clone, Debug, Default)]
pub struct DrawCounters {
    /// Posterior draws attempted (faulted ones included).
    pub calls: Arc<AtomicUsize>,
    /// Draws that failed with the injected Cholesky error.
    pub injected: Arc<AtomicUsize>,
}

impl DrawCounters {
    /// Draws attempted so far.
    pub fn calls(&self) -> usize {
        self.calls.load(Ordering::SeqCst)
    }

    /// Injected failures so far.
    pub fn injected(&self) -> usize {
        self.injected.load(Ordering::SeqCst)
    }
}

/// A [`PosteriorBackend`] wrapper that fails the draws named by
/// `FaultPlan::cholesky_fail` with a synthetic non-SPD error, passing
/// every other draw through to the wrapped backend.
pub struct FaultyPosterior<B: PosteriorBackend> {
    inner: B,
    cholesky_fail: Vec<usize>,
    counters: DrawCounters,
}

impl<B: PosteriorBackend> FaultyPosterior<B> {
    /// Wrap `inner`, failing the zero-based draw indices in
    /// `cholesky_fail`; `counters` should be cloned from
    /// [`DrawCounters::default`] kept by the test.
    pub fn new(
        inner: B,
        cholesky_fail: Vec<usize>,
        counters: DrawCounters,
    ) -> Self {
        Self { inner, cholesky_fail, counters }
    }

    fn inject(&self) -> Option<NumericError> {
        let idx = self.counters.calls.fetch_add(1, Ordering::SeqCst);
        if self.cholesky_fail.contains(&idx) {
            self.counters.injected.fetch_add(1, Ordering::SeqCst);
            // The same shape a real exhausted jitter ladder reports.
            Some(NumericError::PosteriorNotSpd(CholeskyError {
                attempts: 6,
                max_jitter: 1e-2,
            }))
        } else {
            None
        }
    }
}

impl<B: PosteriorBackend> PosteriorBackend for FaultyPosterior<B> {
    fn draw(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
    ) -> Result<(Vec<f64>, f64), NumericError> {
        if let Some(e) = self.inject() {
            return Err(e);
        }
        self.inner.draw(g, gv, lam, sigma_n2, z)
    }

    fn draw_into(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
        scratch: &mut PosteriorScratch,
    ) -> Result<f64, NumericError> {
        if let Some(e) = self.inject() {
            return Err(e);
        }
        self.inner.draw_into(g, gv, lam, sigma_n2, z, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::blr::NativePosterior;

    struct Quad;
    impl Oracle for Quad {
        fn n_bits(&self) -> usize {
            4
        }
        fn eval(&self, x: &[i8]) -> f64 {
            x.iter().map(|&s| s as f64).sum::<f64>().powi(2)
        }
    }

    #[test]
    fn empty_plan_is_transparent() {
        let o = FaultyOracle::new(&Quad, FaultPlan::none());
        let x = vec![1i8, -1, 1, 1];
        assert_eq!(o.eval(&x), Quad.eval(&x));
        assert_eq!(o.evals(), 1);
    }

    #[test]
    fn nan_plan_fires_at_exact_indices() {
        let plan = FaultPlan { nan_cost: vec![1, 3], ..Default::default() };
        let o = FaultyOracle::new(&Quad, plan);
        let xs: Vec<Vec<i8>> = (0..5).map(|_| vec![1i8; 4]).collect();
        let ys = o.eval_batch(&xs, 8);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(y.is_nan(), i == 1 || i == 3, "index {i}");
        }
        assert_eq!(o.evals(), 5);
    }

    #[test]
    #[should_panic(expected = "injected oracle panic at evaluation 2")]
    fn panic_plan_fires() {
        let plan = FaultPlan { panic_at: Some(2), ..Default::default() };
        let o = FaultyOracle::new(&Quad, plan);
        for _ in 0..3 {
            let _ = o.eval(&[1, 1, 1, 1]);
        }
    }

    #[test]
    fn faulty_posterior_fails_named_draws_only() {
        let counters = DrawCounters::default();
        let be = FaultyPosterior::new(
            NativePosterior,
            vec![1],
            counters.clone(),
        );
        let g = {
            let mut g = Matrix::zeros(2, 2);
            g[(0, 0)] = 4.0;
            g[(1, 1)] = 4.0;
            g
        };
        let (gv, lam, z) = (vec![1.0, 1.0], vec![0.5, 0.5], vec![0.0, 0.0]);
        assert!(be.draw(&g, &gv, &lam, 1.0, &z).is_ok());
        let err = be.draw(&g, &gv, &lam, 1.0, &z).unwrap_err();
        assert!(matches!(err, NumericError::PosteriorNotSpd(_)));
        assert!(be.draw(&g, &gv, &lam, 1.0, &z).is_ok());
        assert_eq!(counters.calls(), 3);
        assert_eq!(counters.injected(), 1);
    }
}
