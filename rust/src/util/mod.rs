//! Support substrates: RNG, JSON, timers, thread pool, property testing.
//!
//! The build environment is offline with a minimal vendored crate set, so
//! these are purpose-built rather than pulled from crates.io (DESIGN.md §6).

pub mod cancel;
pub mod fault;
pub mod json;
pub mod lockfile;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod timer;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Unbiased sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Half-width of the normal-approximation 95% confidence interval.
pub fn ci95(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Nearest-rank percentile of an ascending-sorted sample (`q` in
/// percent, clamped to `[0, 100]`; `0.0` for empty input).  Shared by
/// the bench harness (`p50_s`/`p99_s` rows) and the serve daemon's
/// latency stats.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 100.0);
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// Moving-average smoothing with the given window (paper Fig. 4 uses 100).
pub fn smooth(xs: &[f64], window: usize) -> Vec<f64> {
    if window <= 1 || xs.is_empty() {
        return xs.to_vec();
    }
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0;
    for i in 0..xs.len() {
        acc += xs[i];
        if i >= window {
            acc -= xs[i - window];
        }
        let n = (i + 1).min(window);
        out.push(acc / n as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(ci95(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 75.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        let big: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&big, 99.0), 99.0);
        assert_eq!(percentile(&big, 50.0), 50.0);
    }

    #[test]
    fn smooth_window_one_is_identity() {
        let xs = [3.0, 1.0, 4.0];
        assert_eq!(smooth(&xs, 1), xs.to_vec());
    }

    #[test]
    fn smooth_flattens_constant() {
        let xs = vec![2.0; 50];
        for v in smooth(&xs, 10) {
            assert!((v - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn smooth_warmup_prefix_uses_partial_window() {
        let xs = [1.0, 3.0, 5.0, 7.0];
        let s = smooth(&xs, 2);
        assert!((s[0] - 1.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
        assert!((s[2] - 4.0).abs() < 1e-12);
    }
}
