//! The canonical synthetic-model workload description every process in a
//! sharded run agrees on.
//!
//! A [`ModelSpec`] is the *complete* determinism domain of one
//! `compress-model` workload: instance shape and generator seed, BBO
//! budget, algorithm/solver names, base seed and cache-key policy.  Both
//! the single-process `compress-model` command and every `shard work`
//! process build their [`crate::engine::CompressionJob`]s through
//! [`ModelSpec::job`], so a job is constructed identically no matter
//! which process runs it — the foundation of the shard subsystem's
//! byte-identity contract.
//!
//! Specs serialise to JSON ([`ModelSpec::to_json`] /
//! [`ModelSpec::from_json`]) inside shard manifests, and hash to a
//! [`ModelSpec::fingerprint`] that tags every manifest and result-log
//! line, so results from a different workload can never be merged by
//! accident.

use anyhow::{anyhow, bail, Result};

use crate::bbo::{Algorithm, BboConfig};
use crate::engine::{CacheKeyMode, CompressionJob, EngineConfig};
use crate::instance::{generate, InstanceConfig};
use crate::solvers;
use crate::util::json::Json;

/// Largest seed value that survives the JSON round trip exactly (spec
/// integers travel as f64, so 2⁵³); [`ModelSpec::validate`] rejects
/// anything bigger to keep the cross-process determinism contract
/// airtight.
const MAX_EXACT_SEED: u64 = 1 << 53;

/// Complete description of one multi-layer compression workload — the
/// determinism domain shared by `compress-model` and the `shard`
/// pipeline.
///
/// Layer `i` compresses instance `generate(instance_cfg, i)` with seed
/// `seed + i`; nothing about a job depends on which process (or how many
/// sibling processes) runs it.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// Layer matrix rows N.
    pub n: usize,
    /// Layer matrix columns D.
    pub d: usize,
    /// Decomposition rank K.
    pub k: usize,
    /// Power-law exponent of the synthetic singular spectrum.
    pub gamma: f64,
    /// Instance-generator base seed (instance `i` uses `seed + i`).
    pub instance_seed: u64,
    /// Number of layer matrices in the model.
    pub layers: usize,
    /// Acquisition iterations per layer.
    pub iters: usize,
    /// Ising-solver restarts per acquisition.
    pub restarts: usize,
    /// Acquisition batch size (1 = the paper's serial loop).
    pub batch_size: usize,
    /// Data augmentation (nBOCSa).
    pub augment: bool,
    /// Ising-restart fan-out width (1 = legacy serial restart stream;
    /// > 1 = forked per-restart streams).  Part of the spec because the
    /// two modes produce different (each deterministic) streams.
    pub restart_workers: usize,
    /// BBO algorithm name ([`Algorithm::by_name`]).
    pub algo: String,
    /// Ising solver name ([`solvers::by_name`]).
    pub solver: String,
    /// Base run seed; layer `i` uses `seed + i`.
    pub seed: u64,
    /// Raw (exact) evaluation-cache keys instead of the default
    /// canonical-orbit folding.
    pub cache_key_raw: bool,
}

impl ModelSpec {
    /// Check the spec is runnable: non-degenerate shape, at least one
    /// layer, known algorithm/solver names, and seeds small enough to
    /// round-trip exactly through manifest JSON.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 || self.d == 0 || self.k == 0 {
            bail!("spec: n, d and k must all be >= 1");
        }
        // JSON has no NaN/Inf literals, but an overflowing exponent
        // (`1e999`) parses to +Inf — reject it here so it becomes a
        // typed 400 at the serve boundary instead of reaching the
        // cost oracle (where a non-finite penalty poisons every cost).
        if !self.gamma.is_finite() {
            bail!("spec: gamma must be finite (got {})", self.gamma);
        }
        if self.layers == 0 {
            bail!("spec: layers must be >= 1");
        }
        if self.iters == 0 {
            bail!("spec: iters must be >= 1");
        }
        if Algorithm::by_name(&self.algo).is_none() {
            bail!("spec: unknown algorithm '{}'", self.algo);
        }
        if solvers::by_name(&self.solver).is_none() {
            bail!("spec: unknown solver '{}'", self.solver);
        }
        if self.seed >= MAX_EXACT_SEED
            || self.instance_seed >= MAX_EXACT_SEED
        {
            bail!("spec: seeds must be < 2^53 to round-trip exactly");
        }
        Ok(())
    }

    /// The evaluation-cache key policy the spec selects.
    pub fn cache_mode(&self) -> CacheKeyMode {
        if self.cache_key_raw {
            CacheKeyMode::Exact
        } else {
            CacheKeyMode::Canonical
        }
    }

    /// Build layer `layer`'s compression job — the one construction
    /// path shared by `compress-model` and every shard worker, so a
    /// job is identical no matter which process builds it.
    pub fn job(&self, layer: usize) -> Result<CompressionJob> {
        if layer >= self.layers {
            bail!("layer {layer} out of range (layers = {})", self.layers);
        }
        let icfg = InstanceConfig {
            n: self.n,
            d: self.d,
            k: self.k,
            gamma: self.gamma,
            seed: self.instance_seed,
        };
        let p = generate(&icfg, layer);
        let algo = Algorithm::by_name(&self.algo)
            .ok_or_else(|| anyhow!("unknown algorithm '{}'", self.algo))?;
        let solver = solvers::by_name(&self.solver)
            .ok_or_else(|| anyhow!("unknown solver '{}'", self.solver))?;
        // The shared BboConfig builder path (ISSUE 10): the same
        // base + with_* chain every other layer uses, instead of a
        // re-spelled struct literal.  restart_workers stays 1 here —
        // the per-process fan-out is an engine override
        // ([`ModelSpec::engine_config`]), not part of the job.
        let cfg = BboConfig::smoke_scale(p.n_bits(), self.iters)
            .with_restarts(self.restarts)
            .with_augment(self.augment)
            .with_batch_size(self.batch_size);
        let seed = self.seed.wrapping_add(layer as u64);
        Ok(CompressionJob::new(format!("layer{}", layer + 1), p, 0, seed)
            .with_algo(algo)
            .with_solver(solver)
            .with_cache_mode(self.cache_mode())
            .with_bbo_config(cfg))
    }

    /// Engine parallelism configuration for running this spec — the one
    /// construction path shared by `compress-model`, the shard worker
    /// and both serve call sites (ISSUE 10), so the spec's
    /// `restart_workers`/`batch_size` knobs reach the engine
    /// identically everywhere.
    pub fn engine_config(
        &self,
        workers: usize,
        contain_panics: bool,
    ) -> EngineConfig {
        EngineConfig {
            workers: workers.max(1),
            restart_workers: self.restart_workers,
            batch_size: self.batch_size,
            contain_panics,
        }
    }

    /// Serialise to the manifest JSON layout (keys sorted, so the text
    /// — and hence [`ModelSpec::fingerprint`] — is deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algo", Json::Str(self.algo.clone())),
            ("augment", Json::Bool(self.augment)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("cache_key_raw", Json::Bool(self.cache_key_raw)),
            ("d", Json::Num(self.d as f64)),
            ("gamma", Json::Num(self.gamma)),
            ("instance_seed", Json::Num(self.instance_seed as f64)),
            ("iters", Json::Num(self.iters as f64)),
            ("k", Json::Num(self.k as f64)),
            ("layers", Json::Num(self.layers as f64)),
            ("n", Json::Num(self.n as f64)),
            ("restart_workers", Json::Num(self.restart_workers as f64)),
            ("restarts", Json::Num(self.restarts as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("solver", Json::Str(self.solver.clone())),
        ])
    }

    /// Parse a spec back out of manifest JSON (validated).
    pub fn from_json(j: &Json) -> Result<ModelSpec> {
        let spec = ModelSpec {
            n: usize_field(j, "n")?,
            d: usize_field(j, "d")?,
            k: usize_field(j, "k")?,
            gamma: f64_field(j, "gamma")?,
            instance_seed: u64_field(j, "instance_seed")?,
            layers: usize_field(j, "layers")?,
            iters: usize_field(j, "iters")?,
            restarts: usize_field(j, "restarts")?,
            batch_size: usize_field(j, "batch_size")?,
            augment: bool_field(j, "augment")?,
            restart_workers: usize_field(j, "restart_workers")?,
            algo: str_field(j, "algo")?,
            solver: str_field(j, "solver")?,
            seed: u64_field(j, "seed")?,
            cache_key_raw: bool_field(j, "cache_key_raw")?,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Identity of layer `layer`'s *cost function*: every spec field
    /// the generated problem depends on, plus the layer index.  Two
    /// requests agreeing on this key evaluate the same cost over the
    /// same `W`, so the serve daemon may share one canonical-orbit
    /// [`crate::engine::CostCache`] between them even when their
    /// budgets, seeds or algorithms differ.
    pub fn instance_key(&self, layer: usize) -> String {
        format!(
            "n{}-d{}-k{}-g{:016x}-i{}-l{layer}",
            self.n,
            self.d,
            self.k,
            self.gamma.to_bits(),
            self.instance_seed,
        )
    }

    /// Hex FNV-1a digest of the canonical spec JSON — the workload tag
    /// carried by every manifest and result-log line, so artifacts from
    /// different workloads can never be combined silently.
    pub fn fingerprint(&self) -> String {
        format!("{:016x}", fnv1a64(self.to_json().to_string().as_bytes()))
    }
}

/// 64-bit FNV-1a — tiny, dependency-free and stable across platforms;
/// collision resistance is not a goal (the fingerprint guards against
/// accidents, not adversaries).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| anyhow!("spec: missing field '{key}'"))
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    let v = field(j, key)?
        .as_u64()
        .ok_or_else(|| anyhow!("spec: '{key}' must be a whole number"))?;
    Ok(v as usize)
}

fn u64_field(j: &Json, key: &str) -> Result<u64> {
    field(j, key)?
        .as_u64()
        .ok_or_else(|| anyhow!("spec: '{key}' must be a whole number"))
}

fn f64_field(j: &Json, key: &str) -> Result<f64> {
    field(j, key)?
        .as_f64()
        .ok_or_else(|| anyhow!("spec: '{key}' must be a number"))
}

fn bool_field(j: &Json, key: &str) -> Result<bool> {
    field(j, key)?
        .as_bool()
        .ok_or_else(|| anyhow!("spec: '{key}' must be a boolean"))
}

fn str_field(j: &Json, key: &str) -> Result<String> {
    Ok(field(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("spec: '{key}' must be a string"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(layers: usize) -> ModelSpec {
        ModelSpec {
            n: 4,
            d: 8,
            k: 2,
            gamma: 0.8,
            instance_seed: 9,
            layers,
            iters: 5,
            restarts: 3,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed: 11,
            cache_key_raw: false,
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let spec = tiny_spec(3);
        let back = ModelSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.fingerprint(), spec.fingerprint());
    }

    #[test]
    fn fingerprint_distinguishes_workloads() {
        let a = tiny_spec(3);
        let mut b = a.clone();
        b.seed += 1;
        let mut c = a.clone();
        c.gamma = 0.7;
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_eq!(a.fingerprint().len(), 16);
    }

    #[test]
    fn instance_key_tracks_the_cost_function_only() {
        let a = tiny_spec(3);
        let mut b = a.clone();
        b.seed += 7; // run seed, budget, algorithm: not the cost fn
        b.iters = 50;
        b.algo = "fmqa08".into();
        assert_eq!(a.instance_key(1), b.instance_key(1));
        assert_ne!(a.instance_key(0), a.instance_key(1));
        let mut c = a.clone();
        c.gamma = 0.7;
        assert_ne!(a.instance_key(0), c.instance_key(0));
        let mut d = a.clone();
        d.instance_seed += 1;
        assert_ne!(a.instance_key(0), d.instance_key(0));
    }

    #[test]
    fn validate_rejects_degenerate_specs() {
        let mut s = tiny_spec(0);
        assert!(s.validate().is_err(), "zero layers");
        s.layers = 2;
        s.algo = "bogus".into();
        assert!(s.validate().is_err(), "unknown algo");
        s.algo = "nbocs".into();
        s.solver = "bogus".into();
        assert!(s.validate().is_err(), "unknown solver");
        s.solver = "sa".into();
        s.seed = 1 << 54;
        assert!(s.validate().is_err(), "seed beyond 2^53");
        s.seed = 1;
        assert!(s.validate().is_ok());
    }

    #[test]
    fn validate_rejects_non_finite_gamma() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut s = tiny_spec(2);
            s.gamma = bad;
            assert!(s.validate().is_err(), "gamma {bad} must be rejected");
        }
        let mut s = tiny_spec(2);
        s.gamma = 0.8;
        assert!(s.validate().is_ok());
    }

    #[test]
    fn from_json_rejects_non_finite_gamma_and_negative_budgets() {
        // The JSON number grammar has no NaN/Inf literals, but an
        // overflowing exponent parses to ±Inf — the one ingress for a
        // non-finite gamma.  It must die at parse time, not at the
        // cost oracle.
        for bad in ["1e999", "-1e999"] {
            let txt = tiny_spec(2)
                .to_json()
                .to_string()
                .replace("\"gamma\":0.8", &format!("\"gamma\":{bad}"));
            let j = Json::parse(&txt).expect("overflow still parses");
            assert!(
                !j.get("gamma").unwrap().as_f64().unwrap().is_finite(),
                "precondition: {bad} parses non-finite"
            );
            assert!(
                ModelSpec::from_json(&j).is_err(),
                "gamma {bad} must be a parse-time rejection"
            );
        }
        // Negative or non-finite budget fields are mistyped unsigned
        // integers: one rejection test per field.
        for key in [
            "n", "d", "k", "layers", "iters", "restarts", "batch_size",
            "restart_workers", "seed", "instance_seed",
        ] {
            for bad in [Json::Num(-3.0), Json::Num(f64::INFINITY)] {
                let mut j = tiny_spec(2).to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert(key.into(), bad.clone());
                }
                assert!(
                    ModelSpec::from_json(&j).is_err(),
                    "'{key}' = {bad:?} must be rejected"
                );
            }
        }
    }

    #[test]
    fn jobs_are_per_layer_seeded() {
        let spec = tiny_spec(3);
        let j0 = spec.job(0).unwrap();
        let j2 = spec.job(2).unwrap();
        assert_eq!(j0.name, "layer1");
        assert_eq!(j2.name, "layer3");
        assert_eq!(j0.seed, 11);
        assert_eq!(j2.seed, 13);
        assert_eq!(j0.cfg.iters, 5);
        assert!(spec.job(3).is_err(), "out of range");
    }

    #[test]
    fn from_json_rejects_missing_and_mistyped_fields() {
        let mut j = tiny_spec(2).to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("seed");
        }
        assert!(ModelSpec::from_json(&j).is_err());
        let mut j = tiny_spec(2).to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("iters".into(), Json::Str("many".into()));
        }
        assert!(ModelSpec::from_json(&j).is_err());
    }
}
