//! The shard merger: validate and combine per-shard result logs into
//! the aggregated report a single-process run produces, byte for byte.
//!
//! [`merge_dir`] refuses to produce output from anything less than a
//! complete, mutually consistent plan: every manifest must carry the
//! same workload fingerprint and shard count, shard ids must cover
//! `0..shards` exactly, every manifest job must have a checkpointed
//! record, and no layer may appear twice.  The merged
//! [`deterministic_report`] contains no wall-clock fields, so
//! `intdecomp compress-model --report` (single process) and
//! `intdecomp shard merge --report` (N processes, possibly killed and
//! resumed) emit **identical bytes** for the same workload — the CI
//! `shard-smoke` job diffs exactly that.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::plan::{default_result_path, Manifest};
use super::spec::ModelSpec;
use super::worker::{recover_log, LayerRecord};
use crate::report;

/// A fully validated, merged sharded run.
#[derive(Debug)]
pub struct MergedModel {
    /// The workload every shard agreed on.
    pub spec: ModelSpec,
    /// Shard count of the plan.
    pub shards: usize,
    /// One record per layer, sorted by layer index.
    pub records: Vec<LayerRecord>,
}

/// Load one manifest and the valid prefix of its result log (at the
/// worker's default location next to the manifest).
pub fn load_shard_results(
    manifest_path: &Path,
) -> Result<(Manifest, Vec<LayerRecord>)> {
    let manifest = Manifest::load(manifest_path)?;
    let log = default_result_path(manifest_path);
    let recovered = recover_log(&log, &manifest.fingerprint)?;
    Ok((manifest, recovered.records))
}

/// Merge every shard of the plan in `dir` (manifests `shard_*.json`
/// with result logs beside them), validating completeness and mutual
/// consistency; returns the records in layer order.
pub fn merge_dir(dir: &Path) -> Result<MergedModel> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| {
                    n.starts_with("shard_") && n.ends_with(".json")
                })
        })
        .collect();
    paths.sort();
    if paths.is_empty() {
        bail!("no shard manifests (shard_*.json) in {}", dir.display());
    }

    let mut manifests = Vec::with_capacity(paths.len());
    for p in &paths {
        manifests.push((p.clone(), Manifest::load(p)?));
    }
    let (_, first) = &manifests[0];
    let (fingerprint, shards) = (first.fingerprint.clone(), first.shards);
    let mut seen_shards = vec![false; shards];
    for (p, m) in &manifests {
        if m.fingerprint != fingerprint || m.shards != shards {
            bail!(
                "{}: belongs to a different plan (fingerprint {} / {} \
                 shards, expected {} / {})",
                p.display(),
                m.fingerprint,
                m.shards,
                fingerprint,
                shards
            );
        }
        if seen_shards[m.shard] {
            bail!("{}: duplicate manifest for shard {}", p.display(), m.shard);
        }
        seen_shards[m.shard] = true;
    }
    if manifests.len() != shards {
        bail!(
            "{} holds {} manifests but the plan has {} shards",
            dir.display(),
            manifests.len(),
            shards
        );
    }

    let mut by_layer: BTreeMap<usize, LayerRecord> = BTreeMap::new();
    for (p, m) in &manifests {
        let log = default_result_path(p);
        let recovered = recover_log(&log, &fingerprint)?;
        let mut have: BTreeMap<usize, LayerRecord> = BTreeMap::new();
        for r in recovered.records {
            have.insert(r.job, r);
        }
        for &job in &m.jobs {
            let rec = have.remove(&job).ok_or_else(|| {
                anyhow!(
                    "shard {}/{} incomplete: no record for layer {} in {} \
                     — rerun `intdecomp shard work --manifest {}` (note: \
                     merge reads this default log path; a log written \
                     with --out must be moved here first)",
                    m.shard,
                    m.shards,
                    job + 1,
                    log.display(),
                    p.display()
                )
            })?;
            if by_layer.insert(job, rec).is_some() {
                bail!("layer {} appears in more than one shard", job + 1);
            }
        }
    }
    let records: Vec<LayerRecord> = by_layer.into_values().collect();
    debug_assert_eq!(records.len(), first.spec.layers);
    Ok(MergedModel { spec: first.spec.clone(), shards, records })
}

/// Aggregate compressed/original size over all layers (each layer's
/// ratio weighted by its original size) — the same formula as
/// [`crate::engine::overall_ratio`], computed from checkpoint records.
pub fn overall_ratio(records: &[LayerRecord]) -> f64 {
    let mut orig = 0.0;
    let mut comp = 0.0;
    for r in records {
        let o = (r.n * r.d) as f64;
        orig += o;
        comp += o * r.ratio;
    }
    if orig == 0.0 {
        0.0
    } else {
        comp / orig
    }
}

/// The aggregated per-layer report, built exclusively from
/// deterministic fields — no wall-clock columns — so a sharded run
/// merges to the **same bytes** a single-process run writes
/// (`compress-model --report` uses this very function on its own
/// results).
pub fn deterministic_report(records: &[LayerRecord]) -> String {
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            let lookups = r.cache_hits + r.cache_misses;
            let rate = if lookups == 0 {
                0.0
            } else {
                r.cache_hits as f64 / lookups as f64
            };
            vec![
                r.name.clone(),
                format!("{}x{}", r.n, r.d),
                r.k.to_string(),
                r.algo.clone(),
                r.solver.clone(),
                r.evals.to_string(),
                report::fmt(r.best_y),
                format!("{:.4}", r.err),
                format!("{:.1}%", 100.0 * r.ratio),
                format!(
                    "{}/{} ({:.0}%)",
                    r.cache_hits,
                    lookups,
                    100.0 * rate
                ),
            ]
        })
        .collect();
    let mut out = report::ascii_table(
        &[
            "layer", "shape", "K", "algo", "solver", "evals", "best cost",
            "err", "size", "cache hits",
        ],
        &rows,
    );
    let (mut hits, mut lookups, mut evals) = (0u64, 0u64, 0usize);
    for r in records {
        hits += r.cache_hits;
        lookups += r.cache_hits + r.cache_misses;
        evals += r.evals;
    }
    let _ = writeln!(
        out,
        "total: {evals} evaluations, cache {hits}/{lookups} hits, \
         overall size {:.1}% of original",
        100.0 * overall_ratio(records)
    );
    out
}

/// Write the merged per-layer records as deterministic CSV (same
/// columns as the report, machine-readable, no wall-clock fields).
pub fn write_merged_csv(
    path: impl AsRef<Path>,
    records: &[LayerRecord],
) -> std::io::Result<()> {
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.n.to_string(),
                r.d.to_string(),
                r.k.to_string(),
                r.algo.clone(),
                r.solver.clone(),
                r.evals.to_string(),
                format!("{:.12e}", r.best_y),
                format!("{:.6}", r.err),
                format!("{:.6}", r.ratio),
                r.cache_hits.to_string(),
                r.cache_misses.to_string(),
            ]
        })
        .collect();
    report::write_csv(
        path,
        &[
            "layer",
            "n",
            "d",
            "k",
            "algo",
            "solver",
            "evals",
            "best_cost",
            "normalised_error",
            "compression_ratio",
            "cache_hits",
            "cache_misses",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(job: usize) -> LayerRecord {
        LayerRecord {
            job,
            name: format!("layer{}", job + 1),
            n: 4,
            d: 8,
            k: 2,
            algo: "nBOCS".into(),
            solver: "sa".into(),
            evals: 13,
            best_y: 0.5,
            best_x: vec![1; 8],
            err: 0.25,
            ratio: 0.15,
            cache_hits: 3,
            cache_misses: 10,
            surrogate_failures: 0,
            fallback_proposals: 0,
            rejected_costs: 0,
        }
    }

    #[test]
    fn report_has_rows_totals_and_no_time_column() {
        let records = vec![rec(0), rec(1)];
        let text = deterministic_report(&records);
        assert!(text.contains("layer1"));
        assert!(text.contains("layer2"));
        assert!(text.contains("total: 26 evaluations"));
        assert!(text.contains("cache 6/26 hits"));
        assert!(!text.contains("time"), "wall-clock leaked into report");
        // Byte-determinism: same input, same bytes.
        assert_eq!(text, deterministic_report(&records));
    }

    #[test]
    fn overall_ratio_weights_by_layer_size() {
        let mut a = rec(0);
        a.ratio = 0.1;
        let mut b = rec(1);
        b.ratio = 0.3;
        // Equal shapes: plain mean.
        let r = overall_ratio(&[a, b]);
        assert!((r - 0.2).abs() < 1e-12);
        assert_eq!(overall_ratio(&[]), 0.0);
    }

    #[test]
    fn merged_csv_renders() {
        let dir = std::env::temp_dir().join("intdecomp_shard_csv");
        let path = dir.join("merged.csv");
        write_merged_csv(&path, &[rec(0)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("layer,"));
        assert!(text.contains("layer1"));
        assert!(!text.contains("time_s"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn merge_dir_requires_manifests() {
        let dir = std::env::temp_dir().join("intdecomp_shard_empty");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = format!("{:#}", merge_dir(&dir).unwrap_err());
        assert!(err.contains("no shard manifests"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
