//! The shard planner: a deterministic, shape-only partition of a
//! [`ModelSpec`]'s layers into per-process manifests.
//!
//! [`partition`] depends on nothing but `(layers, shards)` — never on
//! host, worker count or timing — and per-job seeds are a function of
//! the layer index alone, so *any* shard count merges to the same
//! per-job results.  A [`Manifest`] is one shard's work order: the full
//! spec, the shard's layer indices, and the spec
//! [`fingerprint`](ModelSpec::fingerprint) that every result-log line
//! must echo back.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::spec::ModelSpec;
use crate::util::json::Json;

/// Schema tag of every shard manifest; bump on layout changes.
pub const MANIFEST_SCHEMA: &str = "intdecomp-shard-manifest-v1";

/// Split `layers` layer indices into `shards` balanced contiguous
/// blocks — a pure function of the two counts (shape-only), so every
/// process that computes it agrees on the partition.
///
/// Shard sizes differ by at most one; the first `layers % shards`
/// shards carry the extra job.  Shards beyond the layer count come back
/// empty.
///
/// ```
/// use intdecomp::shard::partition;
///
/// assert_eq!(partition(5, 2), vec![vec![0, 1, 2], vec![3, 4]]);
/// assert_eq!(partition(2, 3), vec![vec![0], vec![1], vec![]]);
/// ```
pub fn partition(layers: usize, shards: usize) -> Vec<Vec<usize>> {
    let shards = shards.max(1);
    let base = layers / shards;
    let rem = layers % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for s in 0..shards {
        let len = base + usize::from(s < rem);
        out.push((start..start + len).collect());
        start += len;
    }
    out
}

/// One shard's work order: the spec, which layers this shard owns, and
/// the workload fingerprint tying manifests and result logs together.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// The full workload description (shared by every shard).
    pub spec: ModelSpec,
    /// This shard's index in `0..shards`.
    pub shard: usize,
    /// Total shard count of the plan.
    pub shards: usize,
    /// Layer indices this shard compresses (the shape-only
    /// [`partition`] block for `shard`).
    pub jobs: Vec<usize>,
    /// [`ModelSpec::fingerprint`] of `spec`.
    pub fingerprint: String,
}

impl Manifest {
    /// Canonical manifest file name inside a plan directory.
    pub fn file_name(&self) -> String {
        format!("shard_{}of{}.json", self.shard, self.shards)
    }

    /// Serialise to manifest JSON.
    pub fn to_json(&self) -> Json {
        let jobs = self
            .jobs
            .iter()
            .map(|&j| Json::Num(j as f64))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("fingerprint", Json::Str(self.fingerprint.clone())),
            ("jobs", Json::Arr(jobs)),
            ("schema", Json::Str(MANIFEST_SCHEMA.into())),
            ("shard", Json::Num(self.shard as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("spec", self.spec.to_json()),
        ])
    }

    /// Parse and fully validate a manifest: schema tag, fingerprint
    /// (recomputed from the embedded spec), shard bounds, and the job
    /// list against the shape-only [`partition`] — a hand-edited or
    /// mismatched manifest is rejected, never silently run.
    pub fn from_json(j: &Json) -> Result<Manifest> {
        match j.get("schema").and_then(Json::as_str) {
            Some(s) if s == MANIFEST_SCHEMA => {}
            other => bail!("manifest: bad schema tag {other:?}"),
        }
        let spec = ModelSpec::from_json(
            j.get("spec")
                .ok_or_else(|| anyhow!("manifest: missing 'spec'"))?,
        )?;
        let fingerprint = j
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest: missing 'fingerprint'"))?
            .to_string();
        if fingerprint != spec.fingerprint() {
            bail!(
                "manifest: fingerprint {} does not match its spec ({})",
                fingerprint,
                spec.fingerprint()
            );
        }
        let shard = j
            .get("shard")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("manifest: missing 'shard'"))?
            as usize;
        let shards = j
            .get("shards")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("manifest: missing 'shards'"))?
            as usize;
        if shards == 0 || shard >= shards {
            bail!("manifest: shard {shard} out of range (shards = {shards})");
        }
        let jobs = j
            .get("jobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'jobs' array"))?
            .iter()
            .map(|v| {
                v.as_u64()
                    .map(|x| x as usize)
                    .ok_or_else(|| anyhow!("manifest: non-integer job"))
            })
            .collect::<Result<Vec<usize>>>()?;
        let expected = partition(spec.layers, shards);
        if jobs != expected[shard] {
            bail!(
                "manifest: job list {:?} disagrees with the shape-only \
                 partition {:?} for shard {shard}/{shards}",
                jobs,
                expected[shard]
            );
        }
        Ok(Manifest { spec, shard, shards, jobs, fingerprint })
    }

    /// Load and validate a manifest file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Manifest::from_json(&j)
            .with_context(|| format!("validating {}", path.display()))
    }

    /// Write this manifest into `dir` under its canonical
    /// [`Manifest::file_name`]; creates the directory, returns the path.
    pub fn store(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(self.file_name());
        std::fs::write(&path, self.to_json().to_string() + "\n")
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

/// Plan a workload into `shards` manifests (validates the spec first).
pub fn plan(spec: &ModelSpec, shards: usize) -> Result<Vec<Manifest>> {
    spec.validate()?;
    if shards == 0 {
        bail!("shards must be >= 1");
    }
    let fingerprint = spec.fingerprint();
    Ok(partition(spec.layers, shards)
        .into_iter()
        .enumerate()
        .map(|(shard, jobs)| Manifest {
            spec: spec.clone(),
            shard,
            shards,
            jobs,
            fingerprint: fingerprint.clone(),
        })
        .collect())
}

/// Plan a workload and write every manifest into `dir`
/// (`shard_<i>of<S>.json`); returns the manifest paths in shard order.
pub fn write_plan(
    spec: &ModelSpec,
    shards: usize,
    dir: &Path,
) -> Result<Vec<PathBuf>> {
    plan(spec, shards)?
        .iter()
        .map(|m| m.store(dir))
        .collect()
}

/// The result-log path a worker derives from a manifest path when no
/// explicit `--out` is given: `shard_0of2.json` →
/// `shard_0of2.results.jsonl` (and the path [`crate::shard::merge_dir`]
/// expects).
pub fn default_result_path(manifest_path: &Path) -> PathBuf {
    manifest_path.with_extension("results.jsonl")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    fn spec(layers: usize) -> ModelSpec {
        ModelSpec {
            n: 4,
            d: 8,
            k: 2,
            gamma: 0.8,
            instance_seed: 9,
            layers,
            iters: 4,
            restarts: 2,
            batch_size: 1,
            augment: false,
            restart_workers: 1,
            algo: "nbocs".into(),
            solver: "sa".into(),
            seed: 7,
            cache_key_raw: false,
        }
    }

    #[test]
    fn partition_covers_every_layer_exactly_once_and_is_balanced() {
        for_all(40, |rng| {
            let layers = rng.below(40);
            let shards = 1 + rng.below(9);
            let parts = partition(layers, shards);
            assert_eq!(parts.len(), shards);
            let flat: Vec<usize> =
                parts.iter().flatten().copied().collect();
            assert_eq!(flat, (0..layers).collect::<Vec<_>>());
            let min = parts.iter().map(Vec::len).min().unwrap();
            let max = parts.iter().map(Vec::len).max().unwrap();
            assert!(max - min <= 1, "unbalanced: {parts:?}");
            // Shape-only: recomputing gives the same partition.
            assert_eq!(parts, partition(layers, shards));
        });
    }

    #[test]
    fn manifests_roundtrip_through_files() {
        let dir = std::env::temp_dir().join("intdecomp_shard_plan_rt");
        let _ = std::fs::remove_dir_all(&dir);
        let paths = write_plan(&spec(5), 2, &dir).unwrap();
        assert_eq!(paths.len(), 2);
        let m0 = Manifest::load(&paths[0]).unwrap();
        let m1 = Manifest::load(&paths[1]).unwrap();
        assert_eq!(m0.jobs, vec![0, 1, 2]);
        assert_eq!(m1.jobs, vec![3, 4]);
        assert_eq!(m0.fingerprint, m1.fingerprint);
        assert_eq!(m0.spec, spec(5));
        assert_eq!(
            default_result_path(&paths[0])
                .file_name()
                .unwrap()
                .to_str()
                .unwrap(),
            "shard_0of2.results.jsonl"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_manifests_are_rejected() {
        let m = plan(&spec(4), 2).unwrap().remove(0);
        // Job list not matching the shape-only partition.
        let mut j = m.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("jobs", Json::Arr(vec![Json::Num(3.0)]));
        }
        let err = format!("{:#}", Manifest::from_json(&j).unwrap_err());
        assert!(err.contains("shape-only partition"), "{err}");
        // Spec edited without refreshing the fingerprint.
        let mut j = m.to_json();
        if let Json::Obj(o) = &mut j {
            let mut s = m.spec.clone();
            s.seed += 1;
            o.insert("spec".into(), s.to_json());
        }
        let err = format!("{:#}", Manifest::from_json(&j).unwrap_err());
        assert!(err.contains("fingerprint"), "{err}");
        // Wrong schema tag.
        let mut j = m.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("schema".into(), Json::Str("bogus".into()));
        }
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn plan_rejects_zero_shards_and_bad_specs() {
        assert!(plan(&spec(4), 0).is_err());
        assert!(plan(&spec(0), 2).is_err());
    }
}
