//! Cross-process sharded compression with checkpoint/resume — the
//! ROADMAP's last standing scale item past a single machine.
//!
//! A whole-model workload ([`spec::ModelSpec`]) is embarrassingly
//! parallel at the layer level: each layer is an independent MINLP
//! decomposition with its own seed.  This module splits such a workload
//! across independent OS processes in three stages, each a subcommand of
//! the `intdecomp shard` CLI:
//!
//! 1. **Plan** ([`plan`] / [`write_plan`]) — partition the layers into
//!    shard manifests.  The [`partition`] is *shape-only* (a pure
//!    function of `(layers, shards)`) and per-job seeds depend on the
//!    layer index alone, so any shard count yields the same per-job
//!    results.
//! 2. **Work** ([`run_shard`]) — one process per manifest runs its jobs
//!    on the in-process engine ([`crate::engine::Engine::compress_each`]
//!    streams results in job order over the persistent worker pool) and
//!    appends each finished job to a crash-safe JSONL result log
//!    (fsync per record).  A killed worker restarts, keeps the log's
//!    valid prefix, skips checkpointed jobs and completes a log that is
//!    byte-identical to an uninterrupted run's.
//! 3. **Merge** ([`merge_dir`]) — validate that the shard logs form one
//!    complete, mutually consistent plan (fingerprints, shard coverage,
//!    one record per layer) and emit the aggregated
//!    [`deterministic_report`] — byte-identical to what a
//!    single-process `compress-model --report` run writes, because both
//!    sides build jobs through [`spec::ModelSpec::job`] and the report
//!    contains no wall-clock fields.
//!
//! The determinism contract (`docs/ARCHITECTURE.md` § "The shard
//! subsystem") is enforced end-to-end by `rust/tests/shard.rs` and the
//! CI `shard-smoke` job, which kills and resumes a live worker process
//! and then byte-compares the merged report against a single-process
//! run.
//!
//! ```
//! use intdecomp::shard::{self, ModelSpec};
//!
//! let spec = ModelSpec {
//!     n: 3, d: 6, k: 2, gamma: 0.8, instance_seed: 7,
//!     layers: 2, iters: 2, restarts: 2, batch_size: 1,
//!     augment: false, restart_workers: 1,
//!     algo: "nbocs".into(), solver: "sa".into(),
//!     seed: 42, cache_key_raw: false,
//! };
//! let dir = std::env::temp_dir().join("intdecomp_shard_doc");
//! let _ = std::fs::remove_dir_all(&dir);
//! // Plan two shards, run each (normally: two separate processes).
//! for path in shard::write_plan(&spec, 2, &dir).unwrap() {
//!     let m = shard::Manifest::load(&path).unwrap();
//!     let log = shard::default_result_path(&path);
//!     shard::run_shard(&m, &log, 2, |_rec| {}).unwrap();
//! }
//! // Merge: one record per layer, deterministic report.
//! let merged = shard::merge_dir(&dir).unwrap();
//! assert_eq!(merged.records.len(), 2);
//! let report = shard::deterministic_report(&merged.records);
//! assert!(report.contains("layer1"));
//! std::fs::remove_dir_all(&dir).unwrap();
//! ```

pub mod merge;
pub mod plan;
pub mod spec;
pub mod worker;

pub use merge::{
    deterministic_report, load_shard_results, merge_dir, overall_ratio,
    write_merged_csv, MergedModel,
};
pub use plan::{
    default_result_path, partition, plan, write_plan, Manifest,
    MANIFEST_SCHEMA,
};
pub use spec::ModelSpec;
pub use worker::{
    recover_log, run_shard, CheckpointLog, LayerRecord, RecoveredLog,
    ShardRun, RESULT_SCHEMA,
};
