//! The shard worker: runs one manifest's jobs on the in-process engine,
//! checkpointing every finished job to a crash-safe JSONL result log.
//!
//! **Crash-recovery semantics.**  Each finished job appends exactly one
//! JSON line (flushed and fsynced before the next job is reported), so
//! the log on disk is always a *valid prefix* of the shard's canonical
//! record sequence plus at most one torn tail line.  On start-up
//! [`recover_log`] keeps the valid prefix, [`run_shard`] truncates the
//! torn tail, skips every checkpointed job and recomputes only the
//! rest — and because every record is a pure function of the job spec
//! and records are emitted in job order (the engine's streaming
//! [`crate::engine::Engine::compress_each`] entry point), the log a
//! resumed worker completes is **byte identical** to the one an
//! uninterrupted run writes.

use std::collections::BTreeSet;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::plan::Manifest;
use crate::engine::{Engine, JobResult};
use crate::util::json::{Json, NonFiniteJson};
use crate::util::lockfile::LockFile;

/// Schema tag of every result-log line; bump on layout changes.
/// v2 (ISSUE 9) adds the degraded-mode counters (`surrogate_failures`,
/// `fallback_proposals`, `rejected_costs`).
pub const RESULT_SCHEMA: &str = "intdecomp-shard-result-v2";

/// One finished layer, as checkpointed to the result log — every field
/// the merged deterministic report needs, and nothing wall-clock
/// dependent (times never enter the log, so sharded and single-process
/// reports can be compared byte for byte).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerRecord {
    /// Layer index in the model (the planner's job id).
    pub job: usize,
    /// Layer display name (`layer<job+1>`).
    pub name: String,
    /// Layer rows N.
    pub n: usize,
    /// Layer columns D.
    pub d: usize,
    /// Decomposition rank K.
    pub k: usize,
    /// Algorithm label of the run (e.g. `nBOCS`).
    pub algo: String,
    /// Ising-solver name of the run.
    pub solver: String,
    /// Black-box evaluations performed.
    pub evals: usize,
    /// Best cost found.
    pub best_y: f64,
    /// The winning binary factor M, column-major ±1 spins.
    pub best_x: Vec<i8>,
    /// `||f(M)|| / ||W||` of the winner.
    pub err: f64,
    /// Compressed/original size at 32-bit floats.
    pub ratio: f64,
    /// Evaluation-cache hits of the job.
    pub cache_hits: u64,
    /// Evaluation-cache misses of the job.
    pub cache_misses: u64,
    /// Surrogate fit/draw failures degraded to random acquisition.
    pub surrogate_failures: u64,
    /// Candidates proposed by the degraded random fallback.
    pub fallback_proposals: u64,
    /// Non-finite oracle costs quarantined before the dataset.
    pub rejected_costs: u64,
}

impl LayerRecord {
    /// Build the checkpoint record of one engine [`JobResult`].
    pub fn from_result(job: usize, r: &JobResult) -> LayerRecord {
        LayerRecord {
            job,
            name: r.name.clone(),
            n: r.n,
            d: r.d,
            k: r.k,
            algo: r.run.algo.clone(),
            solver: r.run.solver.clone(),
            evals: r.run.ys.len(),
            best_y: r.run.best_y,
            best_x: r.best_m.data.clone(),
            err: r.normalised_error,
            ratio: r.ratio,
            cache_hits: r.cache.hits,
            cache_misses: r.cache.misses,
            surrogate_failures: r.run.degradation.surrogate_failures,
            fallback_proposals: r.run.degradation.fallback_proposals,
            rejected_costs: r.run.degradation.rejected_costs,
        }
    }

    /// Serialise to one result-log line (no trailing newline).  Floats
    /// use Rust's shortest round-trip formatting, so parsing the line
    /// back yields bit-identical values.  A non-finite float field
    /// (which JSON would collapse to `null` and the parse side would
    /// then reject) is a typed error instead of a silently corrupt
    /// checkpoint (ISSUE 9).
    pub fn to_json_line(
        &self,
        fingerprint: &str,
    ) -> Result<String, NonFiniteJson> {
        let best_x = self
            .best_x
            .iter()
            .map(|&s| Json::Num(s as f64))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("algo", Json::Str(self.algo.clone())),
            ("best_x", Json::Arr(best_x)),
            ("best_y", Json::Num(self.best_y)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("d", Json::Num(self.d as f64)),
            ("err", Json::Num(self.err)),
            ("evals", Json::Num(self.evals as f64)),
            (
                "fallback_proposals",
                Json::Num(self.fallback_proposals as f64),
            ),
            ("fingerprint", Json::Str(fingerprint.into())),
            ("job", Json::Num(self.job as f64)),
            ("k", Json::Num(self.k as f64)),
            ("n", Json::Num(self.n as f64)),
            ("name", Json::Str(self.name.clone())),
            ("ratio", Json::Num(self.ratio)),
            ("rejected_costs", Json::Num(self.rejected_costs as f64)),
            ("schema", Json::Str(RESULT_SCHEMA.into())),
            ("solver", Json::Str(self.solver.clone())),
            (
                "surrogate_failures",
                Json::Num(self.surrogate_failures as f64),
            ),
        ])
        .to_string_strict()
    }

    /// Parse one result-log line, rejecting lines from another schema
    /// or another workload (`fingerprint` mismatch).
    pub fn parse_line(line: &str, fingerprint: &str) -> Result<LayerRecord> {
        let j = Json::parse(line).map_err(|e| anyhow!("result line: {e}"))?;
        match j.get("schema").and_then(Json::as_str) {
            Some(s) if s == RESULT_SCHEMA => {}
            other => bail!("result line: bad schema tag {other:?}"),
        }
        match j.get("fingerprint").and_then(Json::as_str) {
            Some(f) if f == fingerprint => {}
            other => bail!(
                "result line: fingerprint {other:?} does not match the \
                 manifest ({fingerprint}) — log from another workload?"
            ),
        }
        let best_x = j
            .get("best_x")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("result line: missing 'best_x'"))?
            .iter()
            .map(|v| match v.as_f64() {
                Some(x) if x == 1.0 => Ok(1i8),
                Some(x) if x == -1.0 => Ok(-1i8),
                _ => Err(anyhow!("result line: best_x entries must be ±1")),
            })
            .collect::<Result<Vec<i8>>>()?;
        let num = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("result line: missing number '{key}'"))
        };
        let int = |key: &str| -> Result<u64> {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("result line: missing integer '{key}'"))
        };
        let txt = |key: &str| -> Result<String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    anyhow!("result line: missing string '{key}'")
                })?
                .to_string())
        };
        let rec = LayerRecord {
            job: int("job")? as usize,
            name: txt("name")?,
            n: int("n")? as usize,
            d: int("d")? as usize,
            k: int("k")? as usize,
            algo: txt("algo")?,
            solver: txt("solver")?,
            evals: int("evals")? as usize,
            best_y: num("best_y")?,
            best_x,
            err: num("err")?,
            ratio: num("ratio")?,
            cache_hits: int("cache_hits")?,
            cache_misses: int("cache_misses")?,
            surrogate_failures: int("surrogate_failures")?,
            fallback_proposals: int("fallback_proposals")?,
            rejected_costs: int("rejected_costs")?,
        };
        if rec.best_x.len() != rec.n * rec.k {
            bail!("result line: best_x length != n*k");
        }
        Ok(rec)
    }
}

/// What [`recover_log`] found in an existing result log.
#[derive(Debug)]
pub struct RecoveredLog {
    /// The valid checkpoint records, in log order.
    pub records: Vec<LayerRecord>,
    /// Byte length of the valid prefix (newline-terminated, parseable
    /// lines with the right schema and fingerprint).
    pub valid_bytes: u64,
    /// Bytes past the valid prefix — a torn tail from a crash
    /// mid-append (or foreign garbage); [`run_shard`] truncates them.
    pub dropped_bytes: u64,
}

/// Read the valid prefix of a result log: complete, newline-terminated
/// lines that parse as [`LayerRecord`]s of this workload.  Scanning
/// stops at the first bad or unterminated line — after a crash only the
/// tail line can be torn, so everything before it is trustworthy.  A
/// missing file is an empty log.
pub fn recover_log(
    path: &Path,
    fingerprint: &str,
) -> Result<RecoveredLog> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(RecoveredLog {
                records: Vec::new(),
                valid_bytes: 0,
                dropped_bytes: 0,
            })
        }
        Err(e) => {
            return Err(e)
                .with_context(|| format!("reading {}", path.display()))
        }
    };
    let mut records = Vec::new();
    let mut valid = 0usize;
    // Scan raw bytes so a non-UTF-8 tail (binary garbage, disk
    // corruption) is truncated like any other bad line instead of
    // aborting the resume.
    let mut rest = bytes.as_slice();
    while let Some(nl) = rest.iter().position(|&b| b == b'\n') {
        let parsed = std::str::from_utf8(&rest[..nl])
            .ok()
            .and_then(|line| {
                LayerRecord::parse_line(line, fingerprint).ok()
            });
        match parsed {
            Some(rec) => {
                records.push(rec);
                valid += nl + 1;
                rest = &rest[nl + 1..];
            }
            None => break,
        }
    }
    Ok(RecoveredLog {
        records,
        valid_bytes: valid as u64,
        dropped_bytes: (bytes.len() - valid) as u64,
    })
}

/// A locked, crash-safe, append-only [`LayerRecord`] checkpoint log —
/// the one durability primitive shared by the shard worker and the
/// serve daemon's request journal.
///
/// Life cycle: [`CheckpointLog::recover`] takes the advisory lock and
/// scans the valid prefix without touching any byte on disk (so a
/// caller can still reject the log wholesale, as [`run_shard`] does
/// when a checkpointed job belongs to another shard);
/// [`CheckpointLog::commit`] then truncates the torn tail and opens
/// the file for appending; [`CheckpointLog::append`] writes one record
/// line and fsyncs it before returning — the durability point.
/// [`CheckpointLog::open`] is the one-call form for callers with no
/// pre-commit validation.  The lock is held until the value is
/// dropped; a second writer on the same path fails to acquire it.
#[derive(Debug)]
pub struct CheckpointLog {
    path: PathBuf,
    fingerprint: String,
    _lock: LockFile,
    records: Vec<LayerRecord>,
    valid_bytes: u64,
    dropped_bytes: u64,
    file: Option<std::fs::File>,
}

impl CheckpointLog {
    /// Lock `path` and scan its valid prefix ([`recover_log`]) without
    /// modifying the file.  The parent directory is created if
    /// missing (the lock sidecar needs it to exist).
    pub fn recover(path: &Path, fingerprint: &str) -> Result<CheckpointLog> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating {}", parent.display())
                })?;
            }
        }
        // Single-writer guard: a second writer on the same log would
        // interleave appends and corrupt the valid prefix recover_log
        // trusts.  Stale locks from a SIGKILLed process are reclaimed.
        let lock = LockFile::acquire(path).with_context(|| {
            format!("locking checkpoint log {}", path.display())
        })?;
        let recovered = recover_log(path, fingerprint)?;
        Ok(CheckpointLog {
            path: path.to_path_buf(),
            fingerprint: fingerprint.to_string(),
            _lock: lock,
            records: recovered.records,
            valid_bytes: recovered.valid_bytes,
            dropped_bytes: recovered.dropped_bytes,
            file: None,
        })
    }

    /// Drop the torn tail (truncate to the valid prefix) and open the
    /// log for appending.  The file is created even when there is
    /// nothing to append, so operators can see the writer ran.
    /// Idempotent: committing twice is a no-op.
    pub fn commit(&mut self) -> Result<()> {
        if self.file.is_some() {
            return Ok(());
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;
        file.set_len(self.valid_bytes)
            .with_context(|| format!("truncating {}", self.path.display()))?;
        drop(file);
        let log = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| {
                format!("opening {} for append", self.path.display())
            })?;
        self.file = Some(log);
        Ok(())
    }

    /// [`CheckpointLog::recover`] + [`CheckpointLog::commit`] in one
    /// call, for callers with no validation between the two.
    pub fn open(path: &Path, fingerprint: &str) -> Result<CheckpointLog> {
        let mut log = CheckpointLog::recover(path, fingerprint)?;
        log.commit()?;
        Ok(log)
    }

    /// The valid-prefix records recovered at open, in log order.
    pub fn records(&self) -> &[LayerRecord] {
        &self.records
    }

    /// Take ownership of the recovered records (leaves the log empty).
    pub fn take_records(&mut self) -> Vec<LayerRecord> {
        std::mem::take(&mut self.records)
    }

    /// Bytes past the valid prefix found at open — a torn tail from a
    /// crash mid-append; [`CheckpointLog::commit`] truncates them.
    pub fn dropped_bytes(&self) -> u64 {
        self.dropped_bytes
    }

    /// The log file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The workload fingerprint every line is tagged with.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Append one record line and force it to disk before returning —
    /// the durability point of the checkpoint contract.  The log must
    /// have been committed first.
    pub fn append(&mut self, rec: &LayerRecord) -> std::io::Result<()> {
        let file = self.file.as_mut().ok_or_else(|| {
            std::io::Error::other("checkpoint log not committed")
        })?;
        append_record(file, rec, &self.fingerprint)
    }
}

/// Outcome of one [`run_shard`] call.
#[derive(Debug)]
pub struct ShardRun {
    /// All of the shard's records (checkpointed + newly computed),
    /// sorted by job index.
    pub records: Vec<LayerRecord>,
    /// Jobs skipped because the log already held their record.
    pub skipped: usize,
    /// Jobs computed by this call.
    pub ran: usize,
    /// The result log written/extended.
    pub log_path: PathBuf,
}

/// Run one shard's jobs on the engine, checkpointing each finished job
/// to `out` (append + fsync per record, in job order) and resuming from
/// whatever valid prefix `out` already holds.  `workers` bounds
/// concurrent jobs on the process-wide pool; it never affects results.
/// `progress` is called once per newly computed record, in job order.
pub fn run_shard(
    manifest: &Manifest,
    out: &Path,
    workers: usize,
    mut progress: impl FnMut(&LayerRecord),
) -> Result<ShardRun> {
    let fp = &manifest.fingerprint;
    // Lock + scan only: the shard-membership check below must run
    // before commit() touches any byte of a log we might reject.
    let mut log = CheckpointLog::recover(out, fp)?;
    let done: BTreeSet<usize> =
        log.records().iter().map(|r| r.job).collect();
    for r in log.records() {
        if !manifest.jobs.contains(&r.job) {
            bail!(
                "{}: checkpointed job {} does not belong to shard {}/{}",
                out.display(),
                r.job,
                manifest.shard,
                manifest.shards
            );
        }
    }
    // Drop any torn tail, then (re)open for appending.  The file is
    // created even for an empty shard so operators can see the worker
    // ran (the merger itself treats a missing log as empty).
    log.commit()?;

    let todo: Vec<usize> = manifest
        .jobs
        .iter()
        .copied()
        .filter(|j| !done.contains(j))
        .collect();
    let jobs = todo
        .iter()
        .map(|&layer| manifest.spec.job(layer))
        .collect::<Result<Vec<_>>>()?;
    // The shared spec→engine path (ISSUE 10) — identical to
    // compress-model's and the serve daemon's construction.
    let eng = Engine::new(manifest.spec.engine_config(workers, false));
    let mut new_records = Vec::with_capacity(todo.len());
    let mut write_err: Option<std::io::Error> = None;
    eng.compress_each(jobs, |i, result| {
        let rec = LayerRecord::from_result(todo[i], &result);
        if write_err.is_none() {
            match log.append(&rec) {
                Ok(()) => progress(&rec),
                Err(e) => write_err = Some(e),
            }
        }
        new_records.push(rec);
    });
    if let Some(e) = write_err {
        return Err(e).with_context(|| format!("appending {}", out.display()));
    }

    let mut records = log.take_records();
    let skipped = records.len();
    let ran = new_records.len();
    records.extend(new_records);
    records.sort_by_key(|r| r.job);
    Ok(ShardRun { records, skipped, ran, log_path: out.to_path_buf() })
}

/// Append one record line and force it to disk before returning — the
/// durability point of the checkpoint contract.
fn append_record(
    log: &mut std::fs::File,
    rec: &LayerRecord,
    fingerprint: &str,
) -> std::io::Result<()> {
    // A non-finite float field would corrupt the checkpoint (the parse
    // side rejects `null`); surface it as a write error instead.
    let mut line =
        rec.to_json_line(fingerprint).map_err(std::io::Error::other)?;
    line.push('\n');
    log.write_all(line.as_bytes())?;
    log.sync_data()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> LayerRecord {
        LayerRecord {
            job: 3,
            name: "layer4".into(),
            n: 4,
            d: 8,
            k: 2,
            algo: "nBOCS".into(),
            solver: "sa".into(),
            evals: 13,
            best_y: 0.062_384_137_529e-2,
            best_x: vec![1, -1, 1, 1, -1, -1, 1, -1],
            err: 0.0417,
            ratio: 0.158_203_125,
            cache_hits: 4,
            cache_misses: 9,
            surrogate_failures: 2,
            fallback_proposals: 2,
            rejected_costs: 1,
        }
    }

    #[test]
    fn record_roundtrips_bit_exactly() {
        let rec = record();
        let line = rec.to_json_line("f00d").unwrap();
        let back = LayerRecord::parse_line(&line, "f00d").unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.best_y.to_bits(), rec.best_y.to_bits());
        assert_eq!(back.to_json_line("f00d").unwrap(), line);
        // Negative-zero float fields keep their sign bit through a
        // full serialise→parse→serialise cycle (f64 == treats -0.0
        // and 0.0 as equal, so compare bits explicitly).
        let mut zero = record();
        zero.best_y = -0.0;
        zero.err = -0.0;
        let line = zero.to_json_line("f00d").unwrap();
        let back = LayerRecord::parse_line(&line, "f00d").unwrap();
        assert_eq!(back.best_y.to_bits(), (-0.0f64).to_bits());
        assert_eq!(back.err.to_bits(), (-0.0f64).to_bits());
        assert_eq!(back.to_json_line("f00d").unwrap(), line);
    }

    #[test]
    fn non_finite_record_fields_are_typed_write_errors() {
        let mut rec = record();
        rec.best_y = f64::NAN;
        let err = rec.to_json_line("f00d").unwrap_err();
        assert_eq!(err.path, "best_y");
        assert!(err.value.is_nan());
        let mut rec = record();
        rec.err = f64::INFINITY;
        assert_eq!(rec.to_json_line("f00d").unwrap_err().path, "err");
    }

    #[test]
    fn parse_rejects_foreign_lines() {
        let line = record().to_json_line("f00d").unwrap();
        assert!(LayerRecord::parse_line(&line, "beef").is_err());
        assert!(LayerRecord::parse_line("{}", "f00d").is_err());
        assert!(LayerRecord::parse_line("not json", "f00d").is_err());
        let torn = &line[..line.len() / 2];
        assert!(LayerRecord::parse_line(torn, "f00d").is_err());
    }

    #[test]
    fn checkpoint_log_resumes_byte_identically_after_a_torn_tail() {
        let dir = std::env::temp_dir().join("intdecomp_checkpoint_log");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested").join("log.jsonl");
        // Uninterrupted run: three records.
        let mut recs = Vec::new();
        for job in 0..3 {
            let mut r = record();
            r.job = job;
            recs.push(r);
        }
        {
            let mut log = CheckpointLog::open(&path, "f00d").unwrap();
            assert!(log.records().is_empty());
            for r in &recs {
                log.append(r).unwrap();
            }
        }
        let full = std::fs::read(&path).unwrap();
        // Crash: torn third line.  Reopen must recover two records,
        // truncate the tail, and re-appending the third must
        // reproduce the uninterrupted bytes exactly.
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        {
            let mut log = CheckpointLog::open(&path, "f00d").unwrap();
            assert_eq!(log.records().len(), 2);
            assert!(log.dropped_bytes() > 0);
            log.append(&recs[2]).unwrap();
            // The lock is exclusive while held.
            assert!(CheckpointLog::recover(&path, "f00d").is_err());
        }
        assert_eq!(std::fs::read(&path).unwrap(), full);
        // recover() without commit() must not touch the file, and
        // append before commit is an error.
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        {
            let mut log = CheckpointLog::recover(&path, "f00d").unwrap();
            assert!(log.append(&recs[2]).is_err());
        }
        assert_eq!(std::fs::read(&path).unwrap(), &full[..full.len() - 7]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_keeps_the_valid_prefix_only() {
        let dir = std::env::temp_dir().join("intdecomp_shard_recover");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.jsonl");
        let l1 = record().to_json_line("f00d").unwrap();
        let mut r2 = record();
        r2.job = 4;
        let l2 = r2.to_json_line("f00d").unwrap();
        // Two good lines + a torn third line.
        let torn = &l1[..l1.len() - 5];
        std::fs::write(&path, format!("{l1}\n{l2}\n{torn}")).unwrap();
        let rec = recover_log(&path, "f00d").unwrap();
        assert_eq!(rec.records.len(), 2);
        assert_eq!(rec.records[1].job, 4);
        assert_eq!(rec.valid_bytes as usize, l1.len() + l2.len() + 2);
        assert_eq!(rec.dropped_bytes as usize, torn.len());
        // Missing file: empty log.
        let none = recover_log(&dir.join("absent.jsonl"), "f00d").unwrap();
        assert!(none.records.is_empty());
        assert_eq!(none.valid_bytes, 0);
        // A corrupt line in the middle invalidates everything after it.
        std::fs::write(&path, format!("{l1}\nGARBAGE\n{l2}\n")).unwrap();
        let rec = recover_log(&path, "f00d").unwrap();
        assert_eq!(rec.records.len(), 1);
        assert_eq!(rec.valid_bytes as usize, l1.len() + 1);
        // A non-UTF-8 tail is truncated like any torn line, not an
        // error (binary garbage must never wedge the resume).
        let mut raw = format!("{l1}\n").into_bytes();
        raw.extend_from_slice(&[0x80, 0x81, 0xff, b'\n']);
        std::fs::write(&path, &raw).unwrap();
        let rec = recover_log(&path, "f00d").unwrap();
        assert_eq!(rec.records.len(), 1);
        assert_eq!(rec.valid_bytes as usize, l1.len() + 1);
        assert_eq!(rec.dropped_bytes, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
