//! Problem-instance generation: the "shrunk VGG matrix" (paper Methods).
//!
//! The paper builds its ten 8×100 test matrices by SVD-shrinking the final
//! fully connected layer of an ImageNet-trained VGG16 (4096×1000): keep the
//! top singular values, pick rows/columns of the singular factors.  No such
//! checkpoint is available offline, so we synthesise matrices with the same
//! structure the shrink step preserves (DESIGN.md §2): a decaying singular
//! spectrum and generic (Haar-random) orthogonal factors:
//!
//! ```text
//!   W = U diag(σ) V^T,   U: N×N Haar,  V: D×N Haar-column,  σ_i ∝ i^-γ
//! ```
//!
//! γ defaults to 0.7, which puts the exact-solution normalised residuals of
//! the K=3 decomposition in the 0.37–0.54 band the paper reports
//! (EXPERIMENTS.md cross-checks this per instance).

use crate::cost::Problem;
use crate::linalg::{householder_qr, Matrix};
use crate::util::rng::Rng;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct InstanceConfig {
    /// Target rows N.
    pub n: usize,
    /// Target columns D.
    pub d: usize,
    /// Decomposition rank K.
    pub k: usize,
    /// Power-law exponent of the singular spectrum.
    pub gamma: f64,
    /// Base seed; instance i uses `seed + i`.
    pub seed: u64,
}

impl Default for InstanceConfig {
    fn default() -> Self {
        // Paper configuration: W is 8×100, decomposed at K = 3 (n = 24).
        // The seed is chosen so that all ten instances are *generic*: the
        // optimal column space contains exactly K ±1 vectors, hence the
        // paper's K!·2^K = 48 exact solutions (non-generic seeds produce
        // 192 = 48·C(4,3) when a fourth ±1 vector lies in the span).
        InstanceConfig { n: 8, d: 100, k: 3, gamma: 0.7, seed: 5005 }
    }
}

/// Haar-ish random matrix with orthonormal columns (QR of a Gaussian with
/// sign-fixed R diagonal).
fn random_orthonormal(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let g = Matrix::from_vec(rows, cols, rng.normals(rows * cols));
    let (q, _) = householder_qr(&g);
    q
}

/// Synthesise one target matrix W (N×D).
pub fn generate_w(cfg: &InstanceConfig, index: usize) -> Matrix {
    let mut rng = Rng::new(cfg.seed.wrapping_add(index as u64));
    let u = random_orthonormal(&mut rng, cfg.n, cfg.n); // N×N
    let v = random_orthonormal(&mut rng, cfg.d, cfg.n); // D×N
    // Per-instance spectrum exponent jitter: the paper's instances differ
    // through the random row/column selection of the VGG factors, which
    // varies how top-heavy the kept spectrum is.  Jittering γ in
    // [0.75γ, 1.75γ] reproduces the paper's spread of exact-solution
    // residuals (0.37–0.54) across the ten instances.
    let gamma = cfg.gamma * (0.75 + rng.f64());
    // σ_i = (i+1)^-γ, scaled so ||W||_F = 1 (scale is irrelevant to the
    // normalised residual measures but keeps numbers readable).
    let mut sigma: Vec<f64> =
        (0..cfg.n).map(|i| ((i + 1) as f64).powf(-gamma)).collect();
    let norm = sigma.iter().map(|s| s * s).sum::<f64>().sqrt();
    for s in sigma.iter_mut() {
        *s /= norm;
    }
    // W = U diag(sigma) V^T.
    let mut us = u;
    for j in 0..cfg.n {
        for i in 0..cfg.n {
            us[(i, j)] *= sigma[j];
        }
    }
    us.matmul(&v.transpose())
}

/// Synthesise instance `index` as a ready-to-optimise `Problem`.
pub fn generate(cfg: &InstanceConfig, index: usize) -> Problem {
    Problem::new(generate_w(cfg, index), cfg.k)
}

/// The paper's ten instances (index 0 = "instance 1").
pub fn generate_suite(cfg: &InstanceConfig, count: usize) -> Vec<Problem> {
    (0..count).map(|i| generate(cfg, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_norm() {
        let cfg = InstanceConfig::default();
        let w = generate_w(&cfg, 0);
        assert_eq!((w.rows, w.cols), (8, 100));
        assert!((w.frob_norm_sq() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_per_index() {
        let cfg = InstanceConfig::default();
        let a = generate_w(&cfg, 3);
        let b = generate_w(&cfg, 3);
        assert_eq!(a.data, b.data);
        let c = generate_w(&cfg, 4);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn singular_spectrum_decays() {
        // W W^T eigenvalues should match sigma^2 (power law).  We check the
        // trace split: the top direction carries the largest share.
        let cfg = InstanceConfig::default();
        let p = generate(&cfg, 0);
        // Rayleigh quotient along a few random directions never exceeds
        // sigma_1^2 = (1/norm)^2.
        let sigma1_sq = {
            let sig: Vec<f64> =
                (0..8).map(|i| ((i + 1) as f64).powf(-0.7)).collect();
            let n = sig.iter().map(|s| s * s).sum::<f64>();
            sig[0] * sig[0] / n
        };
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..20 {
            let x = rng.normals(8);
            let nrm = crate::linalg::dot(&x, &x);
            let sx = p.s.matvec(&x);
            let q = crate::linalg::dot(&x, &sx) / nrm;
            assert!(q <= sigma1_sq + 1e-9);
        }
    }

    #[test]
    fn suite_has_distinct_instances() {
        let cfg = InstanceConfig::default();
        let suite = generate_suite(&cfg, 10);
        assert_eq!(suite.len(), 10);
        for i in 1..10 {
            assert_ne!(suite[0].w.data, suite[i].w.data);
        }
    }

    #[test]
    fn small_config_supported() {
        let cfg =
            InstanceConfig { n: 4, d: 6, k: 2, gamma: 1.0, seed: 7 };
        let p = generate(&cfg, 0);
        assert_eq!(p.n(), 4);
        assert_eq!(p.d(), 6);
        assert_eq!(p.n_bits(), 8);
    }
}
