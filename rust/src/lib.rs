//! # intdecomp — lossy matrix compression by black-box optimisation of MINLP
//!
//! Reproduction of Kadowaki & Ambai, *Lossy compression of matrices by
//! black-box optimisation of mixed integer nonlinear programming*,
//! Scientific Reports 12 (2022).
//!
//! The library decomposes a real matrix `W (N×D)` into a binary matrix
//! `M (N×K, ±1)` times a real matrix `C (K×D)` by eliminating `C` with least
//! squares (turning the MINLP into a binary NLIP) and optimising `M` with
//! black-box optimisation: BOCS-style Bayesian surrogates or factorisation
//! machines, minimised by Ising solvers (SA / simulated-QA / quenching).
//!
//! Architecture (see DESIGN.md): this crate is the L3 coordinator; the
//! numeric hot paths are AOT-compiled JAX/Pallas artifacts loaded through
//! PJRT (`runtime`), each with a native Rust twin for fallback and
//! cross-checking.

pub mod bbo;
pub mod bench;
pub mod bruteforce;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod engine;
pub mod experiments;
pub mod greedy;
pub mod instance;
pub mod linalg;
pub mod minlp;
pub mod report;
pub mod runtime;
pub mod solvers;
pub mod surrogate;
pub mod util;
