//! # intdecomp — lossy matrix compression by black-box optimisation of MINLP
//!
//! Reproduction of Kadowaki & Ambai, *Lossy compression of matrices by
//! black-box optimisation of mixed integer nonlinear programming*,
//! Scientific Reports 12 (2022).
//!
//! The library decomposes a real matrix `W (N×D)` into a binary matrix
//! `M (N×K, ±1)` times a real matrix `C (K×D)` by eliminating `C` with least
//! squares (turning the MINLP into a binary NLIP) and optimising `M` with
//! black-box optimisation: BOCS-style Bayesian surrogates or factorisation
//! machines, minimised by Ising solvers (SA / simulated-QA / quenching).
//!
//! Architecture (see DESIGN.md): this crate is the L3 coordinator; the
//! numeric hot paths are AOT-compiled JAX/Pallas artifacts loaded through
//! PJRT (`runtime`), each with a native Rust twin for fallback and
//! cross-checking.  `docs/ARCHITECTURE.md` maps every module to the
//! paper's sections and walks one batched BBO iteration through the
//! system.
//!
//! Quick start — compress one synthetic layer with batched acquisition:
//!
//! ```
//! use intdecomp::engine::{CompressionJob, Engine};
//! use intdecomp::instance::{generate, InstanceConfig};
//!
//! let icfg = InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 1 };
//! let job = CompressionJob::new("fc1", generate(&icfg, 0), 8, 42)
//!     .with_batch_size(4);
//! let results = Engine::with_workers(2).compress_all(vec![job]);
//! assert_eq!(results.len(), 1);
//! assert!(results[0].normalised_error.is_finite());
//! ```

#![warn(missing_docs)]

pub mod bbo;
pub mod bench;
pub mod bruteforce;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod engine;
pub mod experiments;
pub mod greedy;
pub mod instance;
pub mod linalg;
pub mod minlp;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod solvers;
pub mod surrogate;
pub mod util;
