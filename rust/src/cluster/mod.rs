//! Hierarchical clustering of the exact-solution set (paper Figs. 4, 5b).
//!
//! The 48 exact solutions are Ward-clustered (Lance–Williams recurrence on
//! squared Euclidean distances; for ±1 vectors `d² = 4 · Hamming`), the
//! tree is cut into four domains, and every candidate the BBO samples is
//! assigned to the domain of its Hamming-nearest exact solution.  The
//! smoothed domain populations reveal whether an algorithm focuses on one
//! solution subspace (FMQA) or keeps exploring (BOCS) — the paper's Fig. 4
//! analysis.

use crate::util::smooth;

/// One merge step of the agglomeration: clusters `a` and `b` (ids) merge
/// into a new cluster at the given Ward distance.
#[derive(Clone, Debug)]
pub struct Merge {
    /// First merged cluster id.
    pub a: usize,
    /// Second merged cluster id.
    pub b: usize,
    /// Ward linkage distance (squared-Euclidean scale).
    pub dist: f64,
    /// Size of the merged cluster.
    pub size: usize,
}

/// Hamming distance between spin vectors.
pub fn hamming(a: &[i8], b: &[i8]) -> usize {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

/// Ward agglomerative clustering via the Lance–Williams update.
///
/// Returns the merge list; leaves are cluster ids `0..m`, internal nodes
/// get ids `m, m+1, ..` in merge order (scipy linkage convention).
pub fn ward(points: &[Vec<i8>]) -> Vec<Merge> {
    let m = points.len();
    if m <= 1 {
        return Vec::new();
    }
    // Active cluster list: (id, size). Distance matrix over active set.
    let mut ids: Vec<usize> = (0..m).collect();
    let mut sizes: Vec<f64> = vec![1.0; m];
    let mut d: Vec<Vec<f64>> = vec![vec![0.0; m]; m];
    for i in 0..m {
        for j in (i + 1)..m {
            let d2 = 4.0 * hamming(&points[i], &points[j]) as f64;
            d[i][j] = d2;
            d[j][i] = d2;
        }
    }

    let mut merges = Vec::with_capacity(m - 1);
    let mut next_id = m;
    while ids.len() > 1 {
        // Find the closest active pair (positions in the active arrays).
        let (mut bi, mut bj, mut bd) = (0, 1, f64::INFINITY);
        for i in 0..ids.len() {
            for j in (i + 1)..ids.len() {
                if d[i][j] < bd {
                    bd = d[i][j];
                    bi = i;
                    bj = j;
                }
            }
        }
        let (sa, sb) = (sizes[bi], sizes[bj]);
        merges.push(Merge {
            a: ids[bi],
            b: ids[bj],
            dist: bd,
            size: (sa + sb) as usize,
        });
        // Lance–Williams Ward update of distances to every other cluster:
        // d(AB, C) = ((a+c) d(A,C) + (b+c) d(B,C) - c d(A,B)) / (a+b+c).
        let mut new_row = Vec::with_capacity(ids.len() - 2);
        for k in 0..ids.len() {
            if k == bi || k == bj {
                continue;
            }
            let sc = sizes[k];
            let v = ((sa + sc) * d[bi][k] + (sb + sc) * d[bj][k]
                - sc * bd)
                / (sa + sb + sc);
            new_row.push(v);
        }
        // Remove bj then bi (bj > bi), append merged cluster.
        for row in d.iter_mut() {
            row.remove(bj);
            row.remove(bi);
        }
        d.remove(bj);
        d.remove(bi);
        ids.remove(bj);
        ids.remove(bi);
        sizes.remove(bj);
        sizes.remove(bi);
        for (row, &v) in d.iter_mut().zip(&new_row) {
            row.push(v);
        }
        new_row.push(0.0);
        d.push(new_row);
        ids.push(next_id);
        sizes.push(sa + sb);
        next_id += 1;
    }
    merges
}

/// Cut the Ward tree into `k` clusters; returns a label in `0..k` for each
/// leaf (labels ordered by first occurrence).
pub fn cut(merges: &[Merge], n_leaves: usize, k: usize) -> Vec<usize> {
    assert!(k >= 1);
    let k = k.min(n_leaves.max(1));
    // Undo the last k-1 merges: union-find over the first (m-k) merges.
    let mut parent: Vec<usize> = (0..n_leaves + merges.len()).collect();
    fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]];
            x = parent[x];
        }
        x
    }
    let keep = merges.len() + 1 - k;
    for (step, mrg) in merges.iter().take(keep).enumerate() {
        let node = n_leaves + step;
        let ra = find(&mut parent, mrg.a);
        let rb = find(&mut parent, mrg.b);
        parent[ra] = node;
        parent[rb] = node;
    }
    // Label leaves by root, ordered by first occurrence.
    let mut label_of_root = std::collections::HashMap::new();
    let mut labels = Vec::with_capacity(n_leaves);
    for leaf in 0..n_leaves {
        let r = find(&mut parent, leaf);
        let next = label_of_root.len();
        let l = *label_of_root.entry(r).or_insert(next);
        labels.push(l);
    }
    labels
}

/// Assign a candidate to the domain of its Hamming-nearest exact solution.
pub fn assign_domain(
    x: &[i8],
    solutions: &[Vec<i8>],
    labels: &[usize],
) -> usize {
    debug_assert_eq!(solutions.len(), labels.len());
    let mut best = (usize::MAX, 0usize);
    for (sol, &lab) in solutions.iter().zip(labels) {
        let h = hamming(x, sol);
        if h < best.0 {
            best = (h, lab);
        }
    }
    best.1
}

/// Per-domain population traces of a run's sampled candidates, smoothed
/// with the paper's window (Fig. 4 uses 100).  Output: `domains` rows ×
/// `len(xs)` columns of smoothed indicator fractions.
pub fn domain_trace(
    xs: &[Vec<i8>],
    solutions: &[Vec<i8>],
    labels: &[usize],
    n_domains: usize,
    window: usize,
) -> Vec<Vec<f64>> {
    let mut raw = vec![vec![0.0; xs.len()]; n_domains];
    for (t, x) in xs.iter().enumerate() {
        let d = assign_domain(x, solutions, labels);
        raw[d][t] = 1.0;
    }
    raw.into_iter().map(|row| smooth(&row, window)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn two_blobs(rng: &mut Rng) -> Vec<Vec<i8>> {
        // Blob A around all-ones, blob B around all-minus, 1-bit jitter.
        let n = 12;
        let mut pts = Vec::new();
        for b in 0..2 {
            let base: Vec<i8> = vec![if b == 0 { 1 } else { -1 }; n];
            for _ in 0..4 {
                let mut p = base.clone();
                let i = rng.below(n);
                p[i] = -p[i];
                pts.push(p);
            }
        }
        pts
    }

    #[test]
    fn hamming_basic() {
        assert_eq!(hamming(&[1, -1, 1], &[1, 1, -1]), 2);
        assert_eq!(hamming(&[1, 1], &[1, 1]), 0);
    }

    #[test]
    fn ward_merges_blobs_last() {
        let mut rng = Rng::new(800);
        let pts = two_blobs(&mut rng);
        let merges = ward(&pts);
        assert_eq!(merges.len(), pts.len() - 1);
        // The final merge joins the two blobs — its distance must be the
        // largest by a wide margin.
        let last = merges.last().unwrap().dist;
        for m in &merges[..merges.len() - 1] {
            assert!(m.dist < last);
        }
    }

    #[test]
    fn cut_two_blobs_into_two_clusters() {
        let mut rng = Rng::new(801);
        let pts = two_blobs(&mut rng);
        let merges = ward(&pts);
        let labels = cut(&merges, pts.len(), 2);
        assert_eq!(labels.len(), 8);
        // First four leaves = blob A, last four = blob B.
        for i in 0..4 {
            assert_eq!(labels[i], labels[0]);
            assert_eq!(labels[4 + i], labels[4]);
        }
        assert_ne!(labels[0], labels[4]);
    }

    #[test]
    fn cut_k1_is_single_cluster_and_kn_is_all_singletons() {
        let mut rng = Rng::new(802);
        let pts = two_blobs(&mut rng);
        let merges = ward(&pts);
        let l1 = cut(&merges, pts.len(), 1);
        assert!(l1.iter().all(|&l| l == 0));
        let ln = cut(&merges, pts.len(), pts.len());
        let mut s: Vec<usize> = ln.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), pts.len());
    }

    #[test]
    fn assign_domain_picks_nearest() {
        let sols = vec![vec![1i8, 1, 1, 1], vec![-1i8, -1, -1, -1]];
        let labels = vec![0, 1];
        assert_eq!(assign_domain(&[1, 1, 1, -1], &sols, &labels), 0);
        assert_eq!(assign_domain(&[-1, -1, 1, -1], &sols, &labels), 1);
    }

    #[test]
    fn domain_trace_fractions_sum_to_one() {
        let mut rng = Rng::new(803);
        let sols = vec![vec![1i8; 6], vec![-1i8; 6]];
        let labels = vec![0, 1];
        let xs: Vec<Vec<i8>> = (0..50).map(|_| rng.spins(6)).collect();
        let traces = domain_trace(&xs, &sols, &labels, 2, 10);
        for t in 0..50 {
            let total: f64 = traces.iter().map(|row| row[t]).sum();
            assert!((total - 1.0).abs() < 1e-9, "t={t} total={total}");
        }
    }

    #[test]
    fn ward_on_48_solution_orbit_gives_4_domains() {
        // End-to-end: brute-force a tiny instance, cluster its orbit.
        let cfg = crate::instance::InstanceConfig {
            n: 6,
            d: 12,
            k: 2,
            gamma: 0.8,
            seed: 10,
        };
        let p = crate::instance::generate(&cfg, 0);
        let bf = crate::bruteforce::brute_force(&p);
        let pts: Vec<Vec<i8>> =
            bf.orbit.iter().map(|m| m.data.clone()).collect();
        let merges = ward(&pts);
        let labels = cut(&merges, pts.len(), 4.min(pts.len()));
        let distinct: std::collections::HashSet<_> =
            labels.iter().collect();
        assert_eq!(distinct.len(), 4.min(pts.len()));
    }
}
