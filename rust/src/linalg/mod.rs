//! Dense linear-algebra substrate (f64, row-major).
//!
//! Built in-tree because the offline vendored crate set has no linalg crate
//! (DESIGN.md §6).  Provides exactly what the coordinator needs: matmul,
//! Cholesky (for the BOCS posterior samplers), triangular and LU solves,
//! and thin Householder QR (random orthogonal factors for the instance
//! generator).
//!
//! ## Blocking and parallelism (ISSUE 3)
//!
//! The hot kernels — [`Matrix::matmul`], [`Matrix::gram`],
//! [`Matrix::matvec`] and the right-looking [`cholesky_into`] /
//! [`cholesky_scaled_into`] — are blocked for cache locality and fan
//! fixed-size row panels across the process-wide
//! [`crate::util::threadpool::WorkerPool`] once a call crosses the
//! `PAR_FLOPS` work threshold.  The panel partition is a pure function
//! of the shape (never of the worker count), and every output element is
//! accumulated in a fixed order, so results are bit-identical for any
//! pool width — the determinism contract the engine tests pin down.
//! `*_into` variants write into caller-owned buffers so the posterior
//! hot loop allocates nothing after warm-up (see
//! [`crate::surrogate::blr::PosteriorScratch`]).

mod qr;

pub use qr::householder_qr;

use crate::util::threadpool::{default_workers, WorkerPool};

/// Row height of one parallel panel: small enough to load-balance the
/// trailing Cholesky updates on a few cores, big enough that the queue
/// push is amortised over ~10⁵ flops at posterior scale (P ≈ 300).
const PANEL_ROWS: usize = 16;

/// Column-block width of the right-looking Cholesky: 48×48 diagonal
/// blocks (18 KiB) stay L1-resident alongside one trailing row panel.
const CHOL_BLOCK: usize = 48;

/// Flop count above which a kernel fans its row panels across the pool.
/// Below it the queue round-trip costs more than it buys (measured on
/// the P = 301 posterior shapes; see BENCH_*.json).
const PAR_FLOPS: usize = 1 << 20;

/// True when `flops` of independent row-panel work is worth fanning out
/// over the shared pool (used by the kernels here and by the rank-k
/// moment ingestion in `surrogate::Dataset::push_batch`).
pub(crate) fn parallel_worthwhile(flops: usize) -> bool {
    flops >= PAR_FLOPS
}

/// Apply `f(first_row, rows)` to consecutive `panel_rows`-high horizontal
/// panels of a row-major buffer, fanning the panels across the global
/// worker pool when `parallel` is set.
///
/// Each panel is a disjoint `&mut` slice, the partition depends only on
/// the shape, and `f` must touch nothing but its own panel (plus shared
/// read-only state), so serial and parallel execution are bit-identical.
pub(crate) fn for_each_row_panel<F>(
    data: &mut [f64],
    row_len: usize,
    parallel: bool,
    f: F,
) where
    F: Fn(usize, &mut [f64]) + Sync,
{
    if data.is_empty() || row_len == 0 {
        return;
    }
    let chunk = PANEL_ROWS * row_len;
    if !parallel {
        for (ci, rows) in data.chunks_mut(chunk).enumerate() {
            f(ci * PANEL_ROWS, rows);
        }
        return;
    }
    let panels: Vec<(usize, &mut [f64])> =
        data.chunks_mut(chunk).enumerate().collect();
    WorkerPool::global().map(panels, default_workers(), |(ci, rows)| {
        f(ci * PANEL_ROWS, rows);
    });
}

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major entries, length `rows * cols`.
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order n.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix from row vectors (all must share one length).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Matrix from flat row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row i as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self * other` with ikj loop order (streams rows of `other`),
    /// row panels fanned across the worker pool above `PAR_FLOPS`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        let n_cols = other.cols;
        let flops = self
            .rows
            .saturating_mul(self.cols)
            .saturating_mul(n_cols);
        let parallel = self.rows > 1 && parallel_worthwhile(flops);
        for_each_row_panel(&mut out.data, n_cols, parallel, |i0, rows| {
            for (li, out_row) in rows.chunks_mut(n_cols).enumerate() {
                let arow = self.row(i0 + li);
                for (k, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let orow = other.row(k);
                    for (o, &b) in out_row.iter_mut().zip(orow) {
                        *o += a * b;
                    }
                }
            }
        });
        out
    }

    /// `self^T * self` exploiting symmetry (Gram matrix): the upper
    /// triangle is accumulated row-streamed (each row of `self` read
    /// once per output panel), panels fanned across the worker pool
    /// above `PAR_FLOPS`, then mirrored.
    pub fn gram(&self) -> Matrix {
        let p = self.cols;
        let rows = self.rows;
        let mut g = Matrix::zeros(p, p);
        let flops = rows.saturating_mul(p).saturating_mul(p) / 2;
        let parallel = p > 1 && parallel_worthwhile(flops);
        for_each_row_panel(&mut g.data, p, parallel, |i0, grows| {
            for r in 0..rows {
                let arow = self.row(r);
                for (li, grow) in grows.chunks_mut(p).enumerate() {
                    let i = i0 + li;
                    let xi = arow[i];
                    if xi == 0.0 {
                        continue;
                    }
                    for (gj, &aj) in
                        grow[i..].iter_mut().zip(&arow[i..])
                    {
                        *gj += xi * aj;
                    }
                }
            }
        });
        for i in 0..p {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_into(x, &mut out);
        out
    }

    /// `out = self * x` without allocating once `out` has warmed up to
    /// `rows` capacity; rows fanned across the pool above `PAR_FLOPS`.
    pub fn matvec_into(&self, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(self.cols, x.len());
        out.resize(self.rows, 0.0);
        let flops = self.rows.saturating_mul(self.cols);
        let parallel = self.rows > 1 && parallel_worthwhile(flops);
        for_each_row_panel(&mut out[..], 1, parallel, |i0, outs| {
            for (li, o) in outs.iter_mut().enumerate() {
                *o = dot(self.row(i0 + li), x);
            }
        });
    }

    /// `self^T * x`.
    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (o, a) in out.iter_mut().zip(self.row(i)) {
                *o += xi * a;
            }
        }
        out
    }

    /// Squared Frobenius norm.
    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// Scalar multiple `s * self`.
    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Elementwise difference `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

thread_local! {
    /// Per-thread scratch for the blocked Cholesky (diagonal-block +
    /// solved-panel copies).  Reused across factorisations so the
    /// posterior hot loop allocates nothing after warm-up.  The borrow
    /// is held by the factor across its inner pool fan-out, which is
    /// safe: a waiting `WorkerPool::map` caller only ever reclaims its
    /// own batch's tickets (never unrelated work that could re-enter
    /// this factor on the same thread).
    static CHOL_SCRATCH: std::cell::RefCell<Vec<f64>> =
        std::cell::RefCell::new(Vec::new());
}

/// Resize `l` to an n×n zero matrix only when the shape is wrong
/// (keeping the allocation on the hot path).
fn resize_square(l: &mut Matrix, n: usize) {
    if l.rows != n || l.cols != n {
        *l = Matrix::zeros(n, n);
    }
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix.
///
/// Returns `None` when a pivot drops below `tol` (not SPD / numerically
/// singular) — callers either jitter the diagonal or treat it as an
/// error.  Allocating wrapper around [`cholesky_into`].
pub fn cholesky(a: &Matrix, tol: f64) -> Option<Matrix> {
    let mut l = Matrix::zeros(a.rows, a.rows);
    if cholesky_into(a, tol, &mut l) {
        Some(l)
    } else {
        None
    }
}

/// Blocked right-looking Cholesky of `a` written into the caller-owned
/// `l` (resized if its shape is wrong, reused otherwise — the zero-alloc
/// path of the posterior scratch).  Returns `false` when a pivot drops
/// to `tol` or below; `l` then holds partial garbage and the caller must
/// retry (e.g. with diagonal jitter) or bail.
pub fn cholesky_into(a: &Matrix, tol: f64, l: &mut Matrix) -> bool {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    resize_square(l, n);
    for i in 0..n {
        let src = a.row(i);
        let dst = &mut l.data[i * n..(i + 1) * n];
        dst[..=i].copy_from_slice(&src[..=i]);
        for v in &mut dst[i + 1..] {
            *v = 0.0;
        }
    }
    factor_lower_in_place(l, tol)
}

/// Cholesky of `A = G * scale + diag(lam) (+ jitter I)` without
/// materialising A separately — the posterior-precision factorisation is
/// the hottest O(P³) loop in the BOCS surrogate (EXPERIMENTS.md §Perf),
/// and G's entries are each read exactly once here.  Allocating wrapper
/// around [`cholesky_scaled_into`].
pub fn cholesky_scaled(
    g: &Matrix,
    scale: f64,
    lam: &[f64],
    jitter: f64,
    tol: f64,
) -> Option<Matrix> {
    let mut l = Matrix::zeros(g.rows, g.rows);
    if cholesky_scaled_into(g, scale, lam, jitter, tol, &mut l) {
        Some(l)
    } else {
        None
    }
}

/// [`cholesky_scaled`] into a caller-owned factor buffer (the scratch
/// path): `l`'s lower triangle is filled with `G·scale + diag(lam) +
/// jitter·I` and factored in place by the blocked right-looking
/// algorithm, its strict upper triangle zeroed.  Returns `false` on a
/// non-positive pivot (retry with more jitter).
pub fn cholesky_scaled_into(
    g: &Matrix,
    scale: f64,
    lam: &[f64],
    jitter: f64,
    tol: f64,
    l: &mut Matrix,
) -> bool {
    assert_eq!(g.rows, g.cols);
    let n = g.rows;
    assert_eq!(lam.len(), n);
    resize_square(l, n);
    for i in 0..n {
        let src = &g.data[i * n..(i + 1) * n];
        let dst = &mut l.data[i * n..(i + 1) * n];
        for j in 0..i {
            dst[j] = src[j] * scale;
        }
        dst[i] = src[i] * scale + lam[i] + jitter;
        for v in &mut dst[i + 1..] {
            *v = 0.0;
        }
    }
    factor_lower_in_place(l, tol)
}

/// How [`cholesky_jittered`] escalates when a factorisation fails:
/// attempt 0 always runs with **zero** jitter (bit-identical to
/// [`cholesky_into`] / [`cholesky_scaled_into`] on the same inputs),
/// then each of the `retries` retry attempts adds
/// `base · factor^i` to the diagonal.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JitterLadder {
    /// Diagonal jitter of the first retry.
    pub base: f64,
    /// Multiplicative escalation between consecutive retries.
    pub factor: f64,
    /// Jittered retries after the clean first attempt.
    pub retries: usize,
}

impl Default for JitterLadder {
    /// Three retries, ×10 jitter each, starting at `1e-10`.
    fn default() -> Self {
        JitterLadder { base: 1e-10, factor: 10.0, retries: 3 }
    }
}

/// Typed failure of the jittered Cholesky: the matrix stayed
/// numerically non-SPD after the whole ladder was exhausted.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CholeskyError {
    /// Factorisation attempts made (one clean + the retries).
    pub attempts: usize,
    /// The largest diagonal jitter tried.
    pub max_jitter: f64,
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix not SPD after {} Cholesky attempts (max jitter {:e})",
            self.attempts, self.max_jitter
        )
    }
}

impl std::error::Error for CholeskyError {}

/// Typed numeric failure of the surrogate/BBO pipeline — the error
/// taxonomy every layer above `linalg` speaks (ISSUE 9).  Each variant
/// is a *recoverable* fault: callers either degrade (fall back to a
/// random acquisition, quarantine the sample) or surface the error as a
/// typed per-request failure, never a process abort.
#[derive(Clone, Debug, PartialEq)]
pub enum NumericError {
    /// The posterior precision matrix stayed non-SPD after the whole
    /// jitter ladder (wraps the [`CholeskyError`] from the draw).
    PosteriorNotSpd(CholeskyError),
    /// A black-box cost came back NaN/±Inf and no finite evaluation
    /// remained to fall back on; `rejected` counts the quarantined
    /// evaluations.
    NonFiniteCost {
        /// Non-finite evaluations quarantined before the failure.
        rejected: usize,
    },
    /// An input matrix carried a NaN/±Inf entry (row-major flat index).
    NonFiniteInput {
        /// Flat row-major index of the first offending entry.
        index: usize,
    },
    /// A trained surrogate produced non-finite parameters.
    SurrogateDiverged {
        /// Which surrogate diverged (e.g. "fm").
        surrogate: &'static str,
    },
}

impl From<CholeskyError> for NumericError {
    fn from(e: CholeskyError) -> Self {
        NumericError::PosteriorNotSpd(e)
    }
}

impl std::fmt::Display for NumericError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NumericError::PosteriorNotSpd(e) => {
                write!(f, "posterior not SPD: {e}")
            }
            NumericError::NonFiniteCost { rejected } => write!(
                f,
                "no finite cost observed ({rejected} non-finite \
                 evaluation(s) quarantined)"
            ),
            NumericError::NonFiniteInput { index } => write!(
                f,
                "input matrix has a non-finite entry at flat index {index}"
            ),
            NumericError::SurrogateDiverged { surrogate } => {
                write!(f, "{surrogate} surrogate diverged to non-finite \
                           parameters")
            }
        }
    }
}

impl std::error::Error for NumericError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NumericError::PosteriorNotSpd(e) => Some(e),
            _ => None,
        }
    }
}

/// [`cholesky`] with a bounded escalating diagonal-jitter retry: the
/// graceful-degradation path for near-singular Gram matrices.  Returns
/// the factor and the jitter that succeeded (`0.0` on the clean first
/// attempt, whose factor is bit-identical to [`cholesky`]'s), or a
/// typed [`CholeskyError`] once the ladder is exhausted.
pub fn cholesky_jittered(
    a: &Matrix,
    tol: f64,
    ladder: JitterLadder,
) -> Result<(Matrix, f64), CholeskyError> {
    let mut l = Matrix::zeros(a.rows, a.rows);
    if cholesky_into(a, tol, &mut l) {
        return Ok((l, 0.0));
    }
    let zeros = vec![0.0; a.rows];
    let mut jitter = 0.0;
    let mut attempts = 1usize;
    loop {
        if attempts > ladder.retries {
            return Err(CholeskyError { attempts, max_jitter: jitter });
        }
        attempts += 1;
        jitter = if jitter == 0.0 {
            ladder.base
        } else {
            jitter * ladder.factor
        };
        if cholesky_scaled_into(a, 1.0, &zeros, jitter, tol, &mut l) {
            return Ok((l, jitter));
        }
    }
}

/// [`cholesky_scaled_into`] with the same bounded jitter ladder — the
/// zero-alloc variant the surrogate's posterior draw runs on.  The
/// first attempt uses zero jitter and is bit-identical to calling
/// [`cholesky_scaled_into`] directly; on success the jitter used is
/// returned so callers can surface degraded draws.
pub fn cholesky_jittered_scaled_into(
    g: &Matrix,
    scale: f64,
    lam: &[f64],
    tol: f64,
    ladder: JitterLadder,
    l: &mut Matrix,
) -> Result<f64, CholeskyError> {
    let mut jitter = 0.0;
    let mut attempts = 0usize;
    loop {
        attempts += 1;
        if cholesky_scaled_into(g, scale, lam, jitter, tol, l) {
            return Ok(jitter);
        }
        if attempts > ladder.retries {
            return Err(CholeskyError { attempts, max_jitter: jitter });
        }
        jitter = if jitter == 0.0 {
            ladder.base
        } else {
            jitter * ladder.factor
        };
    }
}

/// Blocked right-looking Cholesky on the lower triangle of `l` (strict
/// upper triangle must already be zero).  Per block step: unblocked
/// factor of the diagonal block, triangular solve of the panel below it,
/// then the rank-`CHOL_BLOCK` symmetric trailing update — the O(n³)
/// bulk, row panels fanned across the pool above `PAR_FLOPS`.  The
/// diagonal block and the solved panel are copied into a thread-local
/// scratch first, so parallel panel workers only ever read shared copies
/// and write their own rows (bit-identical for any worker count).
fn factor_lower_in_place(l: &mut Matrix, tol: f64) -> bool {
    let n = l.rows;
    let mut j0 = 0;
    while j0 < n {
        let jb = CHOL_BLOCK.min(n - j0);
        // 1. Diagonal block, unblocked: row-prefix dots over the block's
        //    own columns (previous panels already applied their trailing
        //    updates).  `rowj` is a stack copy of the pivot row's block
        //    prefix, so the column update below can read it while
        //    writing other rows.
        let mut rowj = [0.0f64; CHOL_BLOCK];
        for j in j0..j0 + jb {
            let w = j - j0;
            rowj[..w].copy_from_slice(&l.data[j * n + j0..j * n + j]);
            let d = l.data[j * n + j] - dot(&rowj[..w], &rowj[..w]);
            if d <= tol {
                return false;
            }
            let dj = d.sqrt();
            let inv = 1.0 / dj;
            l.data[j * n + j] = dj;
            for i in j + 1..j0 + jb {
                let s = dot(&l.data[i * n + j0..i * n + j], &rowj[..w]);
                l.data[i * n + j] = (l.data[i * n + j] - s) * inv;
            }
        }
        let t0 = j0 + jb;
        if t0 == n {
            break;
        }
        let trail = n - t0;
        CHOL_SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            buf.clear();
            buf.resize(jb * jb + trail * jb, 0.0);
            let (diag, panel) = buf.split_at_mut(jb * jb);
            for j in 0..jb {
                let row = (j0 + j) * n + j0;
                diag[j * jb..(j + 1) * jb]
                    .copy_from_slice(&l.data[row..row + jb]);
            }
            // 2. Panel solve: L21 <- A21 * L11^{-T}, row by row (each
            //    row reads only itself and the diag copy).
            let par2 = parallel_worthwhile(
                trail.saturating_mul(jb).saturating_mul(jb) / 2,
            );
            let diag_ref: &[f64] = diag;
            for_each_row_panel(
                &mut l.data[t0 * n..],
                n,
                par2,
                |_r0, rows| {
                    for row in rows.chunks_mut(n) {
                        for j in 0..jb {
                            let s = dot(
                                &row[j0..j0 + j],
                                &diag_ref[j * jb..j * jb + j],
                            );
                            row[j0 + j] =
                                (row[j0 + j] - s) / diag_ref[j * jb + j];
                        }
                    }
                },
            );
            // Copy the solved panel so the trailing update can read any
            // row's panel while writing its own trailing columns.
            for r in 0..trail {
                let row = (t0 + r) * n + j0;
                panel[r * jb..(r + 1) * jb]
                    .copy_from_slice(&l.data[row..row + jb]);
            }
            // 3. Trailing update: A22 <- A22 - L21 * L21^T (lower
            //    triangle only), one dot per element.
            let par3 = parallel_worthwhile(
                trail.saturating_mul(trail).saturating_mul(jb) / 2,
            );
            let panel_ref: &[f64] = panel;
            for_each_row_panel(
                &mut l.data[t0 * n..],
                n,
                par3,
                |r0, rows| {
                    for (lr, row) in rows.chunks_mut(n).enumerate() {
                        let r = r0 + lr;
                        let pr = &panel_ref[r * jb..(r + 1) * jb];
                        for c in 0..=r {
                            let pc = &panel_ref[c * jb..(c + 1) * jb];
                            row[t0 + c] -= dot(pr, pc);
                        }
                    }
                },
            );
        });
        j0 = t0;
    }
    true
}

/// Solve `L x = b` for lower-triangular L.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut out = Vec::new();
    solve_lower_into(l, b, &mut out);
    out
}

/// Forward substitution `L out = b` into a caller-owned buffer
/// (resized to n; zero-alloc once warmed up).
pub fn solve_lower_into(l: &Matrix, b: &[f64], out: &mut Vec<f64>) {
    let n = l.rows;
    assert_eq!(b.len(), n);
    out.resize(n, 0.0);
    for i in 0..n {
        let row = l.row(i);
        let s = b[i] - dot(&row[..i], &out[..i]);
        out[i] = s / row[i];
    }
}

/// Solve `L^T x = b` for lower-triangular L.
pub fn solve_lower_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = b.to_vec();
    solve_lower_t_in_place(l, &mut x);
    x
}

/// Back substitution `L^T x = x` in place (the allocation-free sibling
/// of [`solve_lower_t`]).
pub fn solve_lower_t_in_place(l: &Matrix, x: &mut [f64]) {
    let n = l.rows;
    assert_eq!(x.len(), n);
    for i in (0..n).rev() {
        x[i] /= l[(i, i)];
        let xi = x[i];
        let row = l.row(i);
        for k in 0..i {
            x[k] -= row[k] * xi;
        }
    }
}

/// Solve `A x = b` through an existing Cholesky factor `L` (A = L L^T).
pub fn cho_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = Vec::new();
    solve_lower_into(l, b, &mut x);
    solve_lower_t_in_place(l, &mut x);
    x
}

/// Solve `A x = b` by LU with partial pivoting. Returns `None` if singular.
pub fn lu_solve(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    assert_eq!(b.len(), n);
    let mut m = a.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        let mut best = m[(col, col)].abs();
        for r in (col + 1)..n {
            let v = m[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let t = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = t;
            }
            x.swap(col, piv);
        }
        let d = m[(col, col)];
        for r in (col + 1)..n {
            let f = m[(r, col)] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                let v = m[(col, j)];
                m[(r, j)] -= f * v;
            }
            x[r] -= f * x[col];
        }
    }
    // Back substitution.
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= m[(i, j)] * x[j];
        }
        x[i] = s / m[(i, i)];
    }
    Some(x)
}

/// Dot product with four accumulators — breaks the serial FP-add chain so
/// LLVM can vectorise/pipeline it; ~3× over the naive zip-sum on the
/// P=301 posterior factorisations (EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        // Safety: i + 3 < 4 * chunks <= n for both slices (equal length).
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3);
        }
    }
    let mut tail = 0.0;
    for i in (chunks * 4)..n {
        tail += a[i] * b[i];
    }
    (s0 + s2) + (s1 + s3) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normals(r * c))
    }

    fn spd(rng: &mut Rng, n: usize) -> Matrix {
        let a = rand_matrix(rng, n + 3, n);
        let mut g = a.gram();
        for i in 0..n {
            g[(i, i)] += 0.5;
        }
        g
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = rand_matrix(&mut rng, 4, 6);
        let i6 = Matrix::identity(6);
        assert_eq!(a.matmul(&i6).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Rng::new(2);
        let a = rand_matrix(&mut rng, 7, 5);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(3);
        let a = spd(&mut rng, 12);
        let l = cholesky(&a, 1e-12).unwrap();
        let llt = l.matmul(&l.transpose());
        for (x, y) in llt.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn blocked_cholesky_roundtrip_past_one_block() {
        // n > CHOL_BLOCK exercises the panel solve + trailing update.
        let mut rng = Rng::new(33);
        let n = CHOL_BLOCK + 19;
        let a = spd(&mut rng, n);
        let l = cholesky(&a, 1e-12).unwrap();
        let llt = l.matmul(&l.transpose());
        let scale = a.frob_norm_sq().sqrt();
        for (x, y) in llt.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-10 * scale);
        }
        // Strict upper triangle stays zero.
        for i in 0..n {
            for j in i + 1..n {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a, 1e-12).is_none());
    }

    #[test]
    fn cholesky_into_reuses_oversized_buffer() {
        // A wrong-shaped scratch is resized; a right-shaped one is
        // reused and fully overwritten (same bits as a fresh factor).
        let mut rng = Rng::new(34);
        let a = spd(&mut rng, 9);
        let fresh = cholesky(&a, 1e-12).unwrap();
        let mut l = Matrix::zeros(3, 3);
        assert!(cholesky_into(&a, 1e-12, &mut l));
        assert_eq!(l.data, fresh.data);
        // Second factorisation into the now-right-shaped buffer.
        let b = spd(&mut rng, 9);
        let fresh_b = cholesky(&b, 1e-12).unwrap();
        assert!(cholesky_into(&b, 1e-12, &mut l));
        assert_eq!(l.data, fresh_b.data);
    }

    #[test]
    fn jittered_no_jitter_path_is_bit_identical() {
        // Seed-pinned: on an SPD matrix the ladder's clean first
        // attempt must reproduce the direct factorisations bit for
        // bit — jitter must be a pure fallback, never a perturbation.
        let mut rng = Rng::new(77);
        let a = spd(&mut rng, CHOL_BLOCK + 5);
        let direct = cholesky(&a, 1e-12).unwrap();
        let (l, jitter) =
            cholesky_jittered(&a, 1e-12, JitterLadder::default()).unwrap();
        assert_eq!(jitter, 0.0);
        assert_eq!(l.data, direct.data);
        // Scaled variant: same contract against cholesky_scaled_into.
        let n = a.rows;
        let lam: Vec<f64> = (0..n).map(|i| 0.1 + i as f64 * 0.01).collect();
        let mut want = Matrix::zeros(n, n);
        assert!(cholesky_scaled_into(&a, 0.7, &lam, 0.0, 0.0, &mut want));
        let mut got = Matrix::zeros(n, n);
        let jitter = cholesky_jittered_scaled_into(
            &a,
            0.7,
            &lam,
            0.0,
            JitterLadder::default(),
            &mut got,
        )
        .unwrap();
        assert_eq!(jitter, 0.0);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn jittered_ladder_rescues_a_singular_gram() {
        // Rank-deficient Gram (duplicate columns): the clean attempt
        // fails, an escalated jitter succeeds, and the jitter used is
        // one of the ladder's rungs.
        let mut rng = Rng::new(78);
        let col = rng.normals(6);
        let mut a = Matrix::zeros(6, 2);
        for i in 0..6 {
            a[(i, 0)] = col[i];
            a[(i, 1)] = col[i];
        }
        let g = a.gram();
        assert!(cholesky(&g, 1e-12).is_none(), "precondition: singular");
        let ladder = JitterLadder { base: 1e-8, factor: 10.0, retries: 3 };
        let (l, jitter) = cholesky_jittered(&g, 1e-12, ladder).unwrap();
        assert!(jitter > 0.0 && jitter <= 1e-8 * 10f64.powi(2));
        // The factor reproduces G + jitter·I.
        let llt = l.matmul(&l.transpose());
        for i in 0..2 {
            for j in 0..2 {
                let want = g[(i, j)] + if i == j { jitter } else { 0.0 };
                assert!((llt[(i, j)] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn jittered_exhaustion_is_a_typed_error() {
        // An indefinite matrix no tiny jitter can fix: the ladder must
        // exhaust and report exactly how hard it tried.
        let a = Matrix::from_rows(&[vec![1.0, 9.0], vec![9.0, 1.0]]);
        let ladder = JitterLadder { base: 1e-10, factor: 10.0, retries: 3 };
        let err = cholesky_jittered(&a, 1e-12, ladder).unwrap_err();
        assert_eq!(err.attempts, 4);
        assert!((err.max_jitter - 1e-8).abs() < 1e-20);
        let msg = err.to_string();
        assert!(msg.contains("not SPD"), "message: {msg}");
        // Scaled variant exhausts identically.
        let lam = vec![0.0; 2];
        let mut l = Matrix::zeros(2, 2);
        let err = cholesky_jittered_scaled_into(
            &a,
            1.0,
            &lam,
            1e-12,
            ladder,
            &mut l,
        )
        .unwrap_err();
        assert_eq!(err.attempts, 4);
    }

    #[test]
    fn cho_solve_solves() {
        let mut rng = Rng::new(4);
        let a = spd(&mut rng, 9);
        let x_true = rng.normals(9);
        let b = a.matvec(&x_true);
        let l = cholesky(&a, 1e-12).unwrap();
        let x = cho_solve(&l, &b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(5);
        let a = spd(&mut rng, 6);
        let l = cholesky(&a, 1e-12).unwrap();
        let x_true = rng.normals(6);
        let b = l.matvec(&x_true);
        let x = solve_lower(&l, &b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9);
        }
        let bt = l.transpose().matvec(&x_true);
        let xt = solve_lower_t(&l, &bt);
        for (u, v) in xt.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn matvec_into_reuses_buffer() {
        let mut rng = Rng::new(35);
        let a = rand_matrix(&mut rng, 5, 9);
        let x = rng.normals(9);
        let mut out = vec![7.0; 2]; // wrong size, stale values
        a.matvec_into(&x, &mut out);
        assert_eq!(out, a.matvec(&x));
    }

    #[test]
    fn lu_solve_general() {
        let mut rng = Rng::new(6);
        let a = rand_matrix(&mut rng, 8, 8);
        let x_true = rng.normals(8);
        let b = a.matvec(&x_true);
        let x = lu_solve(&a, &b).unwrap();
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-7);
        }
    }

    #[test]
    fn lu_solve_detects_singular() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![2.0, 4.0, 6.0],
            vec![0.0, 1.0, 1.0],
        ]);
        assert!(lu_solve(&a, &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn tmatvec_matches_transpose() {
        let mut rng = Rng::new(7);
        let a = rand_matrix(&mut rng, 5, 9);
        let x = rng.normals(5);
        let got = a.tmatvec(&x);
        let want = a.transpose().matvec(&x);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-10);
        }
    }
}
