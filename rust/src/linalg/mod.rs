//! Dense linear-algebra substrate (f64, row-major).
//!
//! Built in-tree because the offline vendored crate set has no linalg crate
//! (DESIGN.md §6).  Provides exactly what the coordinator needs: matmul,
//! Cholesky (for the BOCS posterior samplers), triangular and LU solves,
//! and thin Householder QR (random orthogonal factors for the instance
//! generator).  Shapes are small (≤ a few hundred), so the implementations
//! favour clarity + cache-friendly loop order over blocking.

mod qr;

pub use qr::householder_qr;

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major entries, length `rows * cols`.
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order n.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix from row vectors (all must share one length).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Matrix from flat row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row i as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self * other` with ikj loop order (streams rows of `other`).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `self^T * self` exploiting symmetry (Gram matrix).
    pub fn gram(&self) -> Matrix {
        let p = self.cols;
        let mut g = Matrix::zeros(p, p);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..p {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for j in i..p {
                    g[(i, j)] += xi * row[j];
                }
            }
        }
        for i in 0..p {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                self.row(i).iter().zip(x).map(|(a, b)| a * b).sum::<f64>()
            })
            .collect()
    }

    /// `self^T * x`.
    pub fn tmatvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (o, a) in out.iter_mut().zip(self.row(i)) {
                *o += xi * a;
            }
        }
        out
    }

    /// Squared Frobenius norm.
    pub fn frob_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// Scalar multiple `s * self`.
    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Elementwise difference `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
///
/// Returns `None` when a pivot drops below `tol` (not SPD / numerically
/// singular) — callers either jitter the diagonal or treat it as an error.
pub fn cholesky(a: &Matrix, tol: f64) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        // d = a_jj - l_j[..j] . l_j[..j]  — contiguous row-prefix slices,
        // no per-element bounds checks (hot path; EXPERIMENTS.md §Perf).
        let row_j = &l.data[j * n..j * n + j];
        let d = a[(j, j)] - dot(row_j, row_j);
        if d <= tol {
            return None;
        }
        let dj = d.sqrt();
        let inv_dj = 1.0 / dj;
        let mut col = Vec::with_capacity(n - j - 1);
        for i in (j + 1)..n {
            let row_i = &l.data[i * n..i * n + j];
            col.push((a[(i, j)] - dot(row_i, row_j)) * inv_dj);
        }
        l.data[j * n + j] = dj;
        for (off, v) in col.into_iter().enumerate() {
            l.data[(j + 1 + off) * n + j] = v;
        }
    }
    Some(l)
}

/// Cholesky of `A = G * scale + diag(lam) (+ jitter I)` without
/// materialising A — the posterior-precision factorisation is the hottest
/// O(P³) loop in the BOCS surrogate (EXPERIMENTS.md §Perf), and G's
/// entries are each read exactly once here.
pub fn cholesky_scaled(
    g: &Matrix,
    scale: f64,
    lam: &[f64],
    jitter: f64,
    tol: f64,
) -> Option<Matrix> {
    assert_eq!(g.rows, g.cols);
    let n = g.rows;
    assert_eq!(lam.len(), n);
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        let row_j = &l.data[j * n..j * n + j];
        let ajj = g.data[j * n + j] * scale + lam[j] + jitter;
        let d = ajj - dot(row_j, row_j);
        if d <= tol {
            return None;
        }
        let dj = d.sqrt();
        let inv_dj = 1.0 / dj;
        let mut col = Vec::with_capacity(n - j - 1);
        for i in (j + 1)..n {
            let row_i = &l.data[i * n..i * n + j];
            let aij = g.data[i * n + j] * scale;
            col.push((aij - dot(row_i, row_j)) * inv_dj);
        }
        l.data[j * n + j] = dj;
        for (off, v) in col.into_iter().enumerate() {
            l.data[(j + 1 + off) * n + j] = v;
        }
    }
    Some(l)
}

/// Solve `L x = b` for lower-triangular L.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] * x[k];
        }
        x[i] = s / row[i];
    }
    x
}

/// Solve `L^T x = b` for lower-triangular L.
pub fn solve_lower_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        x[i] /= l[(i, i)];
        let xi = x[i];
        for k in 0..i {
            x[k] -= l[(i, k)] * xi;
        }
    }
    x
}

/// Solve `A x = b` through an existing Cholesky factor `L` (A = L L^T).
pub fn cho_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// Solve `A x = b` by LU with partial pivoting. Returns `None` if singular.
pub fn lu_solve(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    assert_eq!(b.len(), n);
    let mut m = a.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        let mut best = m[(col, col)].abs();
        for r in (col + 1)..n {
            let v = m[(r, col)].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let t = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = t;
            }
            x.swap(col, piv);
        }
        let d = m[(col, col)];
        for r in (col + 1)..n {
            let f = m[(r, col)] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                let v = m[(col, j)];
                m[(r, j)] -= f * v;
            }
            x[r] -= f * x[col];
        }
    }
    // Back substitution.
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= m[(i, j)] * x[j];
        }
        x[i] = s / m[(i, i)];
    }
    Some(x)
}

/// Dot product with four accumulators — breaks the serial FP-add chain so
/// LLVM can vectorise/pipeline it; ~3× over the naive zip-sum on the
/// P=301 posterior factorisations (EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        // Safety: i + 3 < 4 * chunks <= n for both slices (equal length).
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3);
        }
    }
    let mut tail = 0.0;
    for i in (chunks * 4)..n {
        tail += a[i] * b[i];
    }
    (s0 + s2) + (s1 + s3) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normals(r * c))
    }

    fn spd(rng: &mut Rng, n: usize) -> Matrix {
        let a = rand_matrix(rng, n + 3, n);
        let mut g = a.gram();
        for i in 0..n {
            g[(i, i)] += 0.5;
        }
        g
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = rand_matrix(&mut rng, 4, 6);
        let i6 = Matrix::identity(6);
        assert_eq!(a.matmul(&i6).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Rng::new(2);
        let a = rand_matrix(&mut rng, 7, 5);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for (x, y) in g.data.iter().zip(&g2.data) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(3);
        let a = spd(&mut rng, 12);
        let l = cholesky(&a, 1e-12).unwrap();
        let llt = l.matmul(&l.transpose());
        for (x, y) in llt.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a, 1e-12).is_none());
    }

    #[test]
    fn cho_solve_solves() {
        let mut rng = Rng::new(4);
        let a = spd(&mut rng, 9);
        let x_true = rng.normals(9);
        let b = a.matvec(&x_true);
        let l = cholesky(&a, 1e-12).unwrap();
        let x = cho_solve(&l, &b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(5);
        let a = spd(&mut rng, 6);
        let l = cholesky(&a, 1e-12).unwrap();
        let x_true = rng.normals(6);
        let b = l.matvec(&x_true);
        let x = solve_lower(&l, &b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9);
        }
        let bt = l.transpose().matvec(&x_true);
        let xt = solve_lower_t(&l, &bt);
        for (u, v) in xt.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn lu_solve_general() {
        let mut rng = Rng::new(6);
        let a = rand_matrix(&mut rng, 8, 8);
        let x_true = rng.normals(8);
        let b = a.matvec(&x_true);
        let x = lu_solve(&a, &b).unwrap();
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-7);
        }
    }

    #[test]
    fn lu_solve_detects_singular() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![2.0, 4.0, 6.0],
            vec![0.0, 1.0, 1.0],
        ]);
        assert!(lu_solve(&a, &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn tmatvec_matches_transpose() {
        let mut rng = Rng::new(7);
        let a = rand_matrix(&mut rng, 5, 9);
        let x = rng.normals(5);
        let got = a.tmatvec(&x);
        let want = a.transpose().matvec(&x);
        for (u, v) in got.iter().zip(&want) {
            assert!((u - v).abs() < 1e-10);
        }
    }
}
