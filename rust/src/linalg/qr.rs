//! Thin Householder QR.
//!
//! Used by the instance generator to draw Haar-ish random orthonormal
//! factors (QR of a Gaussian matrix with sign-fixed R diagonal).

use super::Matrix;

/// Thin QR of an m×n matrix (m >= n): returns (Q m×n with orthonormal
/// columns, R n×n upper-triangular), with R's diagonal made non-negative so
/// the decomposition of a Gaussian matrix is Haar-distributed.
pub fn householder_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin QR needs rows >= cols");
    let mut r = a.clone();
    // Householder vectors stored column-wise.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);

    for k in 0..n {
        // Build the reflector for column k below the diagonal.
        let mut norm2 = 0.0;
        for i in k..m {
            norm2 += r[(i, k)] * r[(i, k)];
        }
        let norm = norm2.sqrt();
        let mut v = vec![0.0; m - k];
        if norm < 1e-300 {
            vs.push(v);
            continue;
        }
        let alpha = if r[(k, k)] >= 0.0 { -norm } else { norm };
        v[0] = r[(k, k)] - alpha;
        for i in (k + 1)..m {
            v[i - k] = r[(i, k)];
        }
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply H = I - 2 v v^T / (v^T v) to the trailing block of R.
        for j in k..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i - k] * r[(i, j)];
            }
            let f = 2.0 * s / vnorm2;
            for i in k..m {
                r[(i, j)] -= f * v[i - k];
            }
        }
        vs.push(v);
    }

    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the thin identity.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i - k] * q[(i, j)];
            }
            let f = 2.0 * s / vnorm2;
            for i in k..m {
                q[(i, j)] -= f * v[i - k];
            }
        }
    }

    // Zero the strict lower triangle of R and fix signs so diag(R) >= 0.
    let mut r_thin = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_thin[(i, j)] = r[(i, j)];
        }
    }
    for i in 0..n {
        if r_thin[(i, i)] < 0.0 {
            for j in i..n {
                r_thin[(i, j)] = -r_thin[(i, j)];
            }
            for row in 0..m {
                q[(row, i)] = -q[(row, i)];
            }
        }
    }
    (q, r_thin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normals(r * c))
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(5, 5), (8, 3), (12, 7), (100, 8)] {
            let a = rand_matrix(&mut rng, m, n);
            let (q, r) = householder_qr(&a);
            let qr = q.matmul(&r);
            for (x, y) in qr.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-8, "({m},{n})");
            }
        }
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::new(11);
        let a = rand_matrix(&mut rng, 20, 6);
        let (q, _) = householder_qr(&a);
        let qtq = q.gram();
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq[(i, j)] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn r_is_upper_triangular_nonneg_diag() {
        let mut rng = Rng::new(12);
        let a = rand_matrix(&mut rng, 10, 10);
        let (_, r) = householder_qr(&a);
        for i in 0..10 {
            assert!(r[(i, i)] >= 0.0);
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }
}
