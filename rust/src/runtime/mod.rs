//! PJRT artifact runtime — the L3 ↔ L2/L1 boundary.
//!
//! Loads the HLO-text artifacts emitted by `python/compile/aot.py`
//! (`make artifacts`), compiles them once on the PJRT CPU client, and
//! exposes typed entry points.  Python never runs at request time; after
//! `make artifacts` the binary is self-contained.
//!
//! Interchange format is HLO *text* (xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids — see
//! /opt/xla-example/README.md).
//!
//! Every entry point has a native twin (`cost::Problem::cost`,
//! `surrogate::blr::NativePosterior`, `surrogate::fm` Adam); integration
//! tests cross-check the two, and the CLI falls back to native when
//! `artifacts/` is absent.
//!
//! The `xla` bindings themselves are NOT vendored in the offline build
//! image, so the PJRT implementation (`pjrt.rs`) is gated behind the
//! off-by-default `xla` cargo feature; the default build compiles
//! `stub.rs`, which has the same API but never loads artifacts, so every
//! caller takes its native fallback path.

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::XlaRuntime;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaRuntime;

use anyhow::{anyhow, Result};

use crate::cost::BinMatrix;
use crate::linalg::{Matrix, NumericError};
use crate::surrogate::blr::PosteriorBackend;
use crate::surrogate::fm::FmTrainer;
use crate::util::json::Json;

/// Shape contract recorded by `aot.py` in `artifacts/meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Target rows N the cost artifact was compiled for.
    pub n: usize,
    /// Target columns D.
    pub d: usize,
    /// Decomposition rank K.
    pub k: usize,
    /// Binary variables n = N·K.
    pub nbits: usize,
    /// Surrogate feature dimension P.
    pub p: usize,
    /// Cost-artifact batch width.
    pub batch: usize,
    /// Max dataset rows of the gram/FM artifacts.
    pub nmax: usize,
    /// FM factor counts with compiled trainers.
    pub kfms: Vec<usize>,
    /// Adam steps per fm_epoch artifact call.
    pub fm_steps: usize,
}

impl ArtifactMeta {
    /// Parse `artifacts/meta.json` text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("meta.json missing '{k}'"))
        };
        let kfms = j
            .get("kfms")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("meta.json missing 'kfms'"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        Ok(ArtifactMeta {
            n: get("n")?,
            d: get("d")?,
            k: get("k")?,
            nbits: get("nbits")?,
            p: get("p")?,
            batch: get("batch")?,
            nmax: get("nmax")?,
            kfms,
            fm_steps: get("fm_steps")?,
        })
    }
}

/// BOCS posterior backend routed through the artifact ("fast Gaussian
/// sampler" on the XLA side).
pub struct XlaPosterior {
    /// The loaded artifact runtime.
    pub rt: std::sync::Arc<XlaRuntime>,
}

impl PosteriorBackend for XlaPosterior {
    fn draw(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
    ) -> Result<(Vec<f64>, f64), NumericError> {
        match self.rt.bocs_draw(g, gv, lam, sigma_n2, z) {
            Ok(out) => Ok(out),
            Err(e) => {
                // Artifact mismatch is a programming error upstream; fall
                // back to native so a run is never lost mid-experiment.
                // The native twin may itself fail (non-SPD posterior),
                // which propagates as the typed NumericError.
                eprintln!("warn: xla posterior fell back to native: {e:#}");
                crate::surrogate::blr::NativePosterior
                    .draw(g, gv, lam, sigma_n2, z)
            }
        }
    }

    fn backend_name(&self) -> &'static str {
        "xla"
    }
}

/// FM trainer routed through the `fm_epoch` artifact.
pub struct XlaFmTrainer {
    /// The loaded artifact runtime.
    pub rt: std::sync::Arc<XlaRuntime>,
    /// Artifact calls per `train_epoch` (each is `meta.fm_steps` Adam
    /// steps with moments re-initialised, warm-started parameters).
    pub bundles: usize,
}

impl FmTrainer for XlaFmTrainer {
    fn train_epoch(
        &self,
        xs: &[Vec<i8>],
        ys: &[f64],
        w0: &mut f64,
        w: &mut [f64],
        v: &mut Matrix,
        lr: f64,
    ) -> Result<(), NumericError> {
        for _ in 0..self.bundles.max(1) {
            match self.rt.fm_epoch(v.cols, xs, ys, *w0, w, v, lr) {
                Ok((nw0, nw, nv)) => {
                    *w0 = nw0;
                    w.copy_from_slice(&nw);
                    *v = nv;
                }
                Err(e) => {
                    // Artifact failure keeps the warm parameters; the
                    // caller's finiteness check decides whether the model
                    // is still usable.
                    eprintln!("warn: xla fm trainer failed: {e:#}");
                    return Ok(());
                }
            }
        }
        Ok(())
    }

    fn trainer_name(&self) -> &'static str {
        "xla"
    }
}

/// Cost oracle that routes black-box evaluations through the Pallas cost
/// artifact (the paper's f(M) on the XLA side), keeping symmetry metadata
/// from the native problem.
pub struct XlaCostOracle {
    /// The loaded artifact runtime.
    pub rt: std::sync::Arc<XlaRuntime>,
    /// The native problem (shape, symmetry orbit, fallback math).
    pub problem: crate::cost::Problem,
}

impl crate::minlp::Oracle for XlaCostOracle {
    fn n_bits(&self) -> usize {
        self.problem.n_bits()
    }

    fn eval(&self, x: &[i8]) -> f64 {
        let m = BinMatrix::from_spins(self.problem.n(), self.problem.k, x);
        match self.rt.cost_batch(&self.problem.w, std::slice::from_ref(&m)) {
            Ok(costs) => costs[0],
            Err(e) => {
                eprintln!("warn: xla cost fell back to native: {e:#}");
                self.problem.cost(&m)
            }
        }
    }

    fn equivalents(&self, x: &[i8]) -> Vec<Vec<i8>> {
        crate::minlp::Oracle::equivalents(&self.problem, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let text = r#"{"n":8,"d":100,"k":3,"nbits":24,"p":301,
                       "batch":256,"nmax":1280,"kfms":[8,12],
                       "fm_steps":100,"feature_order":"x"}"#;
        let m = ArtifactMeta::parse(text).unwrap();
        assert_eq!(m.p, 301);
        assert_eq!(m.kfms, vec![8, 12]);
    }

    #[test]
    fn meta_parse_rejects_missing_keys() {
        assert!(ArtifactMeta::parse(r#"{"n":8}"#).is_err());
        assert!(ArtifactMeta::parse("not json").is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_reports_missing_feature_on_valid_meta() {
        let dir = std::env::temp_dir().join("intdecomp_stub_meta");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"n":8,"d":100,"k":3,"nbits":24,"p":301,"batch":256,
                "nmax":1280,"kfms":[8],"fm_steps":100}"#,
        )
        .unwrap();
        let err = XlaRuntime::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("xla"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
