//! Native stand-in for the PJRT runtime, compiled when the `xla` feature
//! is off (the default: the native bindings are not vendored in the
//! offline build image).
//!
//! `load` still reads and validates `meta.json` so the failure-injection
//! tests exercise the same error paths, then reports the runtime as
//! unavailable; `load_default` returns `None`.  Every caller already has a
//! native fallback (`cost::Problem::cost`, `surrogate::blr::NativePosterior`,
//! the native FM Adam trainer), so the system degrades to pure-native math
//! rather than failing.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::ArtifactMeta;
use crate::cost::BinMatrix;
use crate::linalg::Matrix;

/// Compiled-artifact runtime (stub: artifacts are never available).
pub struct XlaRuntime {
    /// Shape contract parsed from `meta.json`.
    pub meta: ArtifactMeta,
    /// Artifact directory the runtime was loaded from.
    pub dir: PathBuf,
}

impl XlaRuntime {
    /// Validate the artifact directory, then report the missing backend.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json", dir.display()))?;
        let _meta = ArtifactMeta::parse(&meta_text)?;
        bail!(
            "artifacts at {} look valid, but intdecomp was built without \
             the `xla` feature (the PJRT bindings are not vendored); \
             rebuild with `--features xla` or use the native math path",
            dir.display()
        )
    }

    /// The stub never loads artifacts; callers fall back to native math.
    /// If artifacts *are* present on disk, say why they're being ignored
    /// (the real runtime warns on unusable artifacts too).
    pub fn load_default() -> Option<Self> {
        for dir in ["artifacts", "../artifacts"] {
            if Path::new(dir).join("meta.json").exists() {
                eprintln!(
                    "warn: artifacts at {dir} ignored: built without the \
                     `xla` feature — using the native math path"
                );
                break;
            }
        }
        None
    }

    /// PJRT platform description (stub: always unavailable).
    pub fn platform(&self) -> String {
        "unavailable (built without the `xla` feature)".into()
    }

    /// Batched cost evaluation (stub: always errors).
    pub fn cost_batch(
        &self,
        _w: &Matrix,
        _ms: &[BinMatrix],
    ) -> Result<Vec<f64>> {
        bail!("built without the `xla` feature")
    }

    /// Gram-moment computation (stub: always errors).
    pub fn gram(
        &self,
        _phi: &Matrix,
        _y: &[f64],
    ) -> Result<(Matrix, Vec<f64>, f64)> {
        bail!("built without the `xla` feature")
    }

    /// BOCS posterior draw (stub: always errors).
    pub fn bocs_draw(
        &self,
        _g: &Matrix,
        _gv: &[f64],
        _lam: &[f64],
        _sigma_n2: f64,
        _z: &[f64],
    ) -> Result<(Vec<f64>, f64)> {
        bail!("built without the `xla` feature")
    }

    /// One FM training epoch (stub: always errors).
    #[allow(clippy::too_many_arguments)]
    pub fn fm_epoch(
        &self,
        _k_fm: usize,
        _xs: &[Vec<i8>],
        _ys: &[f64],
        _w0: f64,
        _w: &[f64],
        _v: &Matrix,
        _lr: f64,
    ) -> Result<(f64, Vec<f64>, Matrix)> {
        bail!("built without the `xla` feature")
    }
}
