//! The real PJRT-backed runtime (requires the `xla` bindings; compiled
//! only with `--features xla`).  See `runtime/stub.rs` for the default
//! native stand-in.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::ArtifactMeta;
use crate::cost::BinMatrix;
use crate::linalg::Matrix;

struct Executables {
    client: xla::PjRtClient,
    cost: xla::PjRtLoadedExecutable,
    gram: xla::PjRtLoadedExecutable,
    bocs: xla::PjRtLoadedExecutable,
    fms: Vec<(usize, xla::PjRtLoadedExecutable)>,
}

/// Compiled-artifact runtime.
///
/// Safety note on `Send`/`Sync`: the underlying PJRT CPU client is
/// thread-safe for compilation and execution (it serialises through its own
/// task runtime); the raw pointers in the `xla` wrapper types are what stop
/// the auto-traits.  We additionally serialise all `execute` calls through
/// a `Mutex`, so exposing the wrapper across threads is sound.
pub struct XlaRuntime {
    exes: Mutex<Executables>,
    /// Shape contract parsed from `meta.json`.
    pub meta: ArtifactMeta,
    /// Artifact directory the runtime was loaded from.
    pub dir: PathBuf,
}

unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

fn load_exe(
    client: &xla::PjRtClient,
    dir: &Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(&path)
        .with_context(|| format!("loading {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {name}"))
}

fn f32s(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

impl XlaRuntime {
    /// Load and compile all artifacts from a directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json", dir.display()))?;
        let meta = ArtifactMeta::parse(&meta_text)?;
        let client = xla::PjRtClient::cpu()?;
        let cost = load_exe(&client, &dir, "cost_batch")?;
        let gram = load_exe(&client, &dir, "gram")?;
        let bocs = load_exe(&client, &dir, "bocs_sample")?;
        let mut fms = Vec::new();
        for &kfm in &meta.kfms {
            fms.push((kfm, load_exe(&client, &dir, &format!("fm_epoch_k{kfm}"))?));
        }
        Ok(XlaRuntime {
            exes: Mutex::new(Executables { client, cost, gram, bocs, fms }),
            meta,
            dir,
        })
    }

    /// Try the conventional location, else None (native fallback).
    pub fn load_default() -> Option<Self> {
        for dir in ["artifacts", "../artifacts"] {
            if Path::new(dir).join("meta.json").exists() {
                match Self::load(dir) {
                    Ok(rt) => return Some(rt),
                    Err(e) => {
                        eprintln!("warn: artifacts at {dir} unusable: {e:#}");
                        return None;
                    }
                }
            }
        }
        None
    }

    /// PJRT platform description of the loaded client.
    pub fn platform(&self) -> String {
        self.exes.lock().unwrap().client.platform_name()
    }

    /// Batched cost evaluation through the Pallas cost kernel.  Any number
    /// of candidates; internally padded to multiples of `meta.batch`.
    pub fn cost_batch(
        &self,
        w: &Matrix,
        ms: &[BinMatrix],
    ) -> Result<Vec<f64>> {
        let meta = &self.meta;
        if w.rows != meta.n || w.cols != meta.d {
            bail!(
                "artifact compiled for W {}x{}, got {}x{}",
                meta.n, meta.d, w.rows, w.cols
            );
        }
        let w_lit = literal_2d(&f32s(&w.data), w.rows, w.cols)?;
        let b = meta.batch;
        let mut out = Vec::with_capacity(ms.len());
        let exes = self.exes.lock().unwrap();
        for chunk in ms.chunks(b) {
            let mut data = vec![1.0f32; b * meta.n * meta.k];
            for (bi, m) in chunk.iter().enumerate() {
                assert_eq!(m.n, meta.n);
                assert_eq!(m.k, meta.k);
                // Artifact layout is (B, N, K) row-major; BinMatrix is
                // column-major.
                for i in 0..meta.n {
                    for j in 0..meta.k {
                        data[bi * meta.n * meta.k + i * meta.k + j] =
                            m.get(i, j) as f32;
                    }
                }
            }
            let m_lit = xla::Literal::vec1(&data).reshape(&[
                b as i64,
                meta.n as i64,
                meta.k as i64,
            ])?;
            let result = exes.cost.execute::<xla::Literal>(&[
                w_lit.clone(),
                m_lit,
            ])?[0][0]
                .to_literal_sync()?;
            let costs = result.to_tuple1()?.to_vec::<f32>()?;
            out.extend(
                costs[..chunk.len()].iter().map(|&c| c as f64),
            );
        }
        Ok(out)
    }

    /// Gram moments (Φ^T Φ, Φ^T y, y^T y) through the Pallas Gram kernel.
    /// Rows beyond `phi.rows` are zero-padded (inert).
    pub fn gram(&self, phi: &Matrix, y: &[f64]) -> Result<(Matrix, Vec<f64>, f64)> {
        let meta = &self.meta;
        if phi.cols != meta.p {
            bail!("artifact P={} vs phi cols {}", meta.p, phi.cols);
        }
        if phi.rows > meta.nmax {
            bail!("dataset rows {} exceed artifact nmax {}", phi.rows, meta.nmax);
        }
        let mut phi_pad = vec![0.0f32; meta.nmax * meta.p];
        for r in 0..phi.rows {
            for c in 0..meta.p {
                phi_pad[r * meta.p + c] = phi[(r, c)] as f32;
            }
        }
        let mut y_pad = vec![0.0f32; meta.nmax];
        for (dst, &v) in y_pad.iter_mut().zip(y) {
            *dst = v as f32;
        }
        let phi_lit = literal_2d(&phi_pad, meta.nmax, meta.p)?;
        let y_lit = literal_2d(&y_pad, meta.nmax, 1)?;
        let exes = self.exes.lock().unwrap();
        let result = exes.gram.execute::<xla::Literal>(&[phi_lit, y_lit])?
            [0][0]
            .to_literal_sync()?;
        let (g_l, gv_l, yy_l) = result.to_tuple3()?;
        let g_v: Vec<f32> = g_l.to_vec()?;
        let gv_v: Vec<f32> = gv_l.to_vec()?;
        let yy_v: Vec<f32> = yy_l.to_vec()?;
        let g = Matrix::from_vec(
            meta.p,
            meta.p,
            g_v.into_iter().map(|x| x as f64).collect(),
        );
        let gv = gv_v.into_iter().map(|x| x as f64).collect();
        Ok((g, gv, yy_v[0] as f64))
    }

    /// One BOCS Thompson draw through the `bocs_sample` artifact.
    pub fn bocs_draw(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
    ) -> Result<(Vec<f64>, f64)> {
        let meta = &self.meta;
        if g.rows != meta.p {
            bail!("artifact P={} vs G dim {}", meta.p, g.rows);
        }
        let g_lit = literal_2d(&f32s(&g.data), meta.p, meta.p)?;
        let gv_lit = literal_2d(&f32s(gv), meta.p, 1)?;
        let lam_lit = xla::Literal::vec1(&f32s(lam));
        let s2_lit = xla::Literal::scalar(sigma_n2 as f32);
        let z_lit = xla::Literal::vec1(&f32s(z));
        let exes = self.exes.lock().unwrap();
        let result = exes.bocs.execute::<xla::Literal>(&[
            g_lit, gv_lit, lam_lit, s2_lit, z_lit,
        ])?[0][0]
            .to_literal_sync()?;
        let (alpha_l, hld_l) = result.to_tuple2()?;
        let alpha: Vec<f32> = alpha_l.to_vec()?;
        let hld: Vec<f32> = hld_l.to_vec()?;
        Ok((
            alpha.into_iter().map(|x| x as f64).collect(),
            hld[0] as f64,
        ))
    }

    /// FM training bundle (`fm_steps` Adam steps) through the artifact.
    #[allow(clippy::too_many_arguments)]
    pub fn fm_epoch(
        &self,
        k_fm: usize,
        xs: &[Vec<i8>],
        ys: &[f64],
        w0: f64,
        w: &[f64],
        v: &Matrix,
        lr: f64,
    ) -> Result<(f64, Vec<f64>, Matrix)> {
        let meta = &self.meta;
        if xs.len() > meta.nmax {
            bail!("dataset rows {} exceed artifact nmax {}", xs.len(), meta.nmax);
        }
        if w.len() != meta.nbits || v.rows != meta.nbits || v.cols != k_fm {
            bail!("fm shape mismatch");
        }
        let mut x_pad = vec![0.0f32; meta.nmax * meta.nbits];
        for (r, x) in xs.iter().enumerate() {
            for (c, &s) in x.iter().enumerate() {
                x_pad[r * meta.nbits + c] = s as f32;
            }
        }
        let mut y_pad = vec![0.0f32; meta.nmax];
        let mut mask = vec![0.0f32; meta.nmax];
        for (i, &yv) in ys.iter().enumerate() {
            y_pad[i] = yv as f32;
            mask[i] = 1.0;
        }
        let exes = self.exes.lock().unwrap();
        let exe = exes
            .fms
            .iter()
            .find(|(k, _)| *k == k_fm)
            .map(|(_, e)| e)
            .ok_or_else(|| anyhow!("no fm artifact for k_fm={k_fm}"))?;
        let result = exe.execute::<xla::Literal>(&[
            literal_2d(&x_pad, meta.nmax, meta.nbits)?,
            xla::Literal::vec1(&y_pad),
            xla::Literal::vec1(&mask),
            xla::Literal::vec1(&[w0 as f32]),
            xla::Literal::vec1(&f32s(w)),
            literal_2d(&f32s(&v.data), meta.nbits, k_fm)?,
            xla::Literal::vec1(&[lr as f32]),
        ])?[0][0]
            .to_literal_sync()?;
        let (w0_l, w_l, v_l) = result.to_tuple3()?;
        let w0_v: Vec<f32> = w0_l.to_vec()?;
        let w_v: Vec<f32> = w_l.to_vec()?;
        let v_v: Vec<f32> = v_l.to_vec()?;
        Ok((
            w0_v[0] as f64,
            w_v.into_iter().map(|x| x as f64).collect(),
            Matrix::from_vec(
                meta.nbits,
                k_fm,
                v_v.into_iter().map(|x| x as f64).collect(),
            ),
        ))
    }
}
