//! Surrogate models for the BBO loop (paper "BBO algorithms" section).
//!
//! Both families approximate the black-box cost with a quadratic
//! pseudo-Boolean function that an Ising solver can minimise:
//!
//! * [`blr::Blr`] — Bayesian linear regression over the quadratic feature
//!   map with three priors: horseshoe (vBOCS), normal (nBOCS) and
//!   normal-gamma (gBOCS).  A Thompson draw from the posterior becomes the
//!   QUBO to minimise.
//! * [`fm::FactorizationMachine`] — degree-2 FM surrogate (FMQA); its
//!   (w, ⟨v_i, v_j⟩) parameters *are* the QUBO.
//!
//! [`Dataset`] accumulates evaluations and maintains the Gram moments
//! (Φ^T Φ, Φ^T y, y^T y) incrementally — O(P^2) per push instead of an
//! O(rows · P^2) rebuild per iteration, which is what makes the 48×
//! data-augmentation variant (nBOCSa) tractable.
//!
//! The [`state`] module (ISSUE 10) serialises all of this — dataset
//! moments plus surrogate-specific parameters exported through
//! [`Surrogate::export_state`] — into the versioned
//! `intdecomp-surrogate-state-v1` document that warm-starts later runs.

pub mod blr;
pub mod features;
pub mod fm;
pub mod state;

pub use state::{
    StateError, SurrogateParams, SurrogateState, WarmStart, STATE_SCHEMA,
};

use crate::linalg::{Matrix, NumericError};
use crate::solvers::QuadModel;
use crate::util::rng::Rng;

/// Growing dataset of (spin vector, cost) pairs with incremental moments.
///
/// ```
/// use intdecomp::surrogate::Dataset;
///
/// let mut data = Dataset::new(3);
/// data.push(vec![1, -1, 1], 2.0);
/// data.push_batch(vec![(vec![1, 1, 1], 0.5), (vec![-1, 1, -1], 1.0)]);
/// assert_eq!(data.len(), 3);
/// let (best_x, best_y) = data.best().unwrap();
/// assert_eq!((best_x, best_y), (&[1i8, 1, 1][..], 0.5));
/// ```
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Spin-vector length n (the problem's bit count).
    pub n_bits: usize,
    /// Feature dimension P = 1 + n + n(n-1)/2.
    pub p: usize,
    /// Evaluated spin vectors, in insertion order.
    pub xs: Vec<Vec<i8>>,
    /// Observed costs, aligned with `xs`.
    pub ys: Vec<f64>,
    /// Φ^T Φ, maintained incrementally.
    pub g: Matrix,
    /// Φ^T y.
    pub gv: Vec<f64>,
    /// y^T y.
    pub yty: f64,
    /// Running argmin of `ys`, maintained by `push`/`push_batch` so
    /// [`Dataset::best`] is O(1) (the BBO loop calls it every
    /// iteration).  Mutating `xs`/`ys` directly bypasses the tracking.
    best_idx: Option<usize>,
    /// Running minimum of `ys` (`f64::INFINITY` while empty).
    best_y: f64,
    /// Reusable Φ-panel scratch for `push_batch` (capacity retained
    /// across batches so steady-state ingestion allocates nothing).
    panel: Vec<f64>,
}

impl Dataset {
    /// Empty dataset over `n_bits`-spin vectors.
    pub fn new(n_bits: usize) -> Self {
        let p = features::n_features(n_bits);
        Dataset {
            n_bits,
            p,
            xs: Vec::new(),
            ys: Vec::new(),
            g: Matrix::zeros(p, p),
            gv: vec![0.0; p],
            yty: 0.0,
            best_idx: None,
            best_y: f64::INFINITY,
            panel: Vec::new(),
        }
    }

    /// Number of evaluations stored.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when no evaluation has been stored yet.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Record the (x, y) trace entry and keep the running argmin in
    /// sync (the strictly-lower rule keeps the earliest minimiser, the
    /// same winner the old full rescan produced).
    fn record(&mut self, x: Vec<i8>, y: f64) {
        if y < self.best_y {
            self.best_y = y;
            self.best_idx = Some(self.xs.len());
        }
        self.xs.push(x);
        self.ys.push(y);
    }

    /// Append one evaluation; rank-1 update of the moments.
    pub fn push(&mut self, x: Vec<i8>, y: f64) {
        debug_assert_eq!(x.len(), self.n_bits);
        let phi = features::phi(&x);
        for i in 0..self.p {
            let pi = phi[i];
            if pi == 0.0 {
                continue;
            }
            let row = self.g.row_mut(i);
            for (j, &pj) in phi.iter().enumerate() {
                row[j] += pi * pj;
            }
            self.gv[i] += pi * y;
        }
        self.yty += y * y;
        self.record(x, y);
    }

    /// Ingest a whole acquisition batch in one rank-k update: the
    /// batch's Φ panel is built once, G absorbs it in a single
    /// syrk-style streaming pass (one traversal of the P×P moment
    /// matrix instead of one per pair, row panels fanned across the
    /// worker pool at paper scale), and Φᵀy / yᵀy are accumulated in
    /// pair order.  This is the single-ingestion point the batched BBO
    /// loop uses after evaluating all `batch_size` candidates of an
    /// iteration.
    ///
    /// Bit-identity with sequential [`Dataset::push`] is preserved: the
    /// feature map is ±1-valued, so every G entry is a sum of exact
    /// f64 integers (order-independent), and the Φᵀy / yᵀy updates run
    /// in the exact per-pair order `push` uses.
    pub fn push_batch(
        &mut self,
        pairs: impl IntoIterator<Item = (Vec<i8>, f64)>,
    ) {
        let pairs: Vec<(Vec<i8>, f64)> = pairs.into_iter().collect();
        let kb = pairs.len();
        if kb <= 1 {
            for (x, y) in pairs {
                self.push(x, y);
            }
            return;
        }
        let p = self.p;
        // Reuse the scratch panel across batches (taken out of `self`
        // so the moment updates below can still borrow fields mutably).
        let mut panel = std::mem::take(&mut self.panel);
        panel.clear();
        panel.resize(kb * p, 0.0);
        for (r, (x, _)) in pairs.iter().enumerate() {
            debug_assert_eq!(x.len(), self.n_bits);
            features::phi_into(x, &mut panel[r * p..(r + 1) * p]);
        }
        let parallel = crate::linalg::parallel_worthwhile(
            kb.saturating_mul(p).saturating_mul(p),
        );
        crate::linalg::for_each_row_panel(
            &mut self.g.data,
            p,
            parallel,
            |i0, grows| {
                for (li, grow) in grows.chunks_mut(p).enumerate() {
                    let i = i0 + li;
                    for r in 0..kb {
                        let prow = &panel[r * p..(r + 1) * p];
                        let pi = prow[i];
                        for (gj, &pj) in grow.iter_mut().zip(prow) {
                            *gj += pi * pj;
                        }
                    }
                }
            },
        );
        for (r, (x, y)) in pairs.into_iter().enumerate() {
            let prow = &panel[r * p..(r + 1) * p];
            for (gvi, &pi) in self.gv.iter_mut().zip(prow) {
                *gvi += pi * y;
            }
            self.yty += y * y;
            self.record(x, y);
        }
        self.panel = panel;
    }

    /// Best (lowest) observed cost and its argmin — O(1), served from
    /// the running minimum maintained by `push`/`push_batch`.  Mutating
    /// `xs`/`ys` directly (rather than through the push methods) leaves
    /// the tracked minimum stale; a truncated `xs` yields `None` rather
    /// than panicking.
    pub fn best(&self) -> Option<(&[i8], f64)> {
        self.best_idx
            .and_then(|i| self.xs.get(i))
            .map(|x| (x.as_slice(), self.best_y))
    }

    /// Dense feature matrix Φ (rows × P) — the XLA gram-artifact path and
    /// tests rebuild it on demand.  Rows are written in place with
    /// [`features::phi_into`] (one allocation for the matrix, no
    /// per-row temporaries), bit-identical to the incremental path's
    /// panel rows.
    pub fn phi_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(self.len(), self.p);
        for (i, x) in self.xs.iter().enumerate() {
            features::phi_into(x, m.row_mut(i));
        }
        m
    }
}

/// Common interface: fit on the data seen so far, emit a QUBO to minimise.
pub trait Surrogate: Send {
    /// Fit the surrogate on `data` and return the quadratic model the
    /// Ising solver should minimise (a Thompson draw for BLR, the FM
    /// parameters themselves for FMQA).
    ///
    /// Fallible (ISSUE 9): a non-SPD posterior or diverged FM surfaces
    /// as a typed [`NumericError`]; the BBO loop degrades to a random
    /// acquisition for that iteration instead of aborting the run.
    fn fit_model(
        &mut self,
        data: &Dataset,
        rng: &mut Rng,
    ) -> Result<QuadModel, NumericError>;

    /// Short identifier for reports (e.g. "nBOCS", "FMQA08").
    fn name(&self) -> String;

    /// Export the surrogate's cross-iteration parameters for the
    /// versioned state subsystem ([`state::SurrogateState`], ISSUE 10).
    ///
    /// The default is a `"stateless"` payload for surrogates that carry
    /// nothing between fits; BLR exports its noise variance and Gibbs
    /// chain, FM exports its learned parameters and Adam moments.
    fn export_state(&self) -> SurrogateParams {
        SurrogateParams {
            kind: "stateless".into(),
            params: crate::util::json::Json::Null,
        }
    }

    /// Re-import parameters produced by [`Surrogate::export_state`] on
    /// a compatible instance.  Strict: a payload from a different
    /// surrogate kind, or with shapes that do not match this instance,
    /// is a typed [`StateError`] — never silently ignored.
    fn import_state(
        &mut self,
        params: &SurrogateParams,
    ) -> Result<(), StateError> {
        if params.kind == "stateless" {
            Ok(())
        } else {
            Err(StateError::KindMismatch {
                expected: "stateless".into(),
                found: params.kind.clone(),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incremental_moments_match_dense_rebuild() {
        let mut rng = Rng::new(400);
        let n = 6;
        let mut data = Dataset::new(n);
        for _ in 0..20 {
            data.push(rng.spins(n), rng.normal());
        }
        let phi = data.phi_matrix();
        let g = phi.gram();
        for (a, b) in g.data.iter().zip(&data.g.data) {
            assert!((a - b).abs() < 1e-9);
        }
        let gv = phi.tmatvec(&data.ys);
        for (a, b) in gv.iter().zip(&data.gv) {
            assert!((a - b).abs() < 1e-9);
        }
        let yty: f64 = data.ys.iter().map(|y| y * y).sum();
        assert!((yty - data.yty).abs() < 1e-9);
    }

    #[test]
    fn push_batch_is_bit_identical_to_sequential_push() {
        let mut rng = Rng::new(401);
        let n = 5;
        let mut seq = Dataset::new(n);
        let mut bat = Dataset::new(n);
        for kb in [2usize, 3, 8] {
            let pairs: Vec<(Vec<i8>, f64)> =
                (0..kb).map(|_| (rng.spins(n), rng.normal())).collect();
            for (x, y) in pairs.clone() {
                seq.push(x, y);
            }
            bat.push_batch(pairs);
            for (a, b) in seq.g.data.iter().zip(&bat.g.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in seq.gv.iter().zip(&bat.gv) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(seq.yty.to_bits(), bat.yty.to_bits());
            assert_eq!(seq.best(), bat.best());
        }
    }

    #[test]
    fn best_tracks_minimum() {
        let mut data = Dataset::new(2);
        data.push(vec![1, 1], 3.0);
        data.push(vec![1, -1], 1.0);
        data.push(vec![-1, 1], 2.0);
        let (x, y) = data.best().unwrap();
        assert_eq!(x, &[1, -1]);
        assert_eq!(y, 1.0);
    }

    #[test]
    fn empty_dataset() {
        let data = Dataset::new(4);
        assert!(data.is_empty());
        assert!(data.best().is_none());
    }

    #[test]
    fn phi_matrix_rows_are_bit_identical_to_phi() {
        let mut rng = Rng::new(402);
        let n = 5;
        let mut data = Dataset::new(n);
        for _ in 0..7 {
            data.push(rng.spins(n), rng.normal());
        }
        let m = data.phi_matrix();
        assert_eq!((m.rows, m.cols), (7, data.p));
        for (i, x) in data.xs.iter().enumerate() {
            let reference = features::phi(x);
            for (a, b) in m.row(i).iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
