//! Degree-2 factorisation machine surrogate (FMQA; Rendle 2010, Kitai et
//! al. 2020).
//!
//! ```text
//!   ŷ(x) = w0 + Σ_i w_i x_i + Σ_{i<j} ⟨v_i, v_j⟩ x_i x_j
//! ```
//!
//! The rank-k_FM factorisation of the pair matrix is what makes FMQA
//! sparse/low-rank (the paper tests k_FM = 8 and 12).  Unlike BOCS the fit
//! is a point estimate (full-batch Adam on squared error), so the
//! surrogate→solver→evaluate loop is deterministic given the data — the
//! trap-in-local-minimum behaviour the paper reports falls out of this.
//!
//! Training has two interchangeable engines: native Rust Adam (this file)
//! and the AOT `fm_epoch` artifact via PJRT (`runtime::XlaFmTrainer`),
//! cross-checked in integration tests.

use super::{state, Dataset, Surrogate};
use crate::linalg::{Matrix, NumericError};
use crate::solvers::QuadModel;
use crate::util::json::Json;
use crate::util::rng::Rng;

const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-8;
const L2_REG: f64 = 1e-6;

/// External training engine hook (the PJRT artifact path).
pub trait FmTrainer: Send {
    /// Run a training epoch bundle on (xs, ys), updating the parameters.
    ///
    /// Fallible (ISSUE 9): a trainer that drives the parameters to
    /// non-finite values reports [`NumericError::SurrogateDiverged`]
    /// rather than leaving a poisoned model behind.
    fn train_epoch(
        &self,
        xs: &[Vec<i8>],
        ys: &[f64],
        w0: &mut f64,
        w: &mut [f64],
        v: &mut Matrix,
        lr: f64,
    ) -> Result<(), NumericError>;

    /// Short identifier for reports ("native" / "xla").
    fn trainer_name(&self) -> &'static str;
}

/// Factorisation-machine surrogate with warm-started parameters.
pub struct FactorizationMachine {
    /// Number of binary variables.
    pub n: usize,
    /// Latent factor count (the paper tests 8 and 12).
    pub k_fm: usize,
    /// Bias term.
    pub w0: f64,
    /// Linear weights.
    pub w: Vec<f64>,
    /// Latent factors, n × k_fm.
    pub v: Matrix,
    /// Adam steps per fit call.
    pub steps: usize,
    /// Adam learning rate.
    pub lr: f64,
    trainer: Option<Box<dyn FmTrainer>>,
    adam_t: usize,
    m_w0: f64,
    v_w0: f64,
    m_w: Vec<f64>,
    v_w: Vec<f64>,
    m_v: Matrix,
    v_v: Matrix,
}

impl FactorizationMachine {
    /// Fresh FM with small random latent factors.
    pub fn new(n: usize, k_fm: usize, rng: &mut Rng) -> Self {
        let v = Matrix::from_vec(
            n,
            k_fm,
            rng.normals(n * k_fm).iter().map(|z| 0.01 * z).collect(),
        );
        FactorizationMachine {
            n,
            k_fm,
            w0: 0.0,
            w: vec![0.0; n],
            v: v.clone(),
            steps: 200,
            lr: 0.05,
            trainer: None,
            adam_t: 0,
            m_w0: 0.0,
            v_w0: 0.0,
            m_w: vec![0.0; n],
            v_w: vec![0.0; n],
            m_v: Matrix::zeros(n, k_fm),
            v_v: Matrix::zeros(n, k_fm),
        }
    }

    /// Route training through an external engine (PJRT artifact).
    pub fn with_trainer(mut self, trainer: Box<dyn FmTrainer>) -> Self {
        self.trainer = Some(trainer);
        self
    }

    /// FM forward pass for one spin vector.
    pub fn predict(&self, x: &[i8]) -> f64 {
        let mut y = self.w0;
        for (wi, &xi) in self.w.iter().zip(x) {
            y += wi * xi as f64;
        }
        // Σ_{i<j} ⟨v_i,v_j⟩ x_i x_j = ½ Σ_l [(Σ_i v_il x_i)² - Σ_i v_il²].
        for l in 0..self.k_fm {
            let mut s = 0.0;
            let mut s2 = 0.0;
            for i in 0..self.n {
                let t = self.v[(i, l)] * x[i] as f64;
                s += t;
                s2 += t * t;
            }
            y += 0.5 * (s * s - s2);
        }
        y
    }

    /// One full-batch Adam step on MSE; returns the pre-step loss.
    fn adam_step(&mut self, xs: &[Vec<i8>], ys: &[f64]) -> f64 {
        let rows = xs.len();
        let inv_rows = 1.0 / rows.max(1) as f64;
        let mut g_w0 = 0.0;
        let mut g_w = vec![0.0; self.n];
        let mut g_v = Matrix::zeros(self.n, self.k_fm);
        let mut loss = 0.0;

        // Cache per-row XV sums s_l = Σ_i v_il x_i and reuse them for the
        // prediction (recomputing via predict() doubled the work —
        // EXPERIMENTS.md §Perf).
        let mut s = vec![0.0; self.k_fm];
        for (x, &y) in xs.iter().zip(ys) {
            s.iter_mut().for_each(|v| *v = 0.0);
            let mut s2_sum = 0.0;
            let mut pred = self.w0;
            for i in 0..self.n {
                let xi = x[i] as f64;
                pred += self.w[i] * xi;
                let vrow = &self.v.data[i * self.k_fm..(i + 1) * self.k_fm];
                for (l, &vil) in vrow.iter().enumerate() {
                    let t = vil * xi;
                    s[l] += t;
                    s2_sum += t * t;
                }
            }
            for &sl in s.iter() {
                pred += 0.5 * sl * sl;
            }
            pred -= 0.5 * s2_sum;
            let err = pred - y;
            loss += err * err * inv_rows;
            let e2 = 2.0 * err * inv_rows;
            g_w0 += e2;
            for i in 0..self.n {
                let xi = x[i] as f64;
                g_w[i] += e2 * xi;
                let vrow = &self.v.data[i * self.k_fm..(i + 1) * self.k_fm];
                let grow =
                    &mut g_v.data[i * self.k_fm..(i + 1) * self.k_fm];
                for (l, (&vil, g)) in
                    vrow.iter().zip(grow.iter_mut()).enumerate()
                {
                    // d/dv_il of ½(s_l² - Σ t²) = x_i s_l - v_il x_i².
                    *g += e2 * (xi * s[l] - vil);
                }
            }
        }
        // L2.
        for i in 0..self.n {
            g_w[i] += 2.0 * L2_REG * self.w[i];
            for l in 0..self.k_fm {
                g_v[(i, l)] += 2.0 * L2_REG * self.v[(i, l)];
            }
        }

        // Adam update.
        self.adam_t += 1;
        let t = self.adam_t as f64;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        let lr = self.lr;
        let upd = |p: &mut f64, m: &mut f64, v: &mut f64, g: f64| {
            *m = ADAM_B1 * *m + (1.0 - ADAM_B1) * g;
            *v = ADAM_B2 * *v + (1.0 - ADAM_B2) * g * g;
            *p -= lr * (*m / bc1) / ((*v / bc2).sqrt() + ADAM_EPS);
        };
        upd(&mut self.w0, &mut self.m_w0, &mut self.v_w0, g_w0);
        for i in 0..self.n {
            upd(&mut self.w[i], &mut self.m_w[i], &mut self.v_w[i], g_w[i]);
            for l in 0..self.k_fm {
                let g = g_v[(i, l)];
                let (mut p, mut m, mut v) =
                    (self.v[(i, l)], self.m_v[(i, l)], self.v_v[(i, l)]);
                upd(&mut p, &mut m, &mut v, g);
                self.v[(i, l)] = p;
                self.m_v[(i, l)] = m;
                self.v_v[(i, l)] = v;
            }
        }
        loss
    }

    /// True when every FM parameter is a finite number.
    fn params_finite(&self) -> bool {
        self.w0.is_finite()
            && self.w.iter().all(|v| v.is_finite())
            && self.v.data.iter().all(|v| v.is_finite())
    }

    /// Fit on the dataset (warm start from the previous parameters).
    ///
    /// Fallible (ISSUE 9): if training drives any parameter to a
    /// non-finite value — possible with pathological targets or an
    /// exploding external trainer — this returns
    /// [`NumericError::SurrogateDiverged`] instead of handing the BBO
    /// loop a poisoned model.
    pub fn train(
        &mut self,
        xs: &[Vec<i8>],
        ys: &[f64],
    ) -> Result<f64, NumericError> {
        let diverged =
            || NumericError::SurrogateDiverged { surrogate: "fm" };
        if let Some(trainer) = self.trainer.take() {
            let trained = trainer.train_epoch(
                xs,
                ys,
                &mut self.w0,
                &mut self.w,
                &mut self.v,
                self.lr,
            );
            self.trainer = Some(trainer);
            trained?;
            if !self.params_finite() {
                return Err(diverged());
            }
            let rows = xs.len().max(1) as f64;
            return Ok(xs
                .iter()
                .zip(ys)
                .map(|(x, &y)| {
                    let e = self.predict(x) - y;
                    e * e
                })
                .sum::<f64>()
                / rows);
        }
        let mut loss = f64::INFINITY;
        for _ in 0..self.steps {
            loss = self.adam_step(xs, ys);
        }
        if !self.params_finite() {
            return Err(diverged());
        }
        Ok(loss)
    }

    /// The FM parameters read off as a QUBO (paper: the surrogate is
    /// already quadratic, so no Thompson step is needed).
    pub fn to_quad(&self) -> QuadModel {
        let mut model = QuadModel::new(self.n);
        model.c = self.w0;
        model.h.copy_from_slice(&self.w);
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                let mut dotv = 0.0;
                for l in 0..self.k_fm {
                    dotv += self.v[(i, l)] * self.v[(j, l)];
                }
                model.set_pair(i, j, dotv);
            }
        }
        model
    }
}

impl Surrogate for FactorizationMachine {
    fn fit_model(
        &mut self,
        data: &Dataset,
        _rng: &mut Rng,
    ) -> Result<QuadModel, NumericError> {
        self.train(&data.xs, &data.ys)?;
        Ok(self.to_quad())
    }

    fn name(&self) -> String {
        let engine = self
            .trainer
            .as_ref()
            .map(|t| t.trainer_name())
            .unwrap_or("native");
        format!("FMQA{:02}[{}]", self.k_fm, engine)
    }

    /// Export the learned FM parameters (w0, w, V) together with the
    /// full Adam optimiser state, so an import resumes training exactly
    /// where the donor run stopped.
    fn export_state(&self) -> state::SurrogateParams {
        state::SurrogateParams {
            kind: format!("fm-k{}", self.k_fm),
            params: Json::obj(vec![
                (
                    "adam",
                    Json::obj(vec![
                        ("m_v", Json::arr_f64(&self.m_v.data)),
                        ("m_w", Json::arr_f64(&self.m_w)),
                        ("m_w0", Json::Num(self.m_w0)),
                        ("t", Json::Num(self.adam_t as f64)),
                        ("v_v", Json::arr_f64(&self.v_v.data)),
                        ("v_w", Json::arr_f64(&self.v_w)),
                        ("v_w0", Json::Num(self.v_w0)),
                    ]),
                ),
                ("k_fm", Json::Num(self.k_fm as f64)),
                ("n", Json::Num(self.n as f64)),
                ("v", Json::arr_f64(&self.v.data)),
                ("w", Json::arr_f64(&self.w)),
                ("w0", Json::Num(self.w0)),
            ]),
        }
    }

    /// Import a [`Surrogate::export_state`] payload.  The kind and the
    /// recorded (n, k_fm) shape must match this instance exactly; every
    /// array length and number is validated before anything is applied,
    /// so a failed import leaves the FM untouched.
    fn import_state(
        &mut self,
        params: &state::SurrogateParams,
    ) -> Result<(), state::StateError> {
        let expected = format!("fm-k{}", self.k_fm);
        if params.kind != expected {
            return Err(state::StateError::KindMismatch {
                expected,
                found: params.kind.clone(),
            });
        }
        let doc = &params.params;
        let n = state::get_usize(doc, "n")?;
        let k_fm = state::get_usize(doc, "k_fm")?;
        if n != self.n || k_fm != self.k_fm {
            return Err(state::StateError::Malformed {
                field: "n",
                detail: format!(
                    "state shape n={n}, k_fm={k_fm} does not match \
                     instance n={}, k_fm={}",
                    self.n, self.k_fm
                ),
            });
        }
        let w0 = state::get_finite(doc, "w0")?;
        let w = state::get_f64_vec(doc, "w", n)?;
        let v = state::get_f64_vec(doc, "v", n * k_fm)?;
        let adam = state::get(doc, "adam")?;
        let adam_t = state::get_usize(adam, "t")?;
        let m_w0 = state::get_finite(adam, "m_w0")?;
        let v_w0 = state::get_finite(adam, "v_w0")?;
        let m_w = state::get_f64_vec(adam, "m_w", n)?;
        let v_w = state::get_f64_vec(adam, "v_w", n)?;
        let m_v = state::get_f64_vec(adam, "m_v", n * k_fm)?;
        let v_v = state::get_f64_vec(adam, "v_v", n * k_fm)?;
        self.w0 = w0;
        self.w = w;
        self.v = Matrix::from_vec(n, k_fm, v);
        self.adam_t = adam_t;
        self.m_w0 = m_w0;
        self.v_w0 = v_w0;
        self.m_w = m_w;
        self.v_w = v_w;
        self.m_v = Matrix::from_vec(n, k_fm, m_v);
        self.v_v = Matrix::from_vec(n, k_fm, v_v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::features::{n_features, phi};

    #[test]
    fn predict_matches_pairwise_sum() {
        let mut rng = Rng::new(600);
        let fm = {
            let mut f = FactorizationMachine::new(6, 3, &mut rng);
            f.w0 = rng.normal();
            f.w = rng.normals(6);
            f.v = Matrix::from_vec(6, 3, rng.normals(18));
            f
        };
        for _ in 0..20 {
            let x = rng.spins(6);
            let mut want = fm.w0;
            for i in 0..6 {
                want += fm.w[i] * x[i] as f64;
                for j in (i + 1)..6 {
                    let mut d = 0.0;
                    for l in 0..3 {
                        d += fm.v[(i, l)] * fm.v[(j, l)];
                    }
                    want += d * (x[i] as f64) * (x[j] as f64);
                }
            }
            assert!((fm.predict(&x) - want).abs() < 1e-9);
        }
    }

    #[test]
    fn to_quad_agrees_with_predict() {
        let mut rng = Rng::new(601);
        let mut fm = FactorizationMachine::new(5, 4, &mut rng);
        fm.w0 = 0.3;
        fm.w = rng.normals(5);
        fm.v = Matrix::from_vec(5, 4, rng.normals(20));
        let q = fm.to_quad();
        for _ in 0..20 {
            let x = rng.spins(5);
            assert!((q.energy(&x) - fm.predict(&x)).abs() < 1e-9);
        }
    }

    #[test]
    fn training_fits_planted_quadratic() {
        // Data from a random quadratic (full rank in pair space is not
        // required — k_fm=6 on n=6 gives enough freedom).
        let mut rng = Rng::new(602);
        let n = 6;
        let alpha: Vec<f64> = rng.normals(n_features(n));
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for bits in 0..(1u32 << n) {
            let x: Vec<i8> = (0..n)
                .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
                .collect();
            let y: f64 =
                alpha.iter().zip(phi(&x)).map(|(a, p)| a * p).sum();
            xs.push(x);
            ys.push(y);
        }
        let mut fm = FactorizationMachine::new(n, 6, &mut rng);
        fm.steps = 1500;
        fm.lr = 0.05;
        let loss = fm.train(&xs, &ys).unwrap();
        let var = {
            let mean: f64 = ys.iter().sum::<f64>() / ys.len() as f64;
            ys.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>()
                / ys.len() as f64
        };
        assert!(loss < 0.05 * var, "loss {loss} vs var {var}");
    }

    #[test]
    fn warm_start_improves_over_calls() {
        let mut rng = Rng::new(603);
        let n = 5;
        let alpha: Vec<f64> = rng.normals(n_features(n));
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..40 {
            let x = rng.spins(n);
            let y: f64 =
                alpha.iter().zip(phi(&x)).map(|(a, p)| a * p).sum();
            xs.push(x);
            ys.push(y);
        }
        let mut fm = FactorizationMachine::new(n, 5, &mut rng);
        fm.steps = 50;
        let l1 = fm.train(&xs, &ys).unwrap();
        let mut l5 = l1;
        for _ in 0..6 {
            l5 = fm.train(&xs, &ys).unwrap();
        }
        assert!(l5 < l1, "warm start should keep improving: {l5} vs {l1}");
    }

    #[test]
    fn surrogate_interface() {
        let mut rng = Rng::new(604);
        let mut data = Dataset::new(4);
        for _ in 0..10 {
            data.push(rng.spins(4), rng.normal());
        }
        let mut fm = FactorizationMachine::new(4, 3, &mut rng);
        fm.steps = 20;
        let model = fm.fit_model(&data, &mut rng).unwrap();
        assert_eq!(model.n, 4);
        assert!(fm.name().starts_with("FMQA03"));
    }

    #[test]
    fn non_finite_targets_surface_as_diverged() {
        // NaN targets poison the Adam moments; train() must report a
        // typed divergence instead of returning a poisoned model.
        let mut rng = Rng::new(605);
        let xs: Vec<Vec<i8>> = (0..8).map(|_| rng.spins(4)).collect();
        let ys = vec![f64::NAN; 8];
        let mut fm = FactorizationMachine::new(4, 3, &mut rng);
        fm.steps = 5;
        assert_eq!(
            fm.train(&xs, &ys),
            Err(NumericError::SurrogateDiverged { surrogate: "fm" })
        );
    }

    #[test]
    fn fitted_state_roundtrips_byte_identically() {
        let mut rng = Rng::new(606);
        let n = 5;
        let xs: Vec<Vec<i8>> = (0..30).map(|_| rng.spins(n)).collect();
        let ys: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let mut fm = FactorizationMachine::new(n, 3, &mut rng);
        fm.steps = 40;
        fm.train(&xs, &ys).unwrap();
        let text =
            fm.export_state().to_json().to_string_strict().unwrap();
        let mut fresh = FactorizationMachine::new(n, 3, &mut rng);
        fresh
            .import_state(
                &state::SurrogateParams::from_json(
                    &Json::parse(&text).unwrap(),
                )
                .unwrap(),
            )
            .unwrap();
        assert_eq!(
            fresh.export_state().to_json().to_string_strict().unwrap(),
            text
        );
        // The imported FM is the same model: identical predictions.
        for _ in 0..5 {
            let x = rng.spins(n);
            assert_eq!(fm.predict(&x).to_bits(), fresh.predict(&x).to_bits());
        }
    }

    #[test]
    fn import_rejects_shape_and_kind_mismatches() {
        let mut rng = Rng::new(607);
        let donor = FactorizationMachine::new(4, 3, &mut rng);
        let exported = donor.export_state();
        let mut wrong_k = FactorizationMachine::new(4, 5, &mut rng);
        assert!(matches!(
            wrong_k.import_state(&exported),
            Err(state::StateError::KindMismatch { .. })
        ));
        let mut wrong_n = FactorizationMachine::new(6, 3, &mut rng);
        assert!(matches!(
            wrong_n.import_state(&exported),
            Err(state::StateError::Malformed { .. })
        ));
    }
}
