//! Quadratic feature map shared with the python layer.
//!
//! Layout contract (must match `python/compile/model.py`):
//! `phi(x) = [1, x_1..x_n, x_1 x_2, x_1 x_3, .., x_{n-1} x_n]` — bias first,
//! then linear terms, then upper-triangular pair products in lexicographic
//! order.  P = 1 + n + n(n-1)/2 (the paper's `n + n(n-1)/2` explanatory
//! variables plus the intercept).

use crate::solvers::QuadModel;

/// Feature dimension for n binary variables.
pub fn n_features(n: usize) -> usize {
    1 + n + n * (n - 1) / 2
}

/// Index of the pair feature (i, j), i < j, within the pair block.
#[inline]
pub fn pair_index(n: usize, i: usize, j: usize) -> usize {
    debug_assert!(i < j && j < n);
    i * n - i * (i + 1) / 2 + (j - i - 1)
}

/// Feature vector of a spin configuration.
pub fn phi(x: &[i8]) -> Vec<f64> {
    let mut out = vec![0.0; n_features(x.len())];
    phi_into(x, &mut out);
    out
}

/// Write the feature vector of `x` into `out` (length must be
/// [`n_features`]`(x.len())`) — the allocation-free sibling of [`phi`],
/// used by the rank-k moment ingestion to fill a batch's Φ panel.
pub fn phi_into(x: &[i8], out: &mut [f64]) {
    let n = x.len();
    assert_eq!(out.len(), n_features(n));
    out[0] = 1.0;
    for (o, &xi) in out[1..1 + n].iter_mut().zip(x) {
        *o = xi as f64;
    }
    let mut idx = 1 + n;
    for i in 0..n {
        let xi = x[i] as f64;
        for &xj in &x[i + 1..] {
            out[idx] = xi * xj as f64;
            idx += 1;
        }
    }
}

/// Interpret a regression coefficient vector as a quadratic spin model:
/// `E(x) = alpha . phi(x)` — the object the Ising solver minimises.
pub fn alpha_to_quad(alpha: &[f64], n: usize) -> QuadModel {
    assert_eq!(alpha.len(), n_features(n));
    let mut m = QuadModel::new(n);
    m.c = alpha[0];
    m.h.copy_from_slice(&alpha[1..1 + n]);
    let pairs = &alpha[1 + n..];
    for i in 0..n {
        for j in (i + 1)..n {
            m.set_pair(i, j, pairs[pair_index(n, i, j)]);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dimensions() {
        assert_eq!(n_features(1), 2);
        assert_eq!(n_features(24), 301); // the paper's P at n = 24
    }

    #[test]
    fn pair_index_is_a_bijection() {
        let n = 7;
        let mut seen = vec![false; n * (n - 1) / 2];
        for i in 0..n {
            for j in (i + 1)..n {
                let idx = pair_index(n, i, j);
                assert!(!seen[idx], "collision at ({i},{j})");
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn phi_layout_hand_checked() {
        let x = [1i8, -1, 1];
        // [1, x1, x2, x3, x1x2, x1x3, x2x3]
        assert_eq!(
            phi(&x),
            vec![1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0]
        );
    }

    #[test]
    fn alpha_to_quad_roundtrips_energy() {
        // For any alpha: E(x) = alpha . phi(x).
        let mut rng = Rng::new(410);
        let n = 6;
        let alpha: Vec<f64> = rng.normals(n_features(n));
        let model = alpha_to_quad(&alpha, n);
        for _ in 0..30 {
            let x = rng.spins(n);
            let via_phi: f64 =
                alpha.iter().zip(phi(&x)).map(|(a, p)| a * p).sum();
            assert!((model.energy(&x) - via_phi).abs() < 1e-10);
        }
    }

    #[test]
    fn phi_entries_are_pm_one_after_bias() {
        let mut rng = Rng::new(411);
        let x = rng.spins(10);
        let f = phi(&x);
        assert_eq!(f[0], 1.0);
        for &v in &f[1..] {
            assert!(v == 1.0 || v == -1.0);
        }
    }
}
