//! Versioned surrogate-state serialisation (ISSUE 10).
//!
//! A BBO run's reusable state — the [`Dataset`] sufficient statistics
//! (G = ΦᵀΦ, Φᵀy, yᵀy) plus the surrogate's own cross-iteration
//! parameters — is exported as a schema-tagged JSON document
//! (`intdecomp-surrogate-state-v1`) and re-imported to warm-start a
//! later run on the same (or a slightly drifted) instance.
//!
//! Serialisation contract:
//!
//! * Documents are written through [`Json::to_string_strict`] — floats
//!   use shortest round-trip formatting, object keys are sorted, and a
//!   NaN/Inf anywhere in the tree is a typed error, never `null`.
//! * `export → import → export` is **byte-identical**: every number in
//!   the document round-trips bit-exactly (including `-0.0`), and the
//!   importer stores exactly what it read, so re-export reproduces the
//!   original bytes.  This is pinned by property tests.
//! * Import is strict: a missing/ill-typed field, a shape mismatch, an
//!   unknown schema tag or a non-finite number is a typed
//!   [`StateError`] — a torn or corrupt state file can never silently
//!   degrade into a cold start without the caller noticing.

use crate::linalg::Matrix;
use crate::surrogate::{features, Dataset};
use crate::util::json::{Json, NonFiniteJson};

/// Schema tag carried by every serialised surrogate-state document.
pub const STATE_SCHEMA: &str = "intdecomp-surrogate-state-v1";

/// Typed import/export errors of the surrogate-state subsystem.
///
/// Every way a state document can be unusable gets its own variant so
/// callers (engine, serve warm store, CLI) can distinguish "corrupt
/// file" from "state for a different problem" and report accordingly.
#[derive(Clone, Debug, PartialEq)]
pub enum StateError {
    /// The document's `schema` tag is missing or not [`STATE_SCHEMA`].
    BadSchema {
        /// The tag actually found ("" when absent).
        found: String,
    },
    /// A required field is absent.
    Missing {
        /// Dotted field name.
        field: &'static str,
    },
    /// A field is present but ill-typed, ill-shaped or non-finite.
    Malformed {
        /// Dotted field name ("" for document-level parse errors).
        field: &'static str,
        /// Human-readable description of what was wrong.
        detail: String,
    },
    /// The state was exported from a different problem size.
    BitsMismatch {
        /// `n_bits` the importing run expects.
        expected: usize,
        /// `n_bits` recorded in the document.
        found: usize,
    },
    /// The surrogate parameters were exported by a different surrogate
    /// kind (e.g. a vBOCS state offered to an FM surrogate).
    KindMismatch {
        /// Kind the importing surrogate expects.
        expected: String,
        /// Kind recorded in the document.
        found: String,
    },
    /// Export hit a non-finite number (bug upstream, surfaced typed).
    NonFinite(NonFiniteJson),
}

impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StateError::BadSchema { found } if found.is_empty() => {
                write!(f, "surrogate state: missing schema tag (want {STATE_SCHEMA})")
            }
            StateError::BadSchema { found } => {
                write!(f, "surrogate state: schema '{found}' (want {STATE_SCHEMA})")
            }
            StateError::Missing { field } => {
                write!(f, "surrogate state: missing field '{field}'")
            }
            StateError::Malformed { field, detail } if field.is_empty() => {
                write!(f, "surrogate state: {detail}")
            }
            StateError::Malformed { field, detail } => {
                write!(f, "surrogate state: field '{field}': {detail}")
            }
            StateError::BitsMismatch { expected, found } => write!(
                f,
                "surrogate state: exported for n_bits={found}, run expects n_bits={expected}"
            ),
            StateError::KindMismatch { expected, found } => write!(
                f,
                "surrogate state: exported by surrogate kind '{found}', \
                 importer expects '{expected}'"
            ),
            StateError::NonFinite(e) => write!(f, "surrogate state export: {e}"),
        }
    }
}

impl std::error::Error for StateError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StateError::NonFinite(e) => Some(e),
            _ => None,
        }
    }
}

impl From<NonFiniteJson> for StateError {
    fn from(e: NonFiniteJson) -> Self {
        StateError::NonFinite(e)
    }
}

// ---------------------------------------------------------------------------
// Field accessors (strict: every miss is a typed error).  Shared with
// the per-surrogate `import_state` implementations in `blr`/`fm`.

pub(crate) fn get<'a>(
    doc: &'a Json,
    field: &'static str,
) -> Result<&'a Json, StateError> {
    doc.get(field).ok_or(StateError::Missing { field })
}

pub(crate) fn get_usize(
    doc: &Json,
    field: &'static str,
) -> Result<usize, StateError> {
    get(doc, field)?.as_usize().ok_or(StateError::Malformed {
        field,
        detail: "expected an exact whole number".into(),
    })
}

pub(crate) fn get_finite(
    doc: &Json,
    field: &'static str,
) -> Result<f64, StateError> {
    let v = get(doc, field)?.as_f64().ok_or(StateError::Malformed {
        field,
        detail: "expected a number".into(),
    })?;
    if !v.is_finite() {
        return Err(StateError::Malformed {
            field,
            detail: format!("non-finite value {v}"),
        });
    }
    Ok(v)
}

pub(crate) fn get_str<'a>(
    doc: &'a Json,
    field: &'static str,
) -> Result<&'a str, StateError> {
    get(doc, field)?.as_str().ok_or(StateError::Malformed {
        field,
        detail: "expected a string".into(),
    })
}

/// Finite-f64 array of an exact expected length.
pub(crate) fn get_f64_vec(
    doc: &Json,
    field: &'static str,
    expected_len: usize,
) -> Result<Vec<f64>, StateError> {
    let arr = get(doc, field)?.as_arr().ok_or(StateError::Malformed {
        field,
        detail: "expected an array".into(),
    })?;
    if arr.len() != expected_len {
        return Err(StateError::Malformed {
            field,
            detail: format!("expected {expected_len} entries, found {}", arr.len()),
        });
    }
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let x = v.as_f64().ok_or(StateError::Malformed {
            field,
            detail: "expected numeric entries".into(),
        })?;
        if !x.is_finite() {
            return Err(StateError::Malformed {
                field,
                detail: format!("non-finite entry {x}"),
            });
        }
        out.push(x);
    }
    Ok(out)
}

/// Spin vector (±1 entries) as a JSON array of integers.
fn spins_to_json(x: &[i8]) -> Json {
    Json::Arr(x.iter().map(|&s| Json::Num(f64::from(s))).collect())
}

fn spins_from_json(
    v: &Json,
    field: &'static str,
    n_bits: usize,
) -> Result<Vec<i8>, StateError> {
    let arr = v.as_arr().ok_or(StateError::Malformed {
        field,
        detail: "expected a spin array".into(),
    })?;
    if arr.len() != n_bits {
        return Err(StateError::Malformed {
            field,
            detail: format!("expected {n_bits} spins, found {}", arr.len()),
        });
    }
    arr.iter()
        .map(|s| match s.as_f64() {
            Some(v) if v == 1.0 => Ok(1i8),
            Some(v) if v == -1.0 => Ok(-1i8),
            _ => Err(StateError::Malformed {
                field,
                detail: "spin entries must be 1 or -1".into(),
            }),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Dataset export/import (lives here so the best-point bookkeeping stays
// private to the surrogate module tree).

impl Dataset {
    /// Serialise the dataset — raw pairs *and* the incrementally
    /// maintained sufficient statistics — as a JSON object.
    ///
    /// The moments are exported verbatim rather than recomputed so an
    /// import restores the exact Gram matrix the donor run accumulated
    /// (bit-identical; for ±1 features the entries are exact integers).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("g", Json::arr_f64(&self.g.data)),
            ("gv", Json::arr_f64(&self.gv)),
            ("n_bits", Json::Num(self.n_bits as f64)),
            ("xs", Json::Arr(self.xs.iter().map(|x| spins_to_json(x)).collect())),
            ("ys", Json::arr_f64(&self.ys)),
            ("yty", Json::Num(self.yty)),
        ])
    }

    /// Rebuild a dataset from [`Dataset::to_json`] output.
    ///
    /// Strictly validated: shapes must match `n_bits`, spins must be
    /// ±1, every number must be finite.  Best-point tracking is rebuilt
    /// with the same strictly-lower / earliest-minimiser rule the
    /// incremental path uses, so an imported dataset behaves exactly
    /// like one grown in-process.
    pub fn from_json(doc: &Json) -> Result<Dataset, StateError> {
        let n_bits = get_usize(doc, "n_bits")?;
        let p = features::n_features(n_bits);
        let xs_json = get(doc, "xs")?.as_arr().ok_or(StateError::Malformed {
            field: "xs",
            detail: "expected an array of spin arrays".into(),
        })?;
        let mut xs = Vec::with_capacity(xs_json.len());
        for row in xs_json {
            xs.push(spins_from_json(row, "xs", n_bits)?);
        }
        let ys = get_f64_vec(doc, "ys", xs.len())?;
        let gv = get_f64_vec(doc, "gv", p)?;
        let gdata = get_f64_vec(doc, "g", p * p)?;
        let yty = get_finite(doc, "yty")?;

        let mut best_idx = None;
        let mut best_y = f64::INFINITY;
        for (i, &y) in ys.iter().enumerate() {
            if y < best_y {
                best_y = y;
                best_idx = Some(i);
            }
        }
        Ok(Dataset {
            n_bits,
            p,
            xs,
            ys,
            g: Matrix::from_vec(p, p, gdata),
            gv,
            yty,
            best_idx,
            best_y,
            panel: Vec::new(),
        })
    }
}

// ---------------------------------------------------------------------------
// Surrogate parameter payloads.

/// Opaque surrogate parameter payload: a `kind` discriminator plus the
/// kind-specific parameter tree produced by `Surrogate::export_state`.
#[derive(Clone, Debug, PartialEq)]
pub struct SurrogateParams {
    /// Surrogate kind that produced (and can re-import) the payload —
    /// `"nBOCS"`/`"gBOCS"`/`"vBOCS"` for BLR priors, `"fm-k8"` style
    /// for factorisation machines, `"stateless"` for the default.
    pub kind: String,
    /// Kind-specific parameter tree.
    pub params: Json,
}

impl SurrogateParams {
    /// Serialise as a JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str(self.kind.clone())),
            ("params", self.params.clone()),
        ])
    }

    /// Parse from [`SurrogateParams::to_json`] output.
    pub fn from_json(doc: &Json) -> Result<SurrogateParams, StateError> {
        Ok(SurrogateParams {
            kind: get_str(doc, "kind")?.to_string(),
            params: get(doc, "params")?.clone(),
        })
    }
}

// ---------------------------------------------------------------------------
// The full state document.

/// Everything a later run needs to warm-start: problem size, the
/// evaluated dataset with sufficient statistics, and (optionally) the
/// fitted surrogate's own parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SurrogateState {
    /// Problem size the state was exported for.
    pub n_bits: usize,
    /// Evaluated pairs + incrementally maintained moments.
    pub dataset: Dataset,
    /// Surrogate parameter payload (`None` for surrogate-free
    /// algorithms such as random search).
    pub surrogate: Option<SurrogateParams>,
}

impl SurrogateState {
    /// Serialise as a schema-tagged JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", self.dataset.to_json()),
            ("n_bits", Json::Num(self.n_bits as f64)),
            ("schema", Json::Str(STATE_SCHEMA.to_string())),
            (
                "surrogate",
                match &self.surrogate {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Serialise to text, failing typed on any non-finite number.
    pub fn to_string_strict(&self) -> Result<String, StateError> {
        Ok(self.to_json().to_string_strict()?)
    }

    /// Parse from [`SurrogateState::to_json`] output (strict).
    pub fn from_json(doc: &Json) -> Result<SurrogateState, StateError> {
        let found = doc.get("schema").and_then(Json::as_str).unwrap_or("");
        if found != STATE_SCHEMA {
            return Err(StateError::BadSchema { found: found.to_string() });
        }
        let n_bits = get_usize(doc, "n_bits")?;
        let dataset = Dataset::from_json(get(doc, "dataset")?)?;
        if dataset.n_bits != n_bits {
            return Err(StateError::Malformed {
                field: "dataset.n_bits",
                detail: format!(
                    "dataset n_bits {} disagrees with document n_bits {n_bits}",
                    dataset.n_bits
                ),
            });
        }
        let surrogate = match get(doc, "surrogate")? {
            Json::Null => None,
            v => Some(SurrogateParams::from_json(v)?),
        };
        Ok(SurrogateState { n_bits, dataset, surrogate })
    }

    /// Parse a serialised state document from text.
    pub fn parse(text: &str) -> Result<SurrogateState, StateError> {
        let doc = Json::parse(text).map_err(|e| StateError::Malformed {
            field: "",
            detail: format!("not valid JSON: {e}"),
        })?;
        SurrogateState::from_json(&doc)
    }

    /// True when this state can seed a surrogate of the given kind
    /// (`None` = the algorithm runs without a surrogate; its runs use
    /// only the dataset and previous best, so any payload is fine).
    pub fn compatible_kind(&self, expected: Option<&str>) -> bool {
        match (&self.surrogate, expected) {
            (None, _) | (Some(_), None) => true,
            (Some(p), Some(kind)) => p.kind == kind,
        }
    }
}

// ---------------------------------------------------------------------------
// Warm-start input for `bbo::run_warm`.

/// Warm-start input: a prior run's exported state plus (optionally) the
/// best point it found, which is re-evaluated on the (possibly drifted)
/// oracle to anchor the new trace.
#[derive(Clone, Debug, PartialEq)]
pub struct WarmStart {
    /// Exported state of the donor run.
    pub state: SurrogateState,
    /// Best `(x, y)` of the donor run.  The `y` is the *stale* cost on
    /// the donor instance; the warm run re-evaluates `x` and only the
    /// fresh value enters the trace.
    pub prev_best: Option<(Vec<i8>, f64)>,
}

impl WarmStart {
    /// Warm start from a state alone (no previous best).
    pub fn new(state: SurrogateState) -> WarmStart {
        WarmStart { state, prev_best: None }
    }

    /// Attach the donor run's best point.
    pub fn with_prev_best(mut self, x: Vec<i8>, y: f64) -> WarmStart {
        self.prev_best = Some((x, y));
        self
    }

    /// Serialise as a schema-tagged JSON value (the state document plus
    /// a `prev_best` member).
    pub fn to_json(&self) -> Json {
        let mut doc = self.state.to_json();
        let prev = match &self.prev_best {
            Some((x, y)) => Json::obj(vec![
                ("x", spins_to_json(x)),
                ("y", Json::Num(*y)),
            ]),
            None => Json::Null,
        };
        if let Json::Obj(m) = &mut doc {
            m.insert("prev_best".to_string(), prev);
        }
        doc
    }

    /// Serialise to text, failing typed on any non-finite number.
    pub fn to_string_strict(&self) -> Result<String, StateError> {
        Ok(self.to_json().to_string_strict()?)
    }

    /// Parse from [`WarmStart::to_json`] output (strict).
    pub fn from_json(doc: &Json) -> Result<WarmStart, StateError> {
        let state = SurrogateState::from_json(doc)?;
        let prev_best = match doc.get("prev_best") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let x = spins_from_json(get(v, "x")?, "prev_best.x", state.n_bits)?;
                let y = get_finite(v, "y")?;
                Some((x, y))
            }
        };
        Ok(WarmStart { state, prev_best })
    }

    /// Parse a serialised warm-start document from text.
    pub fn parse(text: &str) -> Result<WarmStart, StateError> {
        let doc = Json::parse(text).map_err(|e| StateError::Malformed {
            field: "",
            detail: format!("not valid JSON: {e}"),
        })?;
        WarmStart::from_json(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_dataset() -> Dataset {
        let mut d = Dataset::new(3);
        d.push(vec![1, -1, 1], 2.5);
        d.push(vec![-1, -1, 1], -0.75);
        d.push(vec![1, 1, -1], 4.0);
        d
    }

    #[test]
    fn dataset_roundtrips_byte_identically() {
        let d = sample_dataset();
        let text = d.to_json().to_string_strict().unwrap();
        let back = Dataset::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_strict().unwrap(), text);
        assert_eq!(back.best().map(|(_, y)| y), Some(-0.75));
        assert_eq!(back.len(), 3);
        assert_eq!(back.g.data, d.g.data);
        assert_eq!(back.gv, d.gv);
        assert_eq!(back.yty, d.yty);
    }

    #[test]
    fn state_roundtrips_with_and_without_surrogate() {
        for surrogate in [
            None,
            Some(SurrogateParams {
                kind: "nBOCS".into(),
                params: Json::obj(vec![("sigma_n2", Json::Num(0.25))]),
            }),
        ] {
            let st = SurrogateState {
                n_bits: 3,
                dataset: sample_dataset(),
                surrogate,
            };
            let text = st.to_string_strict().unwrap();
            let back = SurrogateState::parse(&text).unwrap();
            assert_eq!(back.to_string_strict().unwrap(), text);
        }
    }

    #[test]
    fn warm_start_roundtrips_prev_best() {
        let ws = WarmStart::new(SurrogateState {
            n_bits: 3,
            dataset: sample_dataset(),
            surrogate: None,
        })
        .with_prev_best(vec![-1, -1, 1], -0.75);
        let text = ws.to_string_strict().unwrap();
        let back = WarmStart::parse(&text).unwrap();
        assert_eq!(back.to_string_strict().unwrap(), text);
        assert_eq!(back.prev_best, Some((vec![-1, -1, 1], -0.75)));
    }

    #[test]
    fn wrong_schema_is_a_typed_error() {
        let st = SurrogateState {
            n_bits: 3,
            dataset: sample_dataset(),
            surrogate: None,
        };
        let mut doc = st.to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("schema".into(), Json::Str("intdecomp-surrogate-state-v0".into()));
        }
        match SurrogateState::from_json(&doc) {
            Err(StateError::BadSchema { found }) => {
                assert_eq!(found, "intdecomp-surrogate-state-v0");
            }
            other => panic!("expected BadSchema, got {other:?}"),
        }
    }

    #[test]
    fn torn_document_is_a_typed_error() {
        let st = SurrogateState {
            n_bits: 3,
            dataset: sample_dataset(),
            surrogate: None,
        };
        let text = st.to_string_strict().unwrap();
        let torn = &text[..text.len() / 2];
        assert!(matches!(
            SurrogateState::parse(torn),
            Err(StateError::Malformed { .. })
        ));
    }

    #[test]
    fn shape_violations_are_typed_errors() {
        let st = SurrogateState {
            n_bits: 3,
            dataset: sample_dataset(),
            surrogate: None,
        };
        // Corrupt the Gram matrix length.
        let mut doc = st.to_json();
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(d)) = m.get_mut("dataset") {
                if let Some(Json::Arr(g)) = d.get_mut("g") {
                    g.pop();
                }
            }
        }
        assert!(matches!(
            SurrogateState::from_json(&doc),
            Err(StateError::Malformed { field: "g", .. })
        ));
        // Non-±1 spin.
        let mut doc2 = st.to_json();
        if let Json::Obj(m) = &mut doc2 {
            if let Some(Json::Obj(d)) = m.get_mut("dataset") {
                if let Some(Json::Arr(xs)) = d.get_mut("xs") {
                    if let Some(Json::Arr(row)) = xs.get_mut(0) {
                        row[0] = Json::Num(0.0);
                    }
                }
            }
        }
        assert!(matches!(
            SurrogateState::from_json(&doc2),
            Err(StateError::Malformed { field: "xs", .. })
        ));
    }

    #[test]
    fn non_finite_export_is_a_typed_error() {
        let mut d = sample_dataset();
        d.yty = f64::NAN;
        let st = SurrogateState { n_bits: 3, dataset: d, surrogate: None };
        assert!(matches!(
            st.to_string_strict(),
            Err(StateError::NonFinite(_))
        ));
    }

    #[test]
    fn kind_compatibility_rules() {
        let with = SurrogateState {
            n_bits: 3,
            dataset: sample_dataset(),
            surrogate: Some(SurrogateParams { kind: "nBOCS".into(), params: Json::Null }),
        };
        let without = SurrogateState {
            n_bits: 3,
            dataset: sample_dataset(),
            surrogate: None,
        };
        assert!(with.compatible_kind(Some("nBOCS")));
        assert!(!with.compatible_kind(Some("vBOCS")));
        assert!(with.compatible_kind(None)); // RS: params ignored
        assert!(without.compatible_kind(Some("nBOCS")));
    }

    #[test]
    fn negative_zero_survives_the_roundtrip() {
        let mut d = Dataset::new(2);
        d.push(vec![1, -1], -0.0);
        let st = SurrogateState { n_bits: 2, dataset: d, surrogate: None };
        let text = st.to_string_strict().unwrap();
        let back = SurrogateState::parse(&text).unwrap();
        assert_eq!(back.dataset.ys[0].to_bits(), (-0.0f64).to_bits());
        assert_eq!(back.to_string_strict().unwrap(), text);
    }
}
