//! Bayesian linear regression surrogates — the BOCS family.
//!
//! The surrogate is `y ≈ alpha . phi(x)` with a Gaussian likelihood
//! (noise σ_n²) and one of three priors on the coefficients (paper
//! "BBO algorithms"):
//!
//! * **Normal** (nBOCS): `alpha_k ~ N(0, σ²_prior)`, σ²_prior a tuned
//!   hyperparameter (0.1 in the paper); σ_n² gets a Jeffreys prior and is
//!   Gibbs-sampled from its inverse-gamma conditional.
//! * **Normal-gamma** (gBOCS): `alpha, σ⁻² ~ NormalGamma(0, 1, 1, β)` —
//!   conjugate, so σ² is drawn from its marginal inverse-gamma and alpha
//!   from the conditional Gaussian.
//! * **Horseshoe** (vBOCS, Carvalho et al. 2010): `alpha_k ~
//!   N(0, β_k² τ² σ²)` with half-Cauchy scales, Gibbs-sampled via the
//!   Makalic–Schmidt (2016) inverse-gamma auxiliary representation — the
//!   slow-but-sparse vanilla BOCS of the paper.
//!
//! Each fit emits one Thompson draw from the posterior (Thompson 1933):
//! the drawn coefficient vector is handed to the Ising solver as-is.
//!
//! The Gaussian draw `alpha ~ N(A⁻¹ b, A⁻¹)`, `A = G/σ_n² + diag(lam)`,
//! is delegated to a [`PosteriorBackend`]: [`NativePosterior`] (in-tree
//! blocked Cholesky) or the PJRT `bocs_sample` artifact
//! (`runtime::XlaPosterior`) — the "fast Gaussian sampler" of the paper,
//! sharing the Gram moments across Gibbs sweeps so the O(rows·P²) work is
//! never repeated.
//!
//! **Scratch reuse (ISSUE 3):** every [`Blr`] owns a [`PosteriorScratch`]
//! (the P×P factor plus the b/μ/u solve buffers) and a set of
//! lam/z/G·alpha work vectors, all threaded through the Gibbs sweeps via
//! [`PosteriorBackend::draw_into`].  After the first fit at a given P the
//! whole sweep performs zero heap allocation (one clone of the final
//! coefficient vector aside), which is what keeps the per-iteration
//! surrogate refit flat at paper scale.

use super::{features, state, Dataset, Surrogate};
use crate::linalg::{
    cholesky_jittered_scaled_into, dot, solve_lower_into,
    solve_lower_t_in_place, JitterLadder, Matrix, NumericError,
};
use crate::solvers::QuadModel;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Prior precision pinned on the intercept (effectively flat — the bias
/// absorbs the mean cost and must not be shrunk).
const BIAS_PRECISION: f64 = 1e-8;

/// Numeric guard rails for Gibbs-sampled scales.
const SCALE_MIN: f64 = 1e-12;
const SCALE_MAX: f64 = 1e12;

fn clamp_scale(v: f64) -> f64 {
    v.clamp(SCALE_MIN, SCALE_MAX)
}

/// Coefficient prior — selects the BOCS variant.
#[derive(Clone, Debug)]
pub enum Prior {
    /// nBOCS: fixed prior variance (paper-tuned value: 0.1).
    Normal {
        /// Prior variance σ²_prior of every non-intercept coefficient.
        sigma2: f64,
    },
    /// gBOCS: NormalGamma(0, 1, a, beta) (paper: a = 1, beta = 0.001).
    NormalGamma {
        /// Gamma shape a.
        a: f64,
        /// Gamma rate β.
        beta: f64,
    },
    /// vBOCS: horseshoe, hyperparameter-free.
    Horseshoe,
}

impl Prior {
    /// The paper's label for the BOCS variant this prior selects.
    pub fn label(&self) -> String {
        match self {
            Prior::Normal { .. } => "nBOCS".into(),
            Prior::NormalGamma { .. } => "gBOCS".into(),
            Prior::Horseshoe => "vBOCS".into(),
        }
    }
}

/// Reusable buffers of one posterior draw: the Cholesky factor `L` of
/// the posterior precision plus the `b`/`u`/draw solve vectors.  Sized
/// lazily on first use and reused afterwards, so a warm draw performs
/// zero heap allocation ([`PosteriorBackend::draw_into`]).
pub struct PosteriorScratch {
    /// Factor of `A = G/σ_n² + diag(lam)` (lower triangular).
    l: Matrix,
    /// Scaled right-hand side `gv / σ_n²`.
    b: Vec<f64>,
    /// `L⁻ᵀ z` — the zero-mean N(0, A⁻¹) component.
    u: Vec<f64>,
    /// `μ + L⁻ᵀ z` — the finished draw.
    draw: Vec<f64>,
}

impl PosteriorScratch {
    /// Empty scratch; buffers warm up on the first draw.
    pub fn new() -> Self {
        PosteriorScratch {
            l: Matrix::zeros(0, 0),
            b: Vec::new(),
            u: Vec::new(),
            draw: Vec::new(),
        }
    }

    /// Coefficients of the most recent draw.
    pub fn draw(&self) -> &[f64] {
        &self.draw
    }

    fn ensure(&mut self, p: usize) {
        self.b.resize(p, 0.0);
        self.u.resize(p, 0.0);
        self.draw.resize(p, 0.0);
        // `l` is (re)sized by the factorisation itself.
    }
}

impl Default for PosteriorScratch {
    fn default() -> Self {
        PosteriorScratch::new()
    }
}

/// Where the O(P³) Gaussian draw happens (native Cholesky or PJRT artifact).
///
/// Both entry points are fallible (ISSUE 9): an exhausted jitter ladder
/// surfaces as [`NumericError::PosteriorNotSpd`] instead of a panic, so
/// the BBO loop above can degrade to a random acquisition for that
/// iteration rather than kill the process.
pub trait PosteriorBackend: Send {
    /// Draw `mu + L⁻ᵀ z` with `A = G/σ_n² + diag(lam)`, `b = gv/σ_n²`,
    /// `mu = A⁻¹ b`; returns (draw, Σ ln diag L).
    fn draw(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
    ) -> Result<(Vec<f64>, f64), NumericError>;

    /// Scratch-reusing draw: identical output to
    /// [`PosteriorBackend::draw`], written into `scratch` (read it back
    /// through [`PosteriorScratch::draw`]); returns Σ ln diag L.  The
    /// default delegates to `draw` and copies — the PJRT backend keeps
    /// its API shape untouched — while [`NativePosterior`] overrides it
    /// with a zero-allocation implementation.  For any one backend the
    /// two entry points are bit-identical, errors included (a failed
    /// draw leaves `scratch` unspecified).
    fn draw_into(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
        scratch: &mut PosteriorScratch,
    ) -> Result<f64, NumericError> {
        let (d, half_logdet) = self.draw(g, gv, lam, sigma_n2, z)?;
        scratch.ensure(g.rows);
        scratch.draw.copy_from_slice(&d);
        Ok(half_logdet)
    }

    /// Short identifier for reports ("native" / "xla").
    fn backend_name(&self) -> &'static str;
}

/// In-tree blocked-Cholesky backend.
pub struct NativePosterior;

impl PosteriorBackend for NativePosterior {
    fn draw(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
    ) -> Result<(Vec<f64>, f64), NumericError> {
        let mut scratch = PosteriorScratch::new();
        let half_logdet =
            self.draw_into(g, gv, lam, sigma_n2, z, &mut scratch)?;
        Ok((scratch.draw, half_logdet))
    }

    fn draw_into(
        &self,
        g: &Matrix,
        gv: &[f64],
        lam: &[f64],
        sigma_n2: f64,
        z: &[f64],
        scratch: &mut PosteriorScratch,
    ) -> Result<f64, NumericError> {
        let p = g.rows;
        scratch.ensure(p);
        let inv_s2 = 1.0 / sigma_n2;
        // Fused scale+diag factorisation into the reused factor buffer;
        // bounded jitter ladder (0, 1e-10, ×100 each retry up to 1e-2)
        // for the (rare) borderline case.  The clean first attempt is
        // bit-identical to a direct `cholesky_scaled_into` call; only
        // an exhausted ladder aborts the draw — as a typed
        // `NumericError::PosteriorNotSpd`, never a panic (ISSUE 9).
        cholesky_jittered_scaled_into(
            g,
            inv_s2,
            lam,
            0.0,
            JitterLadder { base: 1e-10, factor: 100.0, retries: 5 },
            &mut scratch.l,
        )?;
        for (b, v) in scratch.b.iter_mut().zip(gv) {
            *b = v * inv_s2;
        }
        // μ = A⁻¹ b through the factor, accumulated in the draw buffer.
        solve_lower_into(&scratch.l, &scratch.b, &mut scratch.draw);
        solve_lower_t_in_place(&scratch.l, &mut scratch.draw);
        // The N(0, A⁻¹) component L⁻ᵀ z, added on top.
        scratch.u.copy_from_slice(z);
        solve_lower_t_in_place(&scratch.l, &mut scratch.u);
        for (d, u) in scratch.draw.iter_mut().zip(&scratch.u) {
            *d += *u;
        }
        Ok((0..p).map(|i| scratch.l[(i, i)].ln()).sum())
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

/// Horseshoe Gibbs state (Makalic–Schmidt auxiliary variables).
#[derive(Clone, Debug)]
struct HorseshoeState {
    beta2: Vec<f64>,
    nu: Vec<f64>,
    tau2: f64,
    xi: f64,
}

/// BOCS surrogate: Bayesian linear regression + Thompson sampling.
pub struct Blr {
    /// Coefficient prior (selects vBOCS / nBOCS / gBOCS).
    pub prior: Prior,
    /// Gibbs sweeps per fit (hyperparameter resampling).
    pub gibbs_sweeps: usize,
    backend: Box<dyn PosteriorBackend>,
    /// Noise variance carried across BBO iterations (warm start).
    sigma_n2: f64,
    hs: Option<HorseshoeState>,
    /// Posterior-draw scratch, reused across sweeps and fits.
    scratch: PosteriorScratch,
    /// Prior precision diag(lam), rebuilt in place every sweep.
    lam: Vec<f64>,
    /// Standard-normal buffer for the Thompson draw.
    z: Vec<f64>,
    /// G·alpha buffer for the SSR computation.
    ga: Vec<f64>,
}

impl Blr {
    /// BLR surrogate with the native Cholesky posterior backend.
    pub fn new(prior: Prior) -> Self {
        Blr::with_backend(prior, Box::new(NativePosterior))
    }

    /// BLR surrogate with an explicit posterior backend (PJRT path).
    pub fn with_backend(
        prior: Prior,
        backend: Box<dyn PosteriorBackend>,
    ) -> Self {
        let sweeps = match prior {
            Prior::Horseshoe => 5,
            _ => 2,
        };
        Blr {
            prior,
            gibbs_sweeps: sweeps,
            backend,
            sigma_n2: 1.0,
            hs: None,
            scratch: PosteriorScratch::new(),
            lam: Vec::new(),
            z: Vec::new(),
            ga: Vec::new(),
        }
    }

    /// Residual sum of squares from the moments:
    /// `SSR = y^T y - 2 a^T gv + a^T G a` (G·a lands in the reused `ga`).
    fn ssr(data: &Dataset, alpha: &[f64], ga: &mut Vec<f64>) -> f64 {
        data.g.matvec_into(alpha, ga);
        (data.yty - 2.0 * dot(alpha, &data.gv) + dot(alpha, ga)).max(0.0)
    }

    /// One posterior draw with the current `self.lam` into the scratch
    /// (fresh normals off `rng`, same stream the allocating path used).
    ///
    /// The normals are consumed from `rng` *before* the backend runs, so
    /// the RNG stream position after a failed draw is the same as after a
    /// successful one — the degraded-mode determinism contract (ISSUE 9)
    /// depends on this ordering.
    fn draw_into_scratch(
        &mut self,
        data: &Dataset,
        sigma_n2: f64,
        rng: &mut Rng,
    ) -> Result<(), NumericError> {
        self.z.resize(data.p, 0.0);
        rng.fill_normals(&mut self.z);
        self.backend.draw_into(
            &data.g,
            &data.gv,
            &self.lam,
            sigma_n2,
            &self.z,
            &mut self.scratch,
        )?;
        Ok(())
    }

    /// One Thompson sample of the coefficient vector.
    ///
    /// Fallible (ISSUE 9): a non-SPD posterior surfaces as
    /// [`NumericError::PosteriorNotSpd`] and the caller degrades.
    pub fn sample_alpha(
        &mut self,
        data: &Dataset,
        rng: &mut Rng,
    ) -> Result<Vec<f64>, NumericError> {
        let p = data.p;
        let rows = data.len().max(1) as f64;
        match self.prior.clone() {
            Prior::Normal { sigma2 } => {
                self.lam.clear();
                self.lam.resize(p, 1.0 / sigma2.max(SCALE_MIN));
                self.lam[0] = BIAS_PRECISION;
                for _ in 0..self.gibbs_sweeps {
                    let s2 = self.sigma_n2;
                    self.draw_into_scratch(data, s2, rng)?;
                    // Jeffreys conditional: σ_n² ~ IG(rows/2, SSR/2).
                    let ssr =
                        Self::ssr(data, &self.scratch.draw, &mut self.ga);
                    self.sigma_n2 = clamp_scale(
                        rng.inv_gamma(rows / 2.0, (ssr / 2.0).max(SCALE_MIN)),
                    );
                }
                Ok(self.scratch.draw.clone())
            }
            Prior::NormalGamma { a, beta } => {
                // Conjugate: draw σ² from the marginal, then alpha | σ².
                // A0 = G + λ0 I (λ0 = 1), μ = A0⁻¹ gv.
                self.lam.clear();
                self.lam.resize(p, 1.0);
                self.lam[0] = BIAS_PRECISION;
                // μ via a native solve on A0 (σ_n² = 1, z = 0).
                self.z.clear();
                self.z.resize(p, 0.0);
                self.backend.draw_into(
                    &data.g,
                    &data.gv,
                    &self.lam,
                    1.0,
                    &self.z,
                    &mut self.scratch,
                )?;
                // β_post = β + (y^T y - μ^T (G + λ0) μ)/2, guarded >= β.
                data.g.matvec_into(&self.scratch.draw, &mut self.ga);
                let mu = &self.scratch.draw;
                let quad = dot(mu, &self.ga)
                    + mu.iter()
                        .zip(&self.lam)
                        .map(|(m, l)| l * m * m)
                        .sum::<f64>();
                let beta_post = beta + ((data.yty - quad) / 2.0).max(0.0);
                let a_post = a + rows / 2.0;
                let sigma2 = clamp_scale(rng.inv_gamma(a_post, beta_post));
                self.sigma_n2 = sigma2;
                // alpha ~ N(μ, σ² (G + λ0)⁻¹): backend with σ_n² = σ²,
                // lam = λ0/σ² gives A = (G + λ0)/σ².
                for l in self.lam.iter_mut() {
                    *l /= sigma2;
                }
                self.draw_into_scratch(data, sigma2, rng)?;
                Ok(self.scratch.draw.clone())
            }
            Prior::Horseshoe => {
                // (Re)initialise the Gibbs chain when absent — or when a
                // warm-start import carried scales for a different P
                // (only possible from a hand-edited state file; a fresh
                // chain is safe, running with mismatched scales is not).
                if self.hs.as_ref().map_or(true, |h| h.beta2.len() != p) {
                    self.hs = Some(HorseshoeState {
                        beta2: vec![1.0; p],
                        nu: vec![1.0; p],
                        tau2: 1.0,
                        xi: 1.0,
                    });
                }
                for _ in 0..self.gibbs_sweeps {
                    let s2 = self.sigma_n2;
                    {
                        let hs = self.hs.as_ref().unwrap();
                        self.lam.clear();
                        self.lam.reserve(p);
                        for b2 in &hs.beta2 {
                            self.lam.push(
                                1.0 / clamp_scale(*b2 * hs.tau2 * s2),
                            );
                        }
                        self.lam[0] = BIAS_PRECISION;
                    }
                    self.draw_into_scratch(data, s2, rng)?;
                    let ssr =
                        Self::ssr(data, &self.scratch.draw, &mut self.ga);
                    let alpha = &self.scratch.draw;
                    let hs = self.hs.as_mut().unwrap();
                    // Local scales (skip the intercept at k = 0).
                    let mut shrink_sum = 0.0;
                    for k in 1..p {
                        let ak2 = alpha[k] * alpha[k];
                        hs.beta2[k] = clamp_scale(rng.inv_gamma(
                            1.0,
                            1.0 / hs.nu[k] + ak2 / (2.0 * hs.tau2 * s2),
                        ));
                        hs.nu[k] = clamp_scale(
                            rng.inv_gamma(1.0, 1.0 + 1.0 / hs.beta2[k]),
                        );
                        shrink_sum += ak2 / hs.beta2[k];
                    }
                    // Global scale.
                    hs.tau2 = clamp_scale(rng.inv_gamma(
                        (p as f64) / 2.0,
                        1.0 / hs.xi + shrink_sum / (2.0 * s2),
                    ));
                    hs.xi = clamp_scale(
                        rng.inv_gamma(1.0, 1.0 + 1.0 / hs.tau2),
                    );
                    // Noise.
                    self.sigma_n2 = clamp_scale(rng.inv_gamma(
                        (rows + (p - 1) as f64) / 2.0,
                        ((ssr + shrink_sum / hs.tau2) / 2.0)
                            .max(SCALE_MIN),
                    ));
                }
                Ok(self.scratch.draw.clone())
            }
        }
    }
}

impl Surrogate for Blr {
    fn fit_model(
        &mut self,
        data: &Dataset,
        rng: &mut Rng,
    ) -> Result<QuadModel, NumericError> {
        let alpha = self.sample_alpha(data, rng)?;
        Ok(features::alpha_to_quad(&alpha, data.n_bits))
    }

    fn name(&self) -> String {
        format!("{}[{}]", self.prior.label(), self.backend.backend_name())
    }

    /// Export the posterior's cross-iteration state: the Gibbs-sampled
    /// noise variance σ_n² plus (for vBOCS) the horseshoe auxiliary
    /// chain.  The dataset's sufficient statistics G/Φᵀy/yᵀy travel in
    /// the enclosing [`state::SurrogateState`], so together the two
    /// reproduce the full posterior.
    fn export_state(&self) -> state::SurrogateParams {
        let hs = match &self.hs {
            Some(h) => Json::obj(vec![
                ("beta2", Json::arr_f64(&h.beta2)),
                ("nu", Json::arr_f64(&h.nu)),
                ("p", Json::Num(h.beta2.len() as f64)),
                ("tau2", Json::Num(h.tau2)),
                ("xi", Json::Num(h.xi)),
            ]),
            None => Json::Null,
        };
        state::SurrogateParams {
            kind: self.prior.label(),
            params: Json::obj(vec![
                ("hs", hs),
                ("sigma_n2", Json::Num(self.sigma_n2)),
            ]),
        }
    }

    /// Import a [`Surrogate::export_state`] payload.  The kind must be
    /// this prior's label (an nBOCS state cannot seed a vBOCS chain);
    /// shapes and finiteness are validated field by field.
    fn import_state(
        &mut self,
        params: &state::SurrogateParams,
    ) -> Result<(), state::StateError> {
        let expected = self.prior.label();
        if params.kind != expected {
            return Err(state::StateError::KindMismatch {
                expected,
                found: params.kind.clone(),
            });
        }
        let doc = &params.params;
        let sigma_n2 = state::get_finite(doc, "sigma_n2")?;
        if sigma_n2 <= 0.0 {
            return Err(state::StateError::Malformed {
                field: "sigma_n2",
                detail: format!("noise variance must be positive, got {sigma_n2}"),
            });
        }
        let hs = match state::get(doc, "hs")? {
            Json::Null => None,
            v => {
                let p = state::get_usize(v, "p")?;
                Some(HorseshoeState {
                    beta2: state::get_f64_vec(v, "beta2", p)?,
                    nu: state::get_f64_vec(v, "nu", p)?,
                    tau2: state::get_finite(v, "tau2")?,
                    xi: state::get_finite(v, "xi")?,
                })
            }
        };
        self.sigma_n2 = sigma_n2;
        self.hs = hs;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::features::{n_features, phi};

    /// Build a dataset from a planted quadratic model plus noise.
    fn planted_dataset(
        n: usize,
        rows: usize,
        noise: f64,
        rng: &mut Rng,
    ) -> (Dataset, Vec<f64>) {
        let p = n_features(n);
        let alpha_true: Vec<f64> = rng.normals(p);
        let mut data = Dataset::new(n);
        for _ in 0..rows {
            let x = rng.spins(n);
            let y: f64 = dot(&alpha_true, &phi(&x)) + noise * rng.normal();
            data.push(x, y);
        }
        (data, alpha_true)
    }

    fn dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn normal_prior_recovers_planted_model() {
        let mut rng = Rng::new(500);
        let n = 5;
        let (data, alpha_true) = planted_dataset(n, 400, 0.01, &mut rng);
        let mut blr = Blr::new(Prior::Normal { sigma2: 10.0 });
        // Average several Thompson draws to beat sampling noise.
        let mut avg = vec![0.0; data.p];
        let draws = 20;
        for _ in 0..draws {
            let a = blr.sample_alpha(&data, &mut rng).unwrap();
            for (s, v) in avg.iter_mut().zip(&a) {
                *s += v / draws as f64;
            }
        }
        for (got, want) in avg.iter().zip(&alpha_true).skip(1) {
            assert!((got - want).abs() < 0.15, "got {got}, want {want}");
        }
    }

    #[test]
    fn all_priors_produce_finite_draws() {
        let mut rng = Rng::new(501);
        let n = 6;
        let (data, _) = planted_dataset(n, 60, 0.1, &mut rng);
        for prior in [
            Prior::Normal { sigma2: 0.1 },
            Prior::NormalGamma { a: 1.0, beta: 0.001 },
            Prior::Horseshoe,
        ] {
            let mut blr = Blr::new(prior.clone());
            for _ in 0..3 {
                let a = blr.sample_alpha(&data, &mut rng).unwrap();
                assert_eq!(a.len(), data.p);
                assert!(
                    a.iter().all(|v| v.is_finite()),
                    "{:?} produced non-finite draw",
                    prior
                );
            }
        }
    }

    #[test]
    fn horseshoe_shrinks_null_coefficients() {
        // Planted model with only ONE active pair term: the horseshoe
        // posterior should shrink the rest far more than it shrinks the
        // active one.
        let mut rng = Rng::new(502);
        let n = 6;
        let p = n_features(n);
        let mut alpha_true = vec![0.0; p];
        alpha_true[1 + n] = 3.0; // first pair term
        let mut data = Dataset::new(n);
        for _ in 0..150 {
            let x = rng.spins(n);
            let y = dot(&alpha_true, &phi(&x)) + 0.05 * rng.normal();
            data.push(x, y);
        }
        let mut blr = Blr::new(Prior::Horseshoe);
        let mut avg = vec![0.0; p];
        let draws = 10;
        for _ in 0..draws {
            let a = blr.sample_alpha(&data, &mut rng).unwrap();
            for (s, v) in avg.iter_mut().zip(&a) {
                *s += v.abs() / draws as f64;
            }
        }
        let active = avg[1 + n];
        let null_max = avg[1 + n + 1..]
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        assert!(active > 2.0, "active coefficient lost: {active}");
        assert!(
            null_max < 0.5 * active,
            "null coeffs not shrunk: {null_max} vs {active}"
        );
    }

    #[test]
    fn surrogate_model_predicts_low_cost_at_planted_minimum() {
        // Fit on exhaustive data of a small planted quadratic: the
        // surrogate's minimiser must match the true minimiser.
        let mut rng = Rng::new(503);
        let n = 4;
        let p = n_features(n);
        let alpha_true: Vec<f64> = rng.normals(p);
        let mut data = Dataset::new(n);
        let mut true_best = (vec![], f64::INFINITY);
        for bits in 0..(1u32 << n) {
            let x: Vec<i8> = (0..n)
                .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
                .collect();
            let y = dot(&alpha_true, &phi(&x));
            if y < true_best.1 {
                true_best = (x.clone(), y);
            }
            data.push(x, y);
        }
        let mut blr = Blr::new(Prior::Normal { sigma2: 10.0 });
        let model = blr.fit_model(&data, &mut rng).unwrap();
        // The planted minimiser should be at (or within noise of) the
        // surrogate's own minimum.
        let e_best = model.energy(&true_best.0);
        let mut better = 0;
        for bits in 0..(1u32 << n) {
            let x: Vec<i8> = (0..n)
                .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
                .collect();
            if model.energy(&x) < e_best - 1e-6 {
                better += 1;
            }
        }
        assert!(better <= 1, "surrogate ranks {better} configs above truth");
    }

    #[test]
    fn native_backend_draw_statistics() {
        // With G = I, gv = 0, lam = 1, σ_n² = 1: A = 2I, draws ~ N(0, I/2).
        let p = 4;
        let g = Matrix::identity(p);
        let gv = vec![0.0; p];
        let lam = vec![1.0; p];
        let be = NativePosterior;
        let mut rng = Rng::new(504);
        let nsamp = 4000;
        let mut m2 = vec![0.0; p];
        for _ in 0..nsamp {
            let z = rng.normals(p);
            let (d, hld) = be.draw(&g, &gv, &lam, 1.0, &z).unwrap();
            assert!((hld - (2.0f64).ln() * p as f64 / 2.0).abs() < 1e-9);
            for (s, v) in m2.iter_mut().zip(&d) {
                *s += v * v / nsamp as f64;
            }
        }
        for v in m2 {
            assert!((v - 0.5).abs() < 0.05, "variance {v} != 0.5");
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_allocation_bit_for_bit() {
        // draw() (fresh buffers) and draw_into() (reused scratch, warm
        // across calls) must agree to the last bit on a fixed seed.
        let mut rng = Rng::new(505);
        let p = 37; // not a multiple of the Cholesky block
        let a = Matrix::from_vec(p + 5, p, rng.normals((p + 5) * p));
        let mut g = a.gram();
        for i in 0..p {
            g[(i, i)] += 2.0;
        }
        let gv = rng.normals(p);
        let lam: Vec<f64> =
            rng.normals(p).iter().map(|v| v.abs() + 0.1).collect();
        let be = NativePosterior;
        let mut scratch = PosteriorScratch::new();
        for trial in 0..4 {
            let z = rng.normals(p);
            let s2 = 0.3 + 0.2 * trial as f64;
            let (fresh, hld_fresh) = be.draw(&g, &gv, &lam, s2, &z).unwrap();
            let hld_warm = be
                .draw_into(&g, &gv, &lam, s2, &z, &mut scratch)
                .unwrap();
            assert_eq!(hld_fresh.to_bits(), hld_warm.to_bits());
            assert_eq!(fresh.len(), scratch.draw().len());
            for (a, b) in fresh.iter().zip(scratch.draw()) {
                assert_eq!(a.to_bits(), b.to_bits(), "trial {trial}");
            }
        }
    }

    #[test]
    fn fitted_state_roundtrips_byte_identically() {
        let mut rng = Rng::new(506);
        let n = 5;
        let (data, _) = planted_dataset(n, 40, 0.1, &mut rng);
        for prior in [
            Prior::Normal { sigma2: 0.1 },
            Prior::NormalGamma { a: 1.0, beta: 0.001 },
            Prior::Horseshoe,
        ] {
            let mut blr = Blr::new(prior.clone());
            blr.sample_alpha(&data, &mut rng).unwrap();
            let exported = blr.export_state();
            let text = exported.to_json().to_string_strict().unwrap();
            let mut fresh = Blr::new(prior.clone());
            fresh
                .import_state(
                    &state::SurrogateParams::from_json(
                        &Json::parse(&text).unwrap(),
                    )
                    .unwrap(),
                )
                .unwrap();
            let again = fresh.export_state();
            assert_eq!(
                again.to_json().to_string_strict().unwrap(),
                text,
                "{prior:?} state did not round-trip byte-identically"
            );
        }
    }

    #[test]
    fn import_rejects_cross_prior_state() {
        let mut rng = Rng::new(507);
        let (data, _) = planted_dataset(4, 30, 0.1, &mut rng);
        let mut nbocs = Blr::new(Prior::Normal { sigma2: 0.1 });
        nbocs.sample_alpha(&data, &mut rng).unwrap();
        let exported = nbocs.export_state();
        let mut vbocs = Blr::new(Prior::Horseshoe);
        match vbocs.import_state(&exported) {
            Err(state::StateError::KindMismatch { expected, found }) => {
                assert_eq!(expected, "vBOCS");
                assert_eq!(found, "nBOCS");
            }
            other => panic!("expected KindMismatch, got {other:?}"),
        }
    }

    #[test]
    fn import_rejects_non_positive_noise_variance() {
        let mut blr = Blr::new(Prior::Normal { sigma2: 0.1 });
        let bad = state::SurrogateParams {
            kind: "nBOCS".into(),
            params: Json::obj(vec![
                ("hs", Json::Null),
                ("sigma_n2", Json::Num(-1.0)),
            ]),
        };
        assert!(matches!(
            blr.import_state(&bad),
            Err(state::StateError::Malformed { field: "sigma_n2", .. })
        ));
    }
}
