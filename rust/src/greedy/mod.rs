//! The original greedy integer decomposition (paper Eq. 4–5; Ambai & Sato's
//! SPADE) — the baseline the BBO algorithms are measured against (the red
//! dotted line in Figs. 1/7 and the "original" row of Table 2).
//!
//! The decomposition is built one rank-one term at a time: at step i the
//! residual `R = W - Σ_{j<i} m_j c_j^T` is approximated by `m c^T` with
//! binary `m`, real `c`, found by alternating least squares:
//!
//! ```text
//!   c = R^T m / N          (optimal c given m, since m^T m = N)
//!   m = sign(R c)          (optimal m given c, elementwise)
//! ```
//!
//! iterated to a fixed point from multiple deterministic + random starts.
//! Previously fixed vectors are never revisited, which is exactly why the
//! method cannot escape local minima (the gap the paper's BBO closes).

use crate::cost::{BinMatrix, Problem};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Result of the greedy decomposition.
#[derive(Clone, Debug)]
pub struct GreedyResult {
    /// The greedy binary factor M.
    pub m: BinMatrix,
    /// C from the greedy series (c_i of each rank-one step).
    pub c_series: Matrix,
    /// Cost of the series form ||W - Σ m_i c_i^T||^2.
    pub cost_series: f64,
    /// Cost with C refit by least squares given the final M (Eq. 8 value —
    /// always <= cost_series; this is what the BBO residual plots use).
    pub cost_refit: f64,
}

/// Rank-one alternating fit of the residual; returns (m, c, captured).
fn rank_one_fit(
    r: &Matrix,
    starts: usize,
    iters: usize,
    rng: &mut Rng,
) -> (Vec<i8>, Vec<f64>) {
    let n = r.rows;
    let mut best: Option<(f64, Vec<i8>, Vec<f64>)> = None;

    for start in 0..starts {
        // Start 0: sign of the dominant-ish direction via one power step;
        // others: random spins.
        let mut m: Vec<i8> = if start == 0 {
            // power iteration proxy: row sums of R R^T applied to ones.
            let ones = vec![1.0; r.cols];
            let v = r.matvec(&ones);
            v.iter().map(|&x| if x >= 0.0 { 1 } else { -1 }).collect()
        } else {
            rng.spins(n)
        };
        let mut c = vec![0.0; r.cols];
        for _ in 0..iters {
            // c = R^T m / N
            let mf: Vec<f64> = m.iter().map(|&s| s as f64).collect();
            c = r.tmatvec(&mf);
            for ci in c.iter_mut() {
                *ci /= n as f64;
            }
            // m = sign(R c)
            let rc = r.matvec(&c);
            let new_m: Vec<i8> =
                rc.iter().map(|&x| if x >= 0.0 { 1 } else { -1 }).collect();
            if new_m == m {
                break;
            }
            m = new_m;
        }
        // Captured energy of this rank-one term: N * ||c||^2.
        let captured =
            n as f64 * c.iter().map(|x| x * x).sum::<f64>();
        if best.as_ref().map_or(true, |(b, _, _)| captured > *b) {
            best = Some((captured, m, c));
        }
    }
    let (_, m, c) = best.unwrap();
    (m, c)
}

/// Run the greedy decomposition as the paper's "original algorithm": one
/// deterministic alternating pass per rank-one step (no random restarts —
/// restarts make it stronger than the baseline the paper compares
/// against; use [`greedy_with`] for the boosted variant).
pub fn greedy(problem: &Problem, seed: u64) -> GreedyResult {
    greedy_with(problem, seed, 1, 100)
}

/// Greedy with explicit restart / iteration budget.
pub fn greedy_with(
    problem: &Problem,
    seed: u64,
    starts: usize,
    iters: usize,
) -> GreedyResult {
    let mut rng = Rng::new(seed);
    let (n, d, k) = (problem.n(), problem.d(), problem.k);
    let mut residual = problem.w.clone();
    let mut m_cols: Vec<i8> = Vec::with_capacity(n * k);
    let mut c_rows: Vec<Vec<f64>> = Vec::with_capacity(k);

    for _ in 0..k {
        let (m, c) = rank_one_fit(&residual, starts, iters, &mut rng);
        // residual -= m c^T
        for i in 0..n {
            let mi = m[i] as f64;
            let row = residual.row_mut(i);
            for j in 0..d {
                row[j] -= mi * c[j];
            }
        }
        m_cols.extend_from_slice(&m);
        c_rows.push(c);
    }

    let m = BinMatrix::new(n, k, m_cols);
    let c_series = Matrix::from_rows(&c_rows);
    let cost_series = residual.frob_norm_sq();
    let cost_refit = problem.cost(&m);
    GreedyResult { m, c_series, cost_series, cost_refit }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::{generate, InstanceConfig};
    use crate::util::rng::Rng;

    #[test]
    fn refit_never_worse_than_series() {
        let cfg = InstanceConfig::default();
        for idx in 0..5 {
            let p = generate(&cfg, idx);
            let g = greedy(&p, 1);
            assert!(g.cost_refit <= g.cost_series + 1e-9);
            assert!(g.cost_series <= p.w_norm_sq + 1e-9);
        }
    }

    #[test]
    fn greedy_beats_random_candidates() {
        let cfg = InstanceConfig::default();
        let p = generate(&cfg, 0);
        // The boosted (multi-start) greedy must beat a random sample; the
        // single-pass original can occasionally lose to lucky draws, which
        // is exactly the weakness the paper's BBO exploits.
        let g = greedy_with(&p, 1, 8, 100);
        let mut rng = Rng::new(9);
        let mut best_random = f64::INFINITY;
        for _ in 0..200 {
            let m = BinMatrix::new(8, 3, rng.spins(24));
            best_random = best_random.min(p.cost(&m));
        }
        // 200 random draws from a 2^24 space should not beat the greedy.
        assert!(g.cost_refit <= best_random + 1e-9);
    }

    #[test]
    fn rank_one_on_rank_one_matrix_is_exact() {
        // W = m c^T exactly; greedy at K=1 must capture it all.
        let n = 6;
        let m_true: Vec<i8> = vec![1, -1, 1, 1, -1, -1];
        let c_true = [0.5, -1.5, 2.0, 0.25];
        let mut w = Matrix::zeros(n, 4);
        for i in 0..n {
            for j in 0..4 {
                w[(i, j)] = m_true[i] as f64 * c_true[j];
            }
        }
        let p = Problem::new(w, 1);
        let g = greedy(&p, 3);
        assert!(g.cost_series < 1e-18 * p.w_norm_sq.max(1.0) + 1e-12);
    }

    #[test]
    fn series_cost_decreases_with_k() {
        let cfg = InstanceConfig::default();
        let w = crate::instance::generate_w(&cfg, 2);
        let mut last = f64::INFINITY;
        for k in 1..=4 {
            let p = Problem::new(w.clone(), k);
            let g = greedy(&p, 5);
            assert!(g.cost_series <= last + 1e-9, "k={k}");
            last = g.cost_series;
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = InstanceConfig::default();
        let p = generate(&cfg, 1);
        let a = greedy(&p, 42);
        let b = greedy(&p, 42);
        assert_eq!(a.m, b.m);
        assert_eq!(a.cost_series, b.cost_series);
    }
}
