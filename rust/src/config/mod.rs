//! Experiment configuration: one struct for all paper experiments, filled
//! from CLI flags with the paper's defaults (`--full`) or a smoke scale
//! that finishes in minutes on one core.

use crate::bbo::BboConfig;
use crate::cli::Args;
use crate::instance::InstanceConfig;

/// Scale of an experiment run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Paper scale: 25 runs (100 for RS), 2n² iterations, 10 instances.
    Full,
    /// Reduced default for interactive use.
    Smoke,
}

/// Everything the experiment harness needs.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Synthetic instance shape (N, D, K, γ) and generator seed.
    pub instance: InstanceConfig,
    /// Smoke or full (paper) scale.
    pub scale: Scale,
    /// BBO runs per (algorithm, instance).
    pub runs: usize,
    /// RS runs (paper uses 100 vs 25).
    pub rs_runs: usize,
    /// Acquisition iterations per run.
    pub iters: usize,
    /// Ising-solver restarts per iteration.
    pub restarts: usize,
    /// Instance count.
    pub instances: usize,
    /// Base RNG seed for runs.
    pub seed: u64,
    /// Output directory for CSV/JSON.
    pub out_dir: String,
    /// Use the PJRT artifacts where shapes allow.
    pub use_xla: bool,
    /// Worker threads for independent runs.
    pub workers: usize,
    /// Acquisition batch size per BBO iteration (1 = serial loop).
    pub batch_size: usize,
    /// Use raw (exact) evaluation-cache keys instead of the default
    /// canonical-orbit folding (`--cache-key raw`): bit-identical to an
    /// uncached run, at the price of re-evaluating orbit members.
    pub cache_key_raw: bool,
}

impl ExpConfig {
    /// Build from CLI flags.
    pub fn from_args(args: &Args) -> Result<ExpConfig, String> {
        let full = args.bool_flag("full");
        let n = args.usize_flag("n", 8)?;
        let d = args.usize_flag("d", 100)?;
        let k = args.usize_flag("k", 3)?;
        let n_bits = n * k;
        let instance = InstanceConfig {
            n,
            d,
            k,
            gamma: args.f64_flag("gamma", 0.7)?,
            seed: args.u64_flag("instance-seed", 5005)?,
        };
        // Paper scale: 25 runs, 2n^2 iterations, 10 instances, RS 100.
        let (runs_d, rs_d, iters_d, inst_d) = if full {
            (25, 100, 2 * n_bits * n_bits, 10)
        } else {
            (5, 10, 2 * n_bits * n_bits / 4, 3)
        };
        let cache_key_raw =
            match args.str_flag("cache-key", "canonical").as_str() {
                "canonical" | "orbit" => false,
                "raw" | "exact" => true,
                other => {
                    return Err(format!(
                        "--cache-key expects raw|canonical, got '{other}'"
                    ))
                }
            };
        Ok(ExpConfig {
            instance,
            scale: if full { Scale::Full } else { Scale::Smoke },
            runs: args.usize_flag("runs", runs_d)?,
            rs_runs: args.usize_flag("rs-runs", rs_d)?,
            iters: args.usize_flag("iters", iters_d)?,
            restarts: args.usize_flag("restarts", 10)?,
            instances: args.usize_flag("instances", inst_d)?,
            seed: args.u64_flag("seed", 1)?,
            out_dir: args.str_flag("out", "results"),
            use_xla: !args.bool_flag("no-xla"),
            workers: args.usize_flag(
                "workers",
                crate::util::threadpool::default_workers(),
            )?,
            batch_size: args.usize_flag("batch-size", 1)?.max(1),
            cache_key_raw,
        })
    }

    /// The experiment's loop configuration for a problem of `n_bits`
    /// bits — the shared [`BboConfig`] builder path (ISSUE 10) every
    /// consumer (`run`, `decompose`, the experiment harness and its
    /// ablations) chains from instead of re-spelling the struct
    /// literal.
    pub fn bbo_config(&self, n_bits: usize) -> BboConfig {
        BboConfig::smoke_scale(n_bits, self.iters)
            .with_restarts(self.restarts)
            .with_batch_size(self.batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn smoke_defaults() {
        let c = ExpConfig::from_args(&args(&[])).unwrap();
        assert_eq!(c.scale, Scale::Smoke);
        assert_eq!(c.runs, 5);
        assert_eq!(c.instances, 3);
        assert_eq!(c.instance.n, 8);
        assert!(c.iters < 2 * 24 * 24);
        assert_eq!(c.batch_size, 1);
        assert!(!c.cache_key_raw, "canonical cache keys are the default");
    }

    #[test]
    fn cache_key_flag_parses_and_rejects_garbage() {
        let c =
            ExpConfig::from_args(&args(&["--cache-key", "raw"])).unwrap();
        assert!(c.cache_key_raw);
        let c = ExpConfig::from_args(&args(&["--cache-key", "canonical"]))
            .unwrap();
        assert!(!c.cache_key_raw);
        assert!(
            ExpConfig::from_args(&args(&["--cache-key", "bogus"])).is_err()
        );
    }

    #[test]
    fn batch_size_flag_parses_and_clamps() {
        let c =
            ExpConfig::from_args(&args(&["--batch-size", "8"])).unwrap();
        assert_eq!(c.batch_size, 8);
        let c =
            ExpConfig::from_args(&args(&["--batch-size", "0"])).unwrap();
        assert_eq!(c.batch_size, 1);
    }

    #[test]
    fn full_scale_matches_paper() {
        let c = ExpConfig::from_args(&args(&["--full"])).unwrap();
        assert_eq!(c.scale, Scale::Full);
        assert_eq!(c.runs, 25);
        assert_eq!(c.rs_runs, 100);
        assert_eq!(c.iters, 1152); // 2 * 24^2
        assert_eq!(c.instances, 10);
    }

    #[test]
    fn overrides_win() {
        let c = ExpConfig::from_args(&args(&[
            "--full", "--runs", "3", "--iters", "50",
        ]))
        .unwrap();
        assert_eq!(c.runs, 3);
        assert_eq!(c.iters, 50);
    }
}
