//! Reporting substrate: ASCII tables, terminal line plots, CSV writers.
//!
//! Every experiment prints the same rows/series the paper reports and
//! writes machine-readable CSV to `results/` for offline plotting.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Render an ASCII table with a header row.
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let cols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(cols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            let _ = write!(out, "+{}", "-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        let _ = write!(out, "| {:w$} ", h, w = widths[i]);
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(cols) {
            let _ = write!(out, "| {:w$} ", cell, w = widths[i]);
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// Terminal line plot of one or more (label, series) on a log-y axis —
/// the residual-error convergence plots of Figs. 1/2/3/7.
pub fn ascii_plot_log(
    series: &[(String, Vec<f64>)],
    width: usize,
    height: usize,
) -> String {
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&', '~'];
    let floor = 1e-12;
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    let mut max_len = 0usize;
    for (_, ys) in series {
        max_len = max_len.max(ys.len());
        for &y in ys {
            let ly = y.max(floor).log10();
            lo = lo.min(ly);
            hi = hi.max(ly);
        }
    }
    if !lo.is_finite() || max_len == 0 {
        return "(no data)\n".into();
    }
    if hi - lo < 1e-9 {
        hi = lo + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (t, &y) in ys.iter().enumerate() {
            let xx = t * (width - 1) / max_len.max(2).saturating_sub(1).max(1);
            let ly = y.max(floor).log10();
            let frac = (ly - lo) / (hi - lo);
            let yy = ((1.0 - frac) * (height - 1) as f64).round() as usize;
            if xx < width && yy < height {
                grid[yy][xx] = mark;
            }
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "log10 residual  [{hi:.2} .. {lo:.2}]");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    let _ = writeln!(out, "+{}", "-".repeat(width));
    for (si, (label, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "  {} {}", marks[si % marks.len()], label);
    }
    out
}

/// Write rows as CSV (first row = header).  Creates parent directories.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Format a float compactly for tables.
pub fn fmt(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = ascii_table(
            &["algo", "hits"],
            &[
                vec!["nBOCS".into(), "91".into()],
                vec!["RS".into(), "9".into()],
            ],
        );
        assert!(t.contains("| algo  | hits |"));
        assert!(t.contains("| nBOCS | 91   |"));
        // Consistent line lengths.
        let lens: Vec<usize> =
            t.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn plot_contains_marks_and_legend() {
        let s = vec![
            ("a".to_string(), vec![1.0, 0.1, 0.01]),
            ("b".to_string(), vec![0.5, 0.5, 0.5]),
        ];
        let p = ascii_plot_log(&s, 40, 10);
        assert!(p.contains('*'));
        assert!(p.contains('o'));
        assert!(p.contains("a\n") || p.contains("a"));
    }

    #[test]
    fn plot_empty_series() {
        assert_eq!(ascii_plot_log(&[], 10, 5), "(no data)\n");
    }

    #[test]
    fn csv_roundtrip(){
        let dir = std::env::temp_dir().join("intdecomp_test_csv");
        let path = dir.join("t.csv");
        write_csv(&path, &["a", "b"], &[vec!["1".into(), "2".into()]])
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert!(fmt(12345.0).contains('e'));
        assert!(fmt(0.25).starts_with("0.25"));
    }
}
