//! Generic mixed-integer front-end — the paper's generalisation claim.
//!
//! The BBO machinery optimises any pseudo-Boolean black box through the
//! [`Oracle`] trait.  The paper's observation (Discussion): every MINLP
//! whose cost is *linear in the real variables given the binaries* can be
//! reduced to such a black box by eliminating the real variables with
//! least squares — exactly how the integer decomposition eliminates `C`.
//! [`LinearLsqMinlp`] packages that reduction for general problems (the
//! `minlp_feature_select` example uses it for subset-selection
//! regression).

use crate::cost::{BinMatrix, Problem};
use crate::linalg::{lu_solve, Matrix};

/// A pseudo-Boolean black-box objective over spins x ∈ {-1,+1}^n.
pub trait Oracle: Sync {
    /// Number of binary variables of the problem.
    fn n_bits(&self) -> usize;

    /// The black-box evaluation y = f(x).
    fn eval(&self, x: &[i8]) -> f64;

    /// Evaluate a whole acquisition batch concurrently across `workers`
    /// threads of the shared pool, preserving input order — the entry
    /// point the batched BBO loop uses.  The default fans
    /// [`Oracle::eval`] over
    /// [`crate::util::threadpool::parallel_map`] (each pool thread
    /// reuses its own evaluation scratch); implementors with a cheaper
    /// native batch path (e.g. [`crate::cost::Problem::cost_batch`])
    /// override it.
    fn eval_batch(&self, xs: &[Vec<i8>], workers: usize) -> Vec<f64> {
        crate::util::threadpool::parallel_map(
            xs.iter().map(|x| x.as_slice()).collect(),
            workers,
            |x| self.eval(x),
        )
    }

    /// Known symmetry orbit of x (same objective value), excluding x
    /// itself — used by the data-augmentation variant (paper Fig. 3).
    fn equivalents(&self, _x: &[i8]) -> Vec<Vec<i8>> {
        Vec::new()
    }
}

impl Oracle for Problem {
    fn n_bits(&self) -> usize {
        Problem::n_bits(self)
    }

    fn eval(&self, x: &[i8]) -> f64 {
        self.cost_spins(x)
    }

    fn eval_batch(&self, xs: &[Vec<i8>], workers: usize) -> Vec<f64> {
        let ms: Vec<BinMatrix> = xs
            .iter()
            .map(|x| BinMatrix::from_spins(self.n(), self.k, x))
            .collect();
        self.cost_batch(&ms, workers)
    }

    /// All K!·2^K − 1 column permutation / sign-flip variants.
    fn equivalents(&self, x: &[i8]) -> Vec<Vec<i8>> {
        let m = BinMatrix::from_spins(self.n(), self.k, x);
        crate::bruteforce::expand_orbit(&[m])
            .into_iter()
            .map(|b| b.data)
            .filter(|d| d.as_slice() != x)
            .collect()
    }
}

/// MINLP with least-squares-eliminable real part:
///
/// ```text
///   min_{x, z}  || A diag(gate(x)) z - b ||²  + ρ · |{i : x_i = +1}|
/// ```
///
/// where `gate(x_i) = (1 + x_i)/2` activates column i of the design matrix
/// `A` — i.e. subset-selection least squares with a cardinality penalty.
/// Given x the optimal real vector z solves the normal equations on the
/// active columns, so the objective is a pure function of the binaries.
pub struct LinearLsqMinlp {
    /// Design matrix A (m × n).
    pub a: Matrix,
    /// Target b (m).
    pub b: Vec<f64>,
    /// Per-active-column penalty ρ.
    pub rho: f64,
}

impl LinearLsqMinlp {
    /// Problem `min ||A diag(gate(x)) z - b||² + ρ·|active|`.
    pub fn new(a: Matrix, b: Vec<f64>, rho: f64) -> Self {
        assert_eq!(a.rows, b.len());
        LinearLsqMinlp { a, b, rho }
    }

    /// Optimal real coefficients for the active set (None on empty set).
    pub fn solve_real(&self, x: &[i8]) -> Option<(Vec<usize>, Vec<f64>)> {
        let active: Vec<usize> = (0..self.a.cols)
            .filter(|&i| x[i] == 1)
            .collect();
        if active.is_empty() {
            return None;
        }
        let m = self.a.rows;
        let s = active.len();
        // Normal equations on the active columns (+ tiny ridge).
        let mut g = Matrix::zeros(s, s);
        let mut rhs = vec![0.0; s];
        for r in 0..m {
            let row = self.a.row(r);
            for (ii, &ci) in active.iter().enumerate() {
                let v = row[ci];
                rhs[ii] += v * self.b[r];
                for (jj, &cj) in active.iter().enumerate().skip(ii) {
                    g[(ii, jj)] += v * row[cj];
                }
            }
        }
        for i in 0..s {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
            g[(i, i)] += 1e-10;
        }
        let z = lu_solve(&g, &rhs)?;
        Some((active, z))
    }
}

impl Oracle for LinearLsqMinlp {
    fn n_bits(&self) -> usize {
        self.a.cols
    }

    fn eval(&self, x: &[i8]) -> f64 {
        let bb: f64 = self.b.iter().map(|v| v * v).sum();
        match self.solve_real(x) {
            None => bb,
            Some((active, z)) => {
                // Residual via ||b||² - z^T A_S^T b (LSQ identity).
                let mut atb = 0.0;
                for r in 0..self.a.rows {
                    let row = self.a.row(r);
                    let mut pred = 0.0;
                    for (ii, &ci) in active.iter().enumerate() {
                        pred += row[ci] * z[ii];
                    }
                    atb += pred * self.b[r];
                }
                (bb - atb).max(0.0) + self.rho * active.len() as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn planted(rng: &mut Rng, m: usize, n: usize, truth: &[usize])
        -> LinearLsqMinlp {
        let a = Matrix::from_vec(m, n, rng.normals(m * n));
        let z: Vec<f64> = (0..n)
            .map(|i| if truth.contains(&i) { 2.0 } else { 0.0 })
            .collect();
        let b = a.matvec(&z);
        LinearLsqMinlp::new(a, b, 0.01)
    }

    #[test]
    fn true_support_has_near_zero_residual() {
        let mut rng = Rng::new(700);
        let p = planted(&mut rng, 30, 8, &[1, 4]);
        let mut x = vec![-1i8; 8];
        x[1] = 1;
        x[4] = 1;
        let cost = p.eval(&x);
        assert!(cost < 0.03, "cost {cost}"); // 2 * rho + ~0 residual
    }

    #[test]
    fn true_support_beats_others_exhaustively() {
        let mut rng = Rng::new(701);
        let p = planted(&mut rng, 40, 6, &[0, 3]);
        let mut best = (0u32, f64::INFINITY);
        for bits in 0..(1u32 << 6) {
            let x: Vec<i8> = (0..6)
                .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
                .collect();
            let c = p.eval(&x);
            if c < best.1 {
                best = (bits, c);
            }
        }
        assert_eq!(best.0, (1 << 0) | (1 << 3));
    }

    #[test]
    fn empty_set_costs_full_norm() {
        let mut rng = Rng::new(702);
        let p = planted(&mut rng, 20, 5, &[2]);
        let x = vec![-1i8; 5];
        let bb: f64 = p.b.iter().map(|v| v * v).sum();
        assert!((p.eval(&x) - bb).abs() < 1e-9);
    }

    #[test]
    fn problem_oracle_equivalents_have_equal_cost() {
        let cfg = crate::instance::InstanceConfig {
            n: 5,
            d: 8,
            k: 2,
            gamma: 0.8,
            seed: 3,
        };
        let p = crate::instance::generate(&cfg, 0);
        let mut rng = Rng::new(703);
        let x = rng.spins(10);
        let y = p.eval(&x);
        let eq = Oracle::equivalents(&p, &x);
        // Up to 2! * 2^2 - 1 = 7 equivalents for a generic x (fewer when
        // the orbit is degenerate, e.g. m2 = ±m1).
        assert!(!eq.is_empty() && eq.len() <= 7, "len {}", eq.len());
        for e in &eq {
            assert!((p.eval(e) - y).abs() < 1e-9);
            assert_ne!(e.as_slice(), x.as_slice());
        }
    }
}
