//! Memoised black-box evaluation.
//!
//! The BBO loop re-proposes candidates — across solver restarts, across
//! iterations (FMQA's deterministic trap re-acquires the same `x` for many
//! consecutive steps), and across the symmetry orbit — and every repeat
//! pays the `O(K·N²)` masked-Gram–Schmidt cost evaluation again.
//! [`CostCache`] memoises costs keyed on [`BinMatrix`] (`Hash + Eq`), and
//! [`CachedOracle`] wraps any [`Oracle`] with it transparently.
//!
//! The cache is thread-safe (a `Mutex` map plus atomic hit/miss counters)
//! so a single instance can back concurrent evaluations; values are pure
//! functions of the key, so a racing duplicate evaluation inserts the same
//! value and costs only the wasted work.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Per-entry bookkeeping overhead added to the key payload when
/// estimating a cache's memory footprint: hash-map slot, stored `f64`,
/// `BinMatrix` header.  A deliberate round figure — the registry's byte
/// budget is a sizing knob, not an allocator audit.
const ENTRY_OVERHEAD: usize = 64;

use crate::cost::BinMatrix;
use crate::minlp::Oracle;

/// Hit/miss accounting snapshot of a [`CostCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to evaluate (racing duplicates both count).
    pub misses: u64,
}

impl CacheStats {
    /// Total lookups (one per `eval` call routed through the cache).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.lookups();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Memoised cost table keyed on the binary candidate matrix.
///
/// ```
/// use intdecomp::engine::{CachedOracle, CostCache};
/// use intdecomp::instance::{generate, InstanceConfig};
/// use intdecomp::minlp::Oracle;
///
/// let icfg = InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 3 };
/// let p = generate(&icfg, 0);
/// let cache = CostCache::new();
/// let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
/// let x = vec![1i8; p.n_bits()];
/// let y1 = oracle.eval(&x);
/// let y2 = oracle.eval(&x); // served from the cache
/// assert_eq!(y1, y2);
/// let s = cache.stats();
/// assert_eq!((s.hits, s.misses), (1, 1));
/// ```
#[derive(Default)]
pub struct CostCache {
    map: Mutex<HashMap<BinMatrix, f64>>,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes: AtomicUsize,
    canonical: bool,
}

impl CostCache {
    /// Exact-key cache: a candidate hits only if the very same `M` was
    /// evaluated before.  This never changes any numeric result, so runs
    /// through the cache stay bit-identical to uncached runs.
    pub fn new() -> Self {
        CostCache::default()
    }

    /// Orbit-folding cache (the engine's default key mode): keys are
    /// canonicalised ([`BinMatrix::canonical`]), so all `K!·2^K`
    /// symmetry-equivalent candidates share one entry.  The stored value
    /// is the cost of the canonical *representative* — mathematically
    /// exact (the cost is orbit-invariant) and a pure function of the
    /// key, so racing duplicate evaluations and worker counts can never
    /// change a result; it can differ from a direct evaluation of the
    /// queried member in the last ulps, so opt out
    /// ([`CostCache::new`] / `CacheKeyMode::Exact`) where bit-identical
    /// replay of the uncached run matters.
    pub fn with_canonical_keys() -> Self {
        CostCache { canonical: true, ..Default::default() }
    }

    /// Look `m` up; on a miss, evaluate (outside the lock) and insert.
    /// The closure receives the *key* to evaluate: `m` itself with exact
    /// keys, the orbit's canonical representative with canonical keys —
    /// which keeps every stored value a pure function of its key.  The
    /// hit path allocates nothing with exact keys: the candidate is only
    /// cloned when it has to be stored.
    pub fn get_or_eval(
        &self,
        m: &BinMatrix,
        eval: impl FnOnce(&BinMatrix) -> f64,
    ) -> f64 {
        if self.canonical {
            let key = m.canonical();
            if let Some(&c) = self.map.lock().unwrap().get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return c;
            }
            let c = eval(&key);
            self.misses.fetch_add(1, Ordering::Relaxed);
            let weight = key.as_spins().len() + ENTRY_OVERHEAD;
            if self.map.lock().unwrap().insert(key, c).is_none() {
                self.bytes.fetch_add(weight, Ordering::Relaxed);
            }
            return c;
        }
        if let Some(&c) = self.map.lock().unwrap().get(m) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return c;
        }
        let c = eval(m);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let weight = m.as_spins().len() + ENTRY_OVERHEAD;
        if self.map.lock().unwrap().insert(m.clone(), c).is_none() {
            self.bytes.fetch_add(weight, Ordering::Relaxed);
        }
        c
    }

    /// Distinct keys stored.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Estimated resident bytes: per-entry key payload (one byte per
    /// spin) plus a flat bookkeeping overhead.  Monotone over a cache's
    /// lifetime (entries are never removed); the serve registry sums
    /// this across instances to enforce its `--cache-budget-bytes`.
    pub fn approx_bytes(&self) -> usize {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Snapshot of the hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// An [`Oracle`] adaptor that routes every evaluation through a
/// [`CostCache`].  Purely transparent with exact keys: same values, same
/// call order, just no duplicate work.
///
/// [`CachedOracle::with_shared`] adds a second, process-wide cache level
/// consulted only on a local miss — the cross-request warm store of the
/// serve daemon.  The local cache's map and hit/miss counters stay
/// identical to an unshared run (the shared level only short-circuits
/// the *evaluation*, never the lookup), which is what keeps served
/// reports byte-identical to the cold CLI path.
pub struct CachedOracle<'a> {
    inner: &'a dyn Oracle,
    cache: &'a CostCache,
    shared: Option<&'a CostCache>,
    n: usize,
    k: usize,
}

impl<'a> CachedOracle<'a> {
    /// `n`/`k` give the `BinMatrix` shape of the flat spin vectors
    /// (`n_bits = n * k`).
    pub fn new(
        inner: &'a dyn Oracle,
        cache: &'a CostCache,
        n: usize,
        k: usize,
    ) -> Self {
        assert_eq!(inner.n_bits(), n * k, "oracle bits != n * k");
        CachedOracle { inner, cache, shared: None, n, k }
    }

    /// Like [`CachedOracle::new`] with a second-level `shared` cache
    /// consulted on local misses.  **Soundness**: both levels must use
    /// the same key mode, and `shared` must only ever be fed by oracles
    /// of the *same problem* (cost is a function of `W` as well as the
    /// key — the serve daemon keys its registry per instance layer).
    /// With canonical keys both levels store the canonical
    /// representative's cost, a pure function of the key, so values
    /// coming back from the shared level are bit-identical to the ones
    /// a cold run would compute.
    pub fn with_shared(
        inner: &'a dyn Oracle,
        cache: &'a CostCache,
        shared: &'a CostCache,
        n: usize,
        k: usize,
    ) -> Self {
        assert_eq!(inner.n_bits(), n * k, "oracle bits != n * k");
        CachedOracle { inner, cache, shared: Some(shared), n, k }
    }
}

impl Oracle for CachedOracle<'_> {
    fn n_bits(&self) -> usize {
        self.inner.n_bits()
    }

    fn eval(&self, x: &[i8]) -> f64 {
        let m = BinMatrix::from_spins(self.n, self.k, x);
        match self.shared {
            Some(shared) => self.cache.get_or_eval(&m, |key| {
                shared.get_or_eval(key, |k| self.inner.eval(k.as_spins()))
            }),
            None => self
                .cache
                .get_or_eval(&m, |key| self.inner.eval(key.as_spins())),
        }
    }

    fn equivalents(&self, x: &[i8]) -> Vec<Vec<i8>> {
        self.inner.equivalents(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::{generate, InstanceConfig};
    use crate::util::rng::Rng;

    fn tiny() -> crate::cost::Problem {
        let cfg = InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 12 };
        generate(&cfg, 0)
    }

    #[test]
    fn counts_hits_and_misses() {
        let p = tiny();
        let cache = CostCache::new();
        let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
        let mut rng = Rng::new(1);
        let x = rng.spins(p.n_bits());
        let y1 = oracle.eval(&x);
        let y2 = oracle.eval(&x);
        assert_eq!(y1, y2);
        assert_eq!(y1, p.cost_spins(&x));
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
        // A guaranteed-distinct candidate: flip one entry.
        let mut x2 = x.clone();
        x2[0] = -x2[0];
        let _ = oracle.eval(&x2);
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 2 });
        assert_eq!(cache.len(), 2);
        assert!(!cache.is_empty());
    }

    #[test]
    fn exact_keys_distinguish_orbit_members() {
        let p = tiny();
        let cache = CostCache::new();
        let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
        let mut rng = Rng::new(2);
        let m = crate::cost::BinMatrix::new(4, 2, rng.spins(8));
        let t = m.transformed(&[1, 0], &[1, -1]);
        let _ = oracle.eval(m.as_spins());
        let _ = oracle.eval(t.as_spins());
        // Orbit member is a different exact key -> two misses.
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn canonical_keys_fold_the_orbit() {
        let p = tiny();
        let cache = CostCache::with_canonical_keys();
        let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
        let mut rng = Rng::new(3);
        let m = crate::cost::BinMatrix::new(4, 2, rng.spins(8));
        let t = m.transformed(&[1, 0], &[1, -1]);
        let y1 = oracle.eval(m.as_spins());
        let y2 = oracle.eval(t.as_spins());
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
        // Same stored float, and orbit-invariance says it's the true cost.
        assert_eq!(y1, y2);
        assert!((y2 - p.cost(&t)).abs() < 1e-9 * (1.0 + y2));
    }

    #[test]
    fn approx_bytes_counts_fresh_inserts_once() {
        let p = tiny();
        let cache = CostCache::new();
        let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
        assert_eq!(cache.approx_bytes(), 0);
        let mut rng = Rng::new(7);
        let x = rng.spins(p.n_bits());
        let _ = oracle.eval(&x);
        let per_entry = p.n_bits() + ENTRY_OVERHEAD;
        assert_eq!(cache.approx_bytes(), per_entry);
        let _ = oracle.eval(&x); // hit: no growth
        assert_eq!(cache.approx_bytes(), per_entry);
        let mut x2 = x.clone();
        x2[0] = -x2[0];
        let _ = oracle.eval(&x2);
        assert_eq!(cache.approx_bytes(), 2 * per_entry);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn concurrent_use_is_consistent() {
        let p = tiny();
        let cache = CostCache::new();
        let oracle = CachedOracle::new(&p, &cache, p.n(), p.k);
        // 8 guaranteed-distinct candidates (bit patterns), each queried 4
        // times, across workers.
        let cands: Vec<Vec<i8>> = (0..8u32)
            .map(|i| {
                (0..p.n_bits())
                    .map(|b| if (i >> b) & 1 == 1 { 1 } else { -1 })
                    .collect()
            })
            .collect();
        let queries: Vec<Vec<i8>> = (0..32)
            .map(|i| cands[i % 8].clone())
            .collect();
        let got = crate::util::threadpool::parallel_map(
            queries.clone(),
            4,
            |x| oracle.eval(&x),
        );
        for (x, y) in queries.iter().zip(&got) {
            assert_eq!(*y, p.cost_spins(x));
        }
        let s = cache.stats();
        assert_eq!(s.lookups(), 32);
        assert_eq!(cache.len(), 8);
        // Racing first evaluations may double-miss, but never more than
        // one extra miss per key per worker overlap.
        assert!(s.misses >= 8 && s.misses <= 32);
    }
}
