//! Parallel batched compression engine — the multi-layer, multi-core
//! driver the edge-computing scenario needs.
//!
//! The BBO pipeline is embarrassingly parallel at three levels, and this
//! module wires all three through `util::threadpool`:
//!
//! 1. **Solver restarts** within one BBO iteration —
//!    [`crate::solvers::solve_best_parallel`], enabled per run via
//!    [`crate::bbo::BboConfig::restart_workers`].
//! 2. **Batched acquisition + candidate evaluation** —
//!    [`crate::bbo::BboConfig::batch_size`] acquires the top-k distinct
//!    candidates per surrogate fit ([`crate::solvers::solve_batch`]) and
//!    evaluates them concurrently; repeated candidates are memoised by
//!    [`cache::CostCache`] / [`cache::CachedOracle`], so re-acquired `M`s
//!    never re-pay the `O(K·N²)` cost evaluation.
//! 3. **Whole-model compression** — [`Engine::compress_all`] fans a batch
//!    of [`CompressionJob`]s (one per layer matrix) across workers pulling
//!    from a shared queue, with per-job seeds; [`Engine::compress_each`]
//!    is the streaming variant delivering results in job order as they
//!    complete — the checkpoint hook of the cross-process
//!    [`crate::shard`] subsystem (one OS process per shard, level 4 of
//!    the parallelism stack).
//!
//! All three levels share one set of long-lived threads: the process-wide
//! [`crate::util::threadpool::WorkerPool`], reused across every BBO
//! iteration and every `compress_all` call, so per-iteration fan-outs pay
//! a queue push instead of a thread spawn.
//!
//! Determinism contract: results are a pure function of each job's seed
//! and config — independent of `workers`, job interleaving, the restart
//! fan-out width and the batched-evaluation interleaving.  Jobs default
//! to the orbit-folding cache ([`CacheKeyMode::Canonical`], the ROADMAP
//! open item): every stored cost is the canonical representative's, so
//! results stay deterministic but can differ from an uncached run in the
//! last ulps.  With [`CacheKeyMode::Exact`] plus the default
//! `restart_workers = 1` and `batch_size = 1` every job is bit-identical
//! to a plain serial [`bbo::run`] with the same seed, which the engine
//! regression tests assert.
//!
//! Jobs may attach a process-wide *second* cache level
//! ([`CompressionJob::shared_cache`] — the serve daemon's cross-request
//! warm store).  It is consulted only on local-cache misses and only in
//! canonical mode, so it shortens wall-clock without changing any
//! result or any per-job cache statistic.

pub mod cache;

pub use cache::{CacheStats, CachedOracle, CostCache};
pub use crate::util::cancel::{CancelCause, CancelToken};

use std::collections::BTreeMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::channel;
use std::sync::Arc;

use crate::bbo::{
    self, Algorithm, Backends, BboConfig, BboRun, RunError, StateError,
    SurrogateState, WarmStart,
};
use crate::cost::{compression_ratio, BinMatrix, Problem};
use crate::linalg::NumericError;
use crate::report;
use crate::solvers::{self, IsingSolver};
use crate::util::threadpool::{default_workers, WorkerPool};

/// Float width used for all size/ratio reporting (the paper's f32 layers).
const FLOAT_BITS: usize = 32;

/// Cache-key policy of a job's memoised oracle ([`CachedOracle`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheKeyMode {
    /// Exact keys: a candidate hits only if the very same `M` was seen.
    /// Bit-identical replay of the uncached serial run.
    Exact,
    /// Canonical-orbit keys (the jobs' default): all `K!·2^K`
    /// symmetry-equivalent candidates share one entry holding the
    /// canonical representative's cost — deterministic, orbit-exact,
    /// but last-ulp different from a raw run.
    Canonical,
}

/// Engine-level parallelism knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Concurrent compression jobs.
    pub workers: usize,
    /// Restart fan-out *within* each job (`1` = legacy serial restarts,
    /// bit-identical to `bbo::run`; `> 1` = forked per-restart streams).
    pub restart_workers: usize,
    /// Acquisition batch size *within* each job (`1` = the paper's
    /// serial loop; `k > 1` = one surrogate fit per k candidates, all
    /// evaluated concurrently — see
    /// [`crate::bbo::BboConfig::batch_size`]).  Values `> 1` override
    /// the per-job [`crate::bbo::BboConfig`].
    pub batch_size: usize,
    /// Panic-containment policy for [`Engine::try_compress_each`]
    /// (ISSUE 9).  `false` (the default, the CLI/test policy): a
    /// panicking job is re-raised on the calling thread
    /// (`resume_unwind`), matching the
    /// [`crate::util::threadpool::parallel_map`] policy.  `true` (the
    /// serve daemon's policy): a per-job unwind is caught at the pool
    /// boundary and reported as [`JobError::Panicked`], so one
    /// pathological request degrades one response while the process —
    /// and every other connection — keeps serving.
    pub contain_panics: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: default_workers(),
            restart_workers: 1,
            batch_size: 1,
            contain_panics: false,
        }
    }
}

/// Why a job failed inside [`Engine::try_compress_each`].
#[derive(Clone, Debug, PartialEq)]
pub enum JobError {
    /// The job's [`CancelToken`] tripped (caller cancel or deadline).
    Cancelled(CancelCause),
    /// A typed numeric fault the BBO degraded mode could not absorb
    /// (e.g. every oracle cost was non-finite).
    Numeric(NumericError),
    /// The job panicked and [`EngineConfig::contain_panics`] was set:
    /// the unwind was caught at the pool boundary and the payload
    /// rendered to a message.
    Panicked {
        /// The panic payload (downcast to a string when possible).
        message: String,
    },
    /// The job's [`CompressionJob::warm_start`] donor state was
    /// rejected (schema, shape or surrogate-kind mismatch).  The job
    /// never started — callers decide whether to retry cold.
    Warm(StateError),
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Cancelled(cause) => write!(f, "{cause}"),
            JobError::Numeric(e) => write!(f, "{e}"),
            JobError::Panicked { message } => {
                write!(f, "job panicked: {message}")
            }
            JobError::Warm(e) => write!(f, "warm start rejected: {e}"),
        }
    }
}

impl std::error::Error for JobError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            JobError::Numeric(e) => Some(e),
            JobError::Warm(e) => Some(e),
            _ => None,
        }
    }
}

impl From<RunError> for JobError {
    fn from(e: RunError) -> Self {
        match e {
            RunError::Cancelled(cause) => JobError::Cancelled(cause),
            RunError::Numeric(e) => JobError::Numeric(e),
            RunError::Warm(e) => JobError::Warm(e),
        }
    }
}

/// Render a caught panic payload to a human-readable message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One layer matrix to compress: problem + algorithm + budget + seed.
pub struct CompressionJob {
    /// Display name, e.g. the layer label.
    pub name: String,
    /// The layer's compression instance (W, K and the cost oracle).
    pub problem: Problem,
    /// BBO algorithm to optimise the binary factor with.
    pub algo: Algorithm,
    /// Ising solver minimising the surrogate each iteration.
    pub solver: Box<dyn IsingSolver>,
    /// Loop budget and parallelism knobs for this job.
    pub cfg: BboConfig,
    /// Seed making the job's result reproducible.
    pub seed: u64,
    /// Cache-key policy of the job's memoised oracle (default:
    /// [`CacheKeyMode::Canonical`] — orbit folding).
    pub cache_mode: CacheKeyMode,
    /// Optional process-wide second cache level consulted on local
    /// misses — the serve daemon's cross-request warm store.  Only
    /// honoured under [`CacheKeyMode::Canonical`] (where stored values
    /// are pure functions of the canonical key, so sharing cannot
    /// change any result); silently ignored in
    /// [`CacheKeyMode::Exact`] mode, whose promise is bit-identical
    /// replay of the *uncached* run.  Must be fed only by jobs of the
    /// same problem instance and layer.
    pub shared_cache: Option<Arc<CostCache>>,
    /// Cooperative cancellation token, polled at every BBO iteration
    /// boundary ([`crate::bbo::run_cancellable`]).  The default
    /// ([`CancelToken::never`]) never trips; a tripped token makes the
    /// job unwind with its [`CancelCause`] — observable only through
    /// [`Engine::try_compress_each`] (the infallible entry points treat
    /// cancellation as a bug and panic).  A job that *completes* under
    /// a token is bit-identical to one run without it.
    pub cancel: CancelToken,
    /// Optional warm-start input: a prior run's exported surrogate
    /// state (and best point) seeding this job instead of the random
    /// init design — see [`crate::bbo::run_warm`].  `None` (the
    /// default) is the cold path, bit-identical to pre-warm-start
    /// builds.
    pub warm_start: Option<WarmStart>,
    /// When set, the job's [`JobResult::state`] carries the exported
    /// [`SurrogateState`] for future warm starts (default: `false`, no
    /// export cost).
    pub export_state: bool,
}

impl CompressionJob {
    /// Job with the paper-default algorithm (nBOCS, σ² = 0.1) and SA
    /// solver, at `iters` acquisition iterations.
    pub fn new(
        name: impl Into<String>,
        problem: Problem,
        iters: usize,
        seed: u64,
    ) -> Self {
        let cfg = BboConfig::smoke_scale(problem.n_bits(), iters);
        CompressionJob {
            name: name.into(),
            problem,
            algo: Algorithm::Nbocs { sigma2: 0.1 },
            solver: Box::new(solvers::sa::SimulatedAnnealing::default()),
            cfg,
            seed,
            cache_mode: CacheKeyMode::Canonical,
            shared_cache: None,
            cancel: CancelToken::never(),
            warm_start: None,
            export_state: false,
        }
    }

    /// Replace the BBO algorithm (builder style).
    pub fn with_algo(mut self, algo: Algorithm) -> Self {
        self.algo = algo;
        self
    }

    /// Replace the Ising solver (builder style).
    pub fn with_solver(mut self, solver: Box<dyn IsingSolver>) -> Self {
        self.solver = solver;
        self
    }

    /// Replace the whole loop configuration (builder style) — the hook
    /// [`crate::shard::ModelSpec::job`] uses to install a
    /// [`BboConfig`] assembled through the shared `with_*` builder
    /// chain.
    pub fn with_bbo_config(mut self, cfg: BboConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Set the acquisition batch size for this job (builder style).
    pub fn with_batch_size(mut self, batch_size: usize) -> Self {
        self.cfg.batch_size = batch_size.max(1);
        self
    }

    /// Select the evaluation-cache key policy (builder style);
    /// [`CacheKeyMode::Exact`] restores bit-identical replay of the
    /// uncached serial run.
    pub fn with_cache_mode(mut self, mode: CacheKeyMode) -> Self {
        self.cache_mode = mode;
        self
    }

    /// Attach a process-wide second-level cache (builder style) — see
    /// [`CompressionJob::shared_cache`] for the soundness conditions.
    pub fn with_shared_cache(mut self, shared: Arc<CostCache>) -> Self {
        self.shared_cache = Some(shared);
        self
    }

    /// Attach a cancellation token (builder style) — see
    /// [`CompressionJob::cancel`].
    pub fn with_cancel(mut self, cancel: CancelToken) -> Self {
        self.cancel = cancel;
        self
    }

    /// Seed the job from a prior run's exported state (builder style)
    /// — see [`CompressionJob::warm_start`].
    pub fn with_warm_start(mut self, warm: WarmStart) -> Self {
        self.warm_start = Some(warm);
        self
    }

    /// Request the final surrogate state on [`JobResult::state`]
    /// (builder style) — see [`CompressionJob::export_state`].
    pub fn with_state_export(mut self) -> Self {
        self.export_state = true;
        self
    }
}

/// Output of one job: the full BBO trace plus compression metrics and
/// cache accounting.
pub struct JobResult {
    /// Job display name (the layer label).
    pub name: String,
    /// Layer rows N.
    pub n: usize,
    /// Layer columns D.
    pub d: usize,
    /// Decomposition rank K.
    pub k: usize,
    /// Full BBO trace of the job.
    pub run: BboRun,
    /// The winning binary factor M.
    pub best_m: BinMatrix,
    /// Hit/miss accounting of the job's evaluation cache.
    pub cache: CacheStats,
    /// Compressed/original size at 32-bit floats.
    pub ratio: f64,
    /// `||f(M)|| / ||W||` of the winner.
    pub normalised_error: f64,
    /// The final surrogate state, present iff the job asked for it via
    /// [`CompressionJob::export_state`] — the donor document for a
    /// future warm start.
    pub state: Option<SurrogateState>,
    /// Whether this job was warm-started ([`CompressionJob::warm_start`]
    /// was present and accepted).
    pub warm: bool,
}

/// The compression engine: a configuration plus `compress_all`.
///
/// ```
/// use intdecomp::engine::{CompressionJob, Engine, EngineConfig};
/// use intdecomp::instance::{generate, InstanceConfig};
///
/// let icfg = InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 9 };
/// let jobs: Vec<CompressionJob> = (0..2)
///     .map(|i| {
///         CompressionJob::new(
///             format!("layer{i}"),
///             generate(&icfg, i),
///             6,          // acquisition iterations
///             42 + i as u64,
///         )
///         .with_batch_size(3)
///     })
///     .collect();
/// let eng = Engine::new(EngineConfig {
///     workers: 2,
///     batch_size: 1, // per-job cfg (3, above) wins
///     ..Default::default()
/// });
/// let results = eng.compress_all(jobs);
/// assert_eq!(results.len(), 2);
/// assert!(results.iter().all(|r| r.ratio > 0.0 && r.ratio < 1.0));
/// ```
pub struct Engine {
    /// Parallelism configuration applied to every `compress_all` call.
    pub cfg: EngineConfig,
}

impl Engine {
    /// Engine with an explicit configuration.
    pub fn new(cfg: EngineConfig) -> Self {
        Engine { cfg }
    }

    /// `workers` concurrent jobs, serial restarts and serial (k = 1)
    /// acquisition inside each.
    pub fn with_workers(workers: usize) -> Self {
        Engine {
            cfg: EngineConfig { workers, ..Default::default() },
        }
    }

    /// Compress every job, fanning jobs across `cfg.workers` threads.
    /// Results come back in job order regardless of scheduling, and each
    /// is a pure function of the job (see module docs), so any worker
    /// count yields identical output.
    ///
    /// Panics if a job carries a tripped [`CancelToken`] — use
    /// [`Engine::try_compress_each`] for cancellable work.
    pub fn compress_all(&self, jobs: Vec<CompressionJob>) -> Vec<JobResult> {
        let mut out = Vec::with_capacity(jobs.len());
        self.compress_each(jobs, |_, result| out.push(result));
        out
    }

    /// Compress every job like [`Engine::compress_all`], but deliver
    /// each [`JobResult`] to `sink` **in job order, as soon as it and
    /// every earlier job have finished** — the streaming entry point
    /// the shard worker's checkpoint log is built on
    /// ([`crate::shard::run_shard`] appends one durable record per
    /// sink call).
    ///
    /// Panics if a job carries a tripped [`CancelToken`] — use
    /// [`Engine::try_compress_each`] for cancellable work.
    pub fn compress_each<F>(&self, jobs: Vec<CompressionJob>, sink: F)
    where
        F: FnMut(usize, JobResult),
    {
        if let Err(e) = self.try_compress_each(jobs, sink) {
            panic!(
                "job failed ({e}) on an infallible engine entry point; \
                 fallible jobs go through try_compress_each"
            );
        }
    }

    /// The fallible streaming core under [`Engine::compress_each`]:
    /// deliver each [`JobResult`] to `sink` in job order as soon as it
    /// and every earlier job have finished, or stop early with the
    /// first (lowest job index) [`JobError`] once a job fails —
    /// cancellation, a typed numeric fault, or (with
    /// [`EngineConfig::contain_panics`]) a caught panic.
    ///
    /// Up to `cfg.workers` jobs run concurrently on the process-wide
    /// pool; out-of-order completions are buffered so the sink always
    /// observes the prefix `0, 1, 2, ..` of finished jobs, and results
    /// are identical to [`Engine::compress_all`] for any worker count.
    /// With `cfg.workers == 1` jobs run inline on the calling thread,
    /// the bit-for-bit legacy serial path.  A panicking job is
    /// re-raised on the calling thread once observed, matching the
    /// [`crate::util::threadpool::parallel_map`] panic policy — unless
    /// `contain_panics` is set, in which case the unwind is caught at
    /// the pool boundary and reported as [`JobError::Panicked`] so the
    /// process (the serve daemon and its other connections) keeps
    /// running.
    ///
    /// On failure: no further jobs are submitted, in-flight jobs are
    /// drained (cancelled jobs observe the shared token at their next
    /// iteration boundary, so the drain is prompt), the sink never sees
    /// a job at or past the failed index, and `Err` is returned only
    /// after every spawned job has left the pool — the caller can
    /// release resources (e.g. the serve daemon's admission permit)
    /// knowing no stray job still runs.
    pub fn try_compress_each<F>(
        &self,
        jobs: Vec<CompressionJob>,
        mut sink: F,
    ) -> Result<(), JobError>
    where
        F: FnMut(usize, JobResult),
    {
        let restart_workers = self.cfg.restart_workers;
        let batch_size = self.cfg.batch_size;
        let contain = self.cfg.contain_panics;
        let cap = self.cfg.workers.max(1);
        if cap == 1 || jobs.len() <= 1 {
            for (i, job) in jobs.into_iter().enumerate() {
                let out = if contain {
                    match catch_unwind(AssertUnwindSafe(|| {
                        run_job(job, restart_workers, batch_size)
                    })) {
                        Ok(out) => out,
                        Err(payload) => Err(JobError::Panicked {
                            message: panic_message(payload.as_ref()),
                        }),
                    }
                } else {
                    run_job(job, restart_workers, batch_size)
                };
                sink(i, out?);
            }
            return Ok(());
        }
        let pool = WorkerPool::global();
        let (tx, rx) = channel();
        let mut queue = jobs.into_iter().enumerate();
        let mut in_flight = 0usize;
        let mut pending: BTreeMap<usize, JobResult> = BTreeMap::new();
        let mut next_emit = 0usize;
        let mut failed: Option<(usize, JobError)> = None;
        loop {
            // Keep up to `cap` jobs on the pool (none once failed).
            while in_flight < cap && failed.is_none() {
                let Some((i, job)) = queue.next() else { break };
                let tx = tx.clone();
                pool.submit(move || {
                    let out = catch_unwind(AssertUnwindSafe(|| {
                        run_job(job, restart_workers, batch_size)
                    }));
                    let _ = tx.send((i, out));
                });
                in_flight += 1;
            }
            if in_flight == 0 {
                break;
            }
            let (i, out) = rx
                .recv()
                .expect("engine job dropped its result channel");
            in_flight -= 1;
            // Remember the earliest failed job; later completions may
            // still fill the sink's prefix below it.
            let mut record_failure = |e: JobError, failed: &mut Option<(usize, JobError)>| {
                let earliest = match failed {
                    Some((j, _)) => i < *j,
                    None => true,
                };
                if earliest {
                    *failed = Some((i, e));
                }
            };
            match out {
                Ok(Ok(result)) => {
                    pending.insert(i, result);
                }
                Ok(Err(e)) => record_failure(e, &mut failed),
                Err(payload) => {
                    if contain {
                        record_failure(
                            JobError::Panicked {
                                message: panic_message(payload.as_ref()),
                            },
                            &mut failed,
                        );
                    } else {
                        resume_unwind(payload)
                    }
                }
            }
            // Emit the finished prefix in job order; a failed index
            // never enters `pending`, so emission stops at the gap.
            while let Some(result) = pending.remove(&next_emit) {
                if failed.as_ref().is_some_and(|(j, _)| next_emit >= *j) {
                    break;
                }
                sink(next_emit, result);
                next_emit += 1;
            }
        }
        match failed {
            Some((_, e)) => Err(e),
            None => Ok(()),
        }
    }
}

/// Test-gated chaos hook (ISSUE 9 CI chaos step): when the named env var
/// holds this job's seed, the fault fires.  Read per call — never cached
/// — so in-process tests that set and unset the variable stay
/// order-independent.
fn chaos_seed_matches(var: &str, seed: u64) -> bool {
    std::env::var(var).is_ok_and(|v| v.parse::<u64>() == Ok(seed))
}

/// Oracle wrapper for the all-NaN chaos hook: every evaluation reports
/// NaN, driving the run through the quarantine path to a typed
/// `NonFiniteCost` error.
struct NanOracle<'a>(&'a dyn crate::minlp::Oracle);

impl crate::minlp::Oracle for NanOracle<'_> {
    fn n_bits(&self) -> usize {
        self.0.n_bits()
    }

    fn eval(&self, _x: &[i8]) -> f64 {
        f64::NAN
    }

    fn eval_batch(&self, xs: &[Vec<i8>], _workers: usize) -> Vec<f64> {
        vec![f64::NAN; xs.len()]
    }

    fn equivalents(&self, x: &[i8]) -> Vec<Vec<i8>> {
        self.0.equivalents(x)
    }
}

fn run_job(
    job: CompressionJob,
    restart_workers: usize,
    batch_size: usize,
) -> Result<JobResult, JobError> {
    if chaos_seed_matches("INTDECOMP_CHAOS_PANIC_SEED", job.seed) {
        panic!("chaos: injected panic (seed {})", job.seed);
    }
    let cache = match job.cache_mode {
        CacheKeyMode::Exact => CostCache::new(),
        CacheKeyMode::Canonical => CostCache::with_canonical_keys(),
    };
    // The shared level is only sound in canonical mode (stored values
    // are pure functions of the canonical key); in exact mode a shared
    // value could differ from the queried member's cost in the last
    // ulps, so the option is dropped to keep that mode's bit-identical
    // replay promise.
    let shared = match job.cache_mode {
        CacheKeyMode::Canonical => job.shared_cache.clone(),
        CacheKeyMode::Exact => None,
    };
    let (n, k) = (job.problem.n(), job.problem.k);
    let oracle = match shared.as_deref() {
        Some(s) => CachedOracle::with_shared(&job.problem, &cache, s, n, k),
        None => CachedOracle::new(&job.problem, &cache, n, k),
    };
    let mut cfg = job.cfg.clone();
    if restart_workers > 1 {
        cfg = cfg.with_restart_workers(restart_workers);
    }
    if batch_size > 1 {
        cfg = cfg.with_batch_size(batch_size);
    }
    let nan_chaos =
        chaos_seed_matches("INTDECOMP_CHAOS_NAN_SEED", job.seed);
    let warm_run = if nan_chaos {
        bbo::run_warm(
            &NanOracle(&oracle),
            &job.algo,
            job.solver.as_ref(),
            &cfg,
            &Backends::default(),
            job.seed,
            &job.cancel,
            job.warm_start.as_ref(),
            job.export_state,
        )
    } else {
        bbo::run_warm(
            &oracle,
            &job.algo,
            job.solver.as_ref(),
            &cfg,
            &Backends::default(),
            job.seed,
            &job.cancel,
            job.warm_start.as_ref(),
            job.export_state,
        )
    }
    .map_err(JobError::from)?;
    let (run, state, warm) =
        (warm_run.run, warm_run.state, warm_run.warm);
    let best_m =
        BinMatrix::from_spins(job.problem.n(), job.problem.k, &run.best_x);
    let normalised_error = job.problem.normalised_error(run.best_y);
    Ok(JobResult {
        name: job.name,
        n: job.problem.n(),
        d: job.problem.d(),
        k: job.problem.k,
        best_m,
        cache: cache.stats(),
        ratio: compression_ratio(
            job.problem.n(),
            job.problem.d(),
            job.problem.k,
            FLOAT_BITS,
        ),
        normalised_error,
        run,
        state,
        warm,
    })
}

/// Aggregate compressed/original size over all jobs: each layer's
/// [`compression_ratio`] weighted by its original size, so the per-layer
/// and whole-model numbers share one formula.
pub fn overall_ratio(results: &[JobResult]) -> f64 {
    let mut orig = 0.0;
    let mut comp = 0.0;
    for r in results {
        let o = (r.n * r.d * FLOAT_BITS) as f64;
        orig += o;
        comp += o * compression_ratio(r.n, r.d, r.k, FLOAT_BITS);
    }
    if orig == 0.0 {
        0.0
    } else {
        comp / orig
    }
}

/// Per-layer ASCII summary (the aggregated `report::` output).
pub fn summary_table(results: &[JobResult]) -> String {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                format!("{}x{}", r.n, r.d),
                r.k.to_string(),
                r.run.algo.clone(),
                r.run.ys.len().to_string(),
                report::fmt(r.run.best_y),
                format!("{:.4}", r.normalised_error),
                format!("{:.1}%", 100.0 * r.ratio),
                format!(
                    "{}/{} ({:.0}%)",
                    r.cache.hits,
                    r.cache.lookups(),
                    100.0 * r.cache.hit_rate()
                ),
                format!("{:.2}", r.run.time_total),
            ]
        })
        .collect();
    report::ascii_table(
        &[
            "layer", "shape", "K", "algo", "evals", "best cost", "err",
            "size", "cache hits", "time s",
        ],
        &rows,
    )
}

/// Machine-readable per-layer results (CSV, `report::write_csv`).
pub fn write_results_csv(
    path: impl AsRef<std::path::Path>,
    results: &[JobResult],
) -> std::io::Result<()> {
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                r.n.to_string(),
                r.d.to_string(),
                r.k.to_string(),
                r.run.algo.clone(),
                r.run.solver.clone(),
                r.run.ys.len().to_string(),
                format!("{:.12e}", r.run.best_y),
                format!("{:.6}", r.normalised_error),
                format!("{:.6}", r.ratio),
                r.cache.hits.to_string(),
                r.cache.misses.to_string(),
                format!("{:.4}", r.run.time_total),
            ]
        })
        .collect();
    report::write_csv(
        path,
        &[
            "layer",
            "n",
            "d",
            "k",
            "algo",
            "solver",
            "evals",
            "best_cost",
            "normalised_error",
            "compression_ratio",
            "cache_hits",
            "cache_misses",
            "time_s",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::{generate, InstanceConfig};

    fn tiny_job(idx: usize, iters: usize) -> CompressionJob {
        let cfg = InstanceConfig { n: 4, d: 8, k: 2, gamma: 0.8, seed: 9 };
        CompressionJob::new(
            format!("l{idx}"),
            generate(&cfg, idx),
            iters,
            idx as u64,
        )
        .with_solver(Box::new(crate::solvers::sa::SimulatedAnnealing {
            sweeps: 10,
            ..Default::default()
        }))
    }

    #[test]
    fn empty_jobs_give_empty_results() {
        assert!(Engine::with_workers(4).compress_all(Vec::new()).is_empty());
        assert_eq!(overall_ratio(&[]), 0.0);
    }

    #[test]
    fn results_preserve_job_order_and_account_the_cache() {
        let r = Engine::with_workers(2)
            .compress_all((0..3).map(|i| tiny_job(i, 6)).collect());
        assert_eq!(r.len(), 3);
        for (i, jr) in r.iter().enumerate() {
            assert_eq!(jr.name, format!("l{i}"));
            assert_eq!((jr.n, jr.d, jr.k), (4, 8, 2));
            assert_eq!(jr.best_m.n, 4);
            assert_eq!(jr.best_m.k, 2);
            // n_init (8 bits) + 6 iterations, one cache lookup each.
            assert_eq!(jr.run.ys.len(), 8 + 6);
            assert_eq!(jr.cache.lookups() as usize, jr.run.ys.len());
            assert!(jr.ratio > 0.0 && jr.ratio < 1.0);
            assert!(jr.normalised_error.is_finite());
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let a = Engine::with_workers(1)
            .compress_all((0..3).map(|i| tiny_job(i, 8)).collect());
        let b = Engine::with_workers(8)
            .compress_all((0..3).map(|i| tiny_job(i, 8)).collect());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.run.ys, y.run.ys);
            assert_eq!(x.run.best_x, y.run.best_x);
            assert_eq!(x.run.best_y, y.run.best_y);
            assert_eq!(x.cache, y.cache);
        }
    }

    #[test]
    fn compress_each_streams_in_job_order_and_matches_compress_all() {
        let all = Engine::with_workers(4)
            .compress_all((0..5).map(|i| tiny_job(i, 6)).collect());
        for workers in [1usize, 4] {
            let mut seen = Vec::new();
            let mut streamed = Vec::new();
            Engine::with_workers(workers).compress_each(
                (0..5).map(|i| tiny_job(i, 6)).collect(),
                |i, r| {
                    seen.push(i);
                    streamed.push(r);
                },
            );
            assert_eq!(seen, vec![0, 1, 2, 3, 4], "workers = {workers}");
            for (a, b) in all.iter().zip(&streamed) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.run.ys, b.run.ys);
                assert_eq!(a.run.best_x, b.run.best_x);
                assert_eq!(a.cache, b.cache);
            }
        }
        // Empty input: the sink is never called.
        Engine::with_workers(3)
            .compress_each(Vec::new(), |_, _| panic!("no jobs"));
    }

    #[test]
    fn cache_modes_share_exact_hit_accounting() {
        // Canonical (the default) vs exact keys: the acquisition
        // sequences may differ in last-ulp costs, but both modes do one
        // cache lookup per black-box evaluation, stay deterministic,
        // and the canonical map can only be the smaller of the two.
        let run_mode = |mode: CacheKeyMode| {
            Engine::with_workers(2).compress_all(vec![
                tiny_job(0, 10).with_cache_mode(mode),
            ])
        };
        let canon = run_mode(CacheKeyMode::Canonical);
        let canon2 = run_mode(CacheKeyMode::Canonical);
        let exact = run_mode(CacheKeyMode::Exact);
        assert_eq!(canon[0].run.ys, canon2[0].run.ys, "nondeterministic");
        assert_eq!(canon[0].cache, canon2[0].cache);
        for r in [&canon[0], &exact[0]] {
            assert_eq!(r.cache.lookups() as usize, r.run.ys.len());
            assert!(r.cache.misses >= 1);
        }
        assert_eq!(canon[0].run.ys.len(), exact[0].run.ys.len());
    }

    #[test]
    fn shared_cache_is_transparent_and_counts_cross_job_hits() {
        let baseline =
            Engine::with_workers(1).compress_all(vec![tiny_job(0, 8)]);
        let shared = Arc::new(CostCache::with_canonical_keys());
        let first = Engine::with_workers(1).compress_all(vec![
            tiny_job(0, 8).with_shared_cache(shared.clone()),
        ]);
        let second = Engine::with_workers(1).compress_all(vec![
            tiny_job(0, 8).with_shared_cache(shared.clone()),
        ]);
        // Results and per-job cache stats match the unshared run
        // exactly — the shared level only short-circuits evaluation.
        for r in [&first[0], &second[0]] {
            assert_eq!(r.run.ys, baseline[0].run.ys);
            assert_eq!(r.run.best_x, baseline[0].run.best_x);
            assert_eq!(r.run.best_y, baseline[0].run.best_y);
            assert_eq!(r.cache, baseline[0].cache);
        }
        // The first job filled the shared map (one miss per local
        // miss); the identical second job was served from it entirely.
        let s = shared.stats();
        assert_eq!(s.misses, first[0].cache.misses);
        assert_eq!(s.hits, second[0].cache.misses);
        assert!(s.hits > 0, "no cross-job shared-cache hits");
    }

    #[test]
    fn exact_mode_ignores_the_shared_level() {
        let shared = Arc::new(CostCache::with_canonical_keys());
        let r = Engine::with_workers(1).compress_all(vec![tiny_job(0, 6)
            .with_cache_mode(CacheKeyMode::Exact)
            .with_shared_cache(shared.clone())]);
        assert!(r[0].cache.lookups() > 0);
        assert_eq!(shared.stats().lookups(), 0);
        assert!(shared.is_empty());
    }

    #[test]
    fn pre_cancelled_jobs_abort_try_compress_each() {
        for workers in [1usize, 4] {
            let tok = CancelToken::never();
            tok.cancel();
            let jobs: Vec<_> = (0..3)
                .map(|i| tiny_job(i, 6).with_cancel(tok.clone()))
                .collect();
            let mut sunk = Vec::new();
            let out = Engine::with_workers(workers)
                .try_compress_each(jobs, |i, _| sunk.push(i));
            assert_eq!(
                out.unwrap_err(),
                JobError::Cancelled(CancelCause::Cancelled)
            );
            assert!(sunk.is_empty(), "workers = {workers}: sank {sunk:?}");
        }
    }

    #[test]
    fn mid_stream_cancel_stops_after_the_emitted_prefix() {
        // Cancel from the sink after job 0 lands: with the shared
        // token, later jobs unwind at their next iteration boundary
        // and the stream reports the cancellation.
        let tok = CancelToken::never();
        let jobs: Vec<_> = (0..4)
            .map(|i| tiny_job(i, 6).with_cancel(tok.clone()))
            .collect();
        let mut sunk = Vec::new();
        let out = Engine::with_workers(1).try_compress_each(jobs, |i, _| {
            sunk.push(i);
            tok.cancel();
        });
        assert_eq!(
            out.unwrap_err(),
            JobError::Cancelled(CancelCause::Cancelled)
        );
        assert_eq!(sunk, vec![0]);
    }

    /// Seed reserved for the chaos-hook tests: process env vars are
    /// global, so the hook must never collide with the small seeds the
    /// other (possibly concurrent) tests use.
    const CHAOS_SEED: u64 = 0xDEAD_BEEF_0BAD_F00D;

    /// The chaos tests mutate process-global env vars keyed on the same
    /// seed, so they must not interleave with each other.
    static CHAOS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn contained_engine_reports_a_panicking_job_as_a_typed_error() {
        let _guard =
            CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // With contain_panics the chaos hook's unwind is caught at the
        // pool boundary and surfaces as JobError::Panicked — the
        // calling thread (the daemon) never unwinds.
        std::env::set_var(
            "INTDECOMP_CHAOS_PANIC_SEED",
            CHAOS_SEED.to_string(),
        );
        for workers in [1usize, 4] {
            let eng = Engine::new(EngineConfig {
                workers,
                contain_panics: true,
                ..Default::default()
            });
            let jobs: Vec<_> = (0..3)
                .map(|i| {
                    let mut j = tiny_job(i, 6);
                    if i == 1 {
                        j.seed = CHAOS_SEED;
                    }
                    j
                })
                .collect();
            let mut sunk = Vec::new();
            let out = eng.try_compress_each(jobs, |i, _| sunk.push(i));
            match out.unwrap_err() {
                JobError::Panicked { message } => {
                    assert!(message.contains("chaos"), "{message}");
                }
                other => panic!("expected Panicked, got {other:?}"),
            }
            // Job 0 completed and streamed before job 1's injected
            // panic stopped the batch.
            assert_eq!(sunk, vec![0], "workers = {workers}");
        }
        std::env::remove_var("INTDECOMP_CHAOS_PANIC_SEED");
    }

    #[test]
    fn default_engine_propagates_job_panics() {
        let _guard =
            CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var(
            "INTDECOMP_CHAOS_PANIC_SEED",
            CHAOS_SEED.to_string(),
        );
        let out = std::panic::catch_unwind(|| {
            let mut j = tiny_job(0, 6);
            j.seed = CHAOS_SEED;
            Engine::with_workers(1).try_compress_each(vec![j], |_, _| {})
        });
        std::env::remove_var("INTDECOMP_CHAOS_PANIC_SEED");
        assert!(out.is_err(), "default policy must re-raise the panic");
    }

    #[test]
    fn nan_chaos_hook_yields_typed_non_finite_cost_error() {
        let _guard =
            CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var(
            "INTDECOMP_CHAOS_NAN_SEED",
            CHAOS_SEED.to_string(),
        );
        let mut j = tiny_job(0, 6);
        j.seed = CHAOS_SEED;
        let out = Engine::with_workers(1)
            .try_compress_each(vec![j], |_, _| {});
        std::env::remove_var("INTDECOMP_CHAOS_NAN_SEED");
        match out.unwrap_err() {
            JobError::Numeric(
                crate::linalg::NumericError::NonFiniteCost { rejected },
            ) => {
                // Every evaluation of the budget was quarantined.
                assert_eq!(rejected, 8 + 6);
            }
            other => panic!("expected NonFiniteCost, got {other:?}"),
        }
    }

    #[test]
    fn completed_jobs_are_identical_with_and_without_a_token() {
        let plain = Engine::with_workers(2)
            .compress_all((0..3).map(|i| tiny_job(i, 6)).collect());
        let tok = CancelToken::never();
        let mut tokened = Vec::new();
        Engine::with_workers(2)
            .try_compress_each(
                (0..3)
                    .map(|i| tiny_job(i, 6).with_cancel(tok.clone()))
                    .collect(),
                |_, r| tokened.push(r),
            )
            .unwrap();
        for (a, b) in plain.iter().zip(&tokened) {
            assert_eq!(a.run.ys, b.run.ys);
            assert_eq!(a.run.best_x, b.run.best_x);
            assert_eq!(a.cache, b.cache);
        }
    }

    #[test]
    fn warm_jobs_round_trip_through_the_engine() {
        // Donor job exports its state; a second job on the same layer
        // warm-starts from it with a quarter of the budget and still
        // holds the donor's best cost.
        let donor = Engine::with_workers(1)
            .compress_all(vec![tiny_job(0, 8).with_state_export()]);
        assert!(!donor[0].warm);
        let state = donor[0].state.clone().expect("state was requested");
        let warm = WarmStart::new(state).with_prev_best(
            donor[0].run.best_x.clone(),
            donor[0].run.best_y,
        );
        let out = Engine::with_workers(1)
            .compress_all(vec![tiny_job(0, 4).with_warm_start(warm)]);
        assert!(out[0].warm);
        assert!(out[0].state.is_none(), "export was not requested");
        // One anchor evaluation + 4 acquisitions — no init design.
        assert_eq!(out[0].run.ys.len(), 1 + 4);
        assert!(out[0].run.best_y <= donor[0].run.best_y);
    }

    #[test]
    fn cold_jobs_report_no_warm_flag_and_no_state() {
        let r = Engine::with_workers(1).compress_all(vec![tiny_job(0, 5)]);
        assert!(!r[0].warm);
        assert!(r[0].state.is_none());
    }

    #[test]
    fn warm_kind_mismatch_is_a_typed_job_error() {
        // nBOCS donor state offered to an FMQA job: rejected before the
        // job starts, surfaced as JobError::Warm.
        let donor = Engine::with_workers(1)
            .compress_all(vec![tiny_job(0, 6).with_state_export()]);
        let warm = WarmStart::new(donor[0].state.clone().unwrap());
        let out = Engine::with_workers(1).try_compress_each(
            vec![tiny_job(0, 4)
                .with_algo(Algorithm::Fmqa { k_fm: 8 })
                .with_warm_start(warm)],
            |_, _| panic!("mismatched warm job must not produce results"),
        );
        assert!(matches!(
            out.unwrap_err(),
            JobError::Warm(StateError::KindMismatch { .. })
        ));
    }

    #[test]
    fn summary_and_csv_render() {
        let r = Engine::with_workers(1).compress_all(vec![tiny_job(0, 5)]);
        let table = summary_table(&r);
        assert!(table.contains("l0"));
        assert!(table.contains("cache hits"));
        assert!(overall_ratio(&r) > 0.0);
        let dir = std::env::temp_dir().join("intdecomp_engine_csv");
        let path = dir.join("out.csv");
        write_results_csv(&path, &r).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("layer,"));
        assert!(text.contains("l0"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
