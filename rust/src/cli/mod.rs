//! Command-line argument substrate (clap is not vendored; DESIGN.md §6).
//!
//! Grammar: `intdecomp <subcommand...> [--flag value] [--switch]`.
//! Positional words before the first `--flag` form the subcommand path.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional words (subcommand path + positional operands).
    pub positional: Vec<String>,
    /// `--key value` pairs and bare `--switch`es (value "true").
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("empty flag '--'".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Next token is the value unless it's another flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(key.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(key.to_string(), "true".into());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// String flag value, or `default` when absent.
    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    /// Integer flag value, or `default` when absent.
    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key} expects an integer: {e}")),
        }
    }

    /// Float flag value, or `default` when absent.
    pub fn f64_flag(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key} expects a number: {e}")),
        }
    }

    /// u64 flag value (seeds), or `default` when absent.
    pub fn u64_flag(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key} expects an integer: {e}")),
        }
    }

    /// True when the switch was given (`--x`, `--x=true/1/yes`).
    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(
            self.flags.get(key).map(String::as_str),
            Some("true") | Some("1") | Some("yes")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse(&["exp", "fig1", "--runs", "5", "--full", "--seed=7"]);
        assert_eq!(a.positional, vec!["exp", "fig1"]);
        assert_eq!(a.usize_flag("runs", 1).unwrap(), 5);
        assert!(a.bool_flag("full"));
        assert_eq!(a.u64_flag("seed", 0).unwrap(), 7);
    }

    #[test]
    fn defaults() {
        let a = parse(&["bench"]);
        assert_eq!(a.str_flag("solver", "sa"), "sa");
        assert_eq!(a.f64_flag("sigma2", 0.1).unwrap(), 0.1);
        assert!(!a.bool_flag("full"));
    }

    #[test]
    fn flag_followed_by_flag_is_a_switch() {
        let a = parse(&["run", "--augment", "--iters", "10"]);
        assert!(a.bool_flag("augment"));
        assert_eq!(a.usize_flag("iters", 0).unwrap(), 10);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--runs", "abc"]);
        assert!(a.usize_flag("runs", 1).is_err());
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["x", "--gamma=-0.7"]);
        assert_eq!(a.f64_flag("gamma", 0.0).unwrap(), -0.7);
    }
}
