//! Replica-major lockstep solver engine (ISSUE 4).
//!
//! The paper's BBO loop re-optimises every surrogate with `restarts`
//! independent SA/SQ/SQA chains (and SQA additionally carries P Trotter
//! replicas).  The legacy execution model ran each chain as its own
//! scalar loop — one thread per chain, each re-walking the full coupling
//! matrix on every sweep.  This module runs all chains of one solve call
//! as rows of a single replicas×n spin panel with a matching replicas×n
//! local-field panel, swept **in lockstep**: for each proposal site `i`
//! the coupling row `J[i,·]` is loaded once and applied to every replica
//! of the block, so the inner loops are contiguous, autovectorizable
//! column passes instead of per-chain pointer-chasing (the Ising-machine
//! execution model of arXiv:2503.23966).
//!
//! # RNG-stream contract
//!
//! Every replica unit owns one forked [`Rng`] stream and consumes it in
//! **exactly** the legacy per-chain order: first the initial spins, then
//! one uniform per Metropolis proposal *whose ΔE is positive* (downhill
//! moves draw nothing).  Draws are served through buffered per-replica
//! block refills of raw `u64`s ([`Rng::fill_u64s`]), which is
//! stream-transparent: the served values are the stream in order, no
//! matter how the refills are batched.  Per-replica output is therefore
//! bit-identical to the serial reference implementations in
//! [`super::reference`] on the same stream — pinned by
//! `rust/tests/replica_engine.rs` for SA, SQ and SQA.
//!
//! # Fan-out
//!
//! [`run_replicas`] partitions the replica units into blocks and fans
//! the blocks over [`crate::util::threadpool::WorkerPool::global`] via
//! [`crate::util::threadpool::parallel_map`].  The partition is
//! **shape-only** (PR-3 rule): the block size depends only on the unit
//! count, never on worker availability, and units never interact across
//! blocks, so results are invariant to the worker count.
//!
//! # SQA slice mapping
//!
//! For SQA one replica *unit* (one restart) spans `P` consecutive panel
//! rows — its Trotter slices — because the slices of one restart share a
//! single RNG stream and couple through `J_perp`.  The lockstep loop
//! therefore fixes `(slice, site)` and sweeps across *restarts*, which
//! preserves each restart's legacy slice-major proposal order while
//! still amortising every `J[i,·]` row load over the whole block.

use super::{greedy_descent, ModelStats, QuadModel};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;

/// Lockstep sweep schedule of one solver family, derived once per model
/// per solve call from the hoisted [`ModelStats`] scan (the legacy
/// solvers recomputed the underlying O(n²) scans in every restart).
#[derive(Clone, Copy, Debug)]
pub enum SweepPlan {
    /// Single-spin Metropolis on a geometric β ramp: simulated annealing
    /// (`ratio` > 1) and simulated quenching (`ratio` = 1) share this
    /// kernel.
    Metropolis {
        /// Full sweeps over all spins.
        sweeps: usize,
        /// Initial inverse temperature (β_hot for SA, 1/T for SQ).
        beta0: f64,
        /// Per-sweep β multiplier (1.0 pins the temperature).
        ratio: f64,
    },
    /// Path-integral Monte Carlo of the transverse-field Ising model;
    /// each replica unit carries `slices` coupled Trotter rows.
    Sqa {
        /// Trotter slices P per replica unit (≥ 2).
        slices: usize,
        /// Monte Carlo sweeps over (site × slice).
        sweeps: usize,
        /// Initial transverse field Γ0.
        gamma0: f64,
        /// P·T — the Trotter-slice temperature product.
        pt: f64,
        /// 1 / max(P·T, 1e-12).
        beta_slice: f64,
    },
}

impl SweepPlan {
    /// Panel rows per replica unit (1 for Metropolis, P for SQA).
    pub fn rows_per_unit(&self) -> usize {
        match self {
            SweepPlan::Metropolis { .. } => 1,
            SweepPlan::Sqa { slices, .. } => *slices,
        }
    }

    /// Full panel-row sweeps one unit performs over a whole solve —
    /// the work unit behind the `sweeps_per_sec` benchmark rows
    /// (Metropolis: `sweeps`; SQA: `sweeps × slices`, one per Trotter
    /// row per Monte Carlo sweep).
    pub fn row_sweeps_per_unit(&self) -> usize {
        match self {
            SweepPlan::Metropolis { sweeps, .. } => *sweeps,
            SweepPlan::Sqa { slices, sweeps, .. } => sweeps * slices,
        }
    }
}

/// Replicas×n spin panel with its matching replicas×n local-field panel
/// — the engine's central data structure, kept public so tests can pin
/// the panel against per-chain [`super::LocalFields`] bookkeeping.
///
/// Row `r` holds one replica's configuration in `spins[r·n .. (r+1)·n]`
/// and its incrementally maintained fields `f_i = h_i + Σ_k J_ik x_k`
/// in the same slice of `fields`.  [`Panel::flip`] applies one coupling
/// row to one replica's contiguous field row — the autovectorizable
/// column pass the lockstep sweeps are built from.
///
/// ```
/// use intdecomp::solvers::{replica::Panel, LocalFields, QuadModel};
/// use intdecomp::util::rng::Rng;
///
/// let mut rng = Rng::new(5);
/// let m = QuadModel::random(6, &mut rng);
/// let x = rng.spins(6);
/// let mut panel = Panel::new(&m, x.clone());
/// let mut chain = LocalFields::new(&m, &x);
/// assert_eq!(panel.delta_e(0, 3), chain.delta_e(&x, 3));
/// // Committing the same flip keeps panel and chain bit-identical.
/// let mut xc = x;
/// panel.flip(&m, 0, 3);
/// chain.flip(&m, &mut xc, 3);
/// assert_eq!(panel.row(0), &xc[..]);
/// assert_eq!(panel.fields, chain.f);
/// ```
#[derive(Clone, Debug)]
pub struct Panel {
    /// Sites per replica row.
    pub n: usize,
    /// Replica rows in the panel.
    pub rows: usize,
    /// Row-major replica spins (`rows × n`, values ±1).
    pub spins: Vec<i8>,
    /// Row-major local fields (`rows × n`).
    pub fields: Vec<f64>,
}

impl Panel {
    /// Panel over `model` from row-major initial spins (length must be
    /// a multiple of `model.n`); fields are computed per row exactly
    /// like [`super::LocalFields::new`].
    pub fn new(model: &QuadModel, spins: Vec<i8>) -> Self {
        let n = model.n;
        assert!(n > 0 && spins.len() % n == 0, "spins must be rows × n");
        let rows = spins.len() / n;
        let mut fields = Vec::with_capacity(rows * n);
        for r in 0..rows {
            let x = &spins[r * n..(r + 1) * n];
            for i in 0..n {
                fields.push(model.local_field(x, i));
            }
        }
        Panel { n, rows, spins, fields }
    }

    /// One replica's configuration.
    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.spins[r * self.n..(r + 1) * self.n]
    }

    /// ΔE of flipping spin `i` of replica `r` under the current fields
    /// (bit-identical to [`super::LocalFields::delta_e`]).
    #[inline]
    pub fn delta_e(&self, r: usize, i: usize) -> f64 {
        -2.0 * self.spins[r * self.n + i] as f64 * self.fields[r * self.n + i]
    }

    /// Commit the flip of spin `i` of replica `r`: negate the spin and
    /// stream the coupling row `J[i,·]` through the replica's contiguous
    /// field row (bit-identical to [`super::LocalFields::flip`]).
    #[inline]
    pub fn flip(&mut self, model: &QuadModel, r: usize, i: usize) {
        let n = self.n;
        let xi = self.spins[r * n + i];
        self.spins[r * n + i] = -xi;
        let two_xi = 2.0 * xi as f64;
        let jrow = &model.j[i * n..(i + 1) * n];
        let frow = &mut self.fields[r * n..(r + 1) * n];
        for (fk, &jik) in frow.iter_mut().zip(jrow) {
            *fk -= two_xi * jik;
        }
    }
}

/// How many raw u64s a replica stream buffers per refill.
const DRAW_BLOCK: usize = 64;

/// Stream-transparent buffered draw source over an owned [`Rng`]:
/// refills a block of raw u64s at a time ([`Rng::fill_u64s`]) and serves
/// `f64`/`spin` draws from the buffer front-to-back, so the served
/// sequence is bit-identical to calling the scalar [`Rng`] methods in
/// the same order.  `served` counts consumed draws so a borrowed caller
/// stream can be advanced by exactly that amount afterwards
/// ([`solve_one`]).
struct BufferedRng {
    rng: Rng,
    buf: [u64; DRAW_BLOCK],
    pos: usize,
    len: usize,
    served: u64,
}

impl BufferedRng {
    fn new(rng: Rng) -> Self {
        BufferedRng { rng, buf: [0; DRAW_BLOCK], pos: 0, len: 0, served: 0 }
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        if self.pos == self.len {
            self.rng.fill_u64s(&mut self.buf);
            self.pos = 0;
            self.len = DRAW_BLOCK;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        self.served += 1;
        v
    }

    /// Uniform in [0, 1) — bit-identical to [`Rng::f64`].
    #[inline]
    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random spin ±1 — bit-identical to [`Rng::spin`].
    #[inline]
    fn spin(&mut self) -> i8 {
        if self.next_u64() & 1 == 1 {
            1
        } else {
            -1
        }
    }
}

/// Shape-only block partition rule: units per lockstep block as a
/// function of the unit count alone (never of worker availability), so
/// the partition — and with it the whole execution — is identical on
/// every machine.  Targets ~8 independent blocks for pool parallelism
/// while keeping blocks wide enough to amortise the `J[i,·]` row loads.
fn unit_block(units: usize) -> usize {
    units.div_ceil(8).clamp(1, 16)
}

/// Run every stream as one lockstep replica unit of `plan` over
/// `model`, fanned across `workers` threads of the persistent pool in
/// shape-only blocks; returns each unit's best configuration and its
/// (freshly recomputed) energy, in stream order.
///
/// Per-unit results are a pure function of `(model, plan, stream)` —
/// the block partition and worker count never change them — and each is
/// bit-identical to the serial reference solver on the same stream.
///
/// ```
/// use intdecomp::solvers::{self, sa::SimulatedAnnealing, IsingSolver};
/// use intdecomp::util::rng::Rng;
///
/// let m = solvers::QuadModel::random(6, &mut Rng::new(3));
/// let sa = SimulatedAnnealing { sweeps: 8, ..Default::default() };
/// let plan = sa.lockstep_plan(&m, &m.stats()).unwrap();
/// let streams: Vec<Rng> = (0..4u64).map(Rng::new).collect();
/// let out = solvers::replica::run_replicas(&m, &plan, streams, 2);
/// assert_eq!(out.len(), 4);
/// for (x, e) in &out {
///     assert_eq!(x.len(), 6);
///     assert_eq!(*e, m.energy(x));
/// }
/// ```
pub fn run_replicas(
    model: &QuadModel,
    plan: &SweepPlan,
    streams: Vec<Rng>,
    workers: usize,
) -> Vec<(Vec<i8>, f64)> {
    let units = streams.len();
    if units == 0 {
        return Vec::new();
    }
    let block = unit_block(units);
    let blocks: Vec<Vec<Rng>> = {
        let mut streams = streams;
        let mut out = Vec::with_capacity(units.div_ceil(block));
        while !streams.is_empty() {
            let rest = streams.split_off(block.min(streams.len()));
            out.push(streams);
            streams = rest;
        }
        out
    };
    let per_block = parallel_map(blocks, workers, |blk| {
        let mut rngs: Vec<BufferedRng> =
            blk.into_iter().map(BufferedRng::new).collect();
        run_block(model, plan, &mut rngs)
    });
    per_block.into_iter().flatten().collect()
}

/// One replica unit on a borrowed caller stream — the back-end of the
/// thin [`super::IsingSolver::solve`] drivers.  Output and the caller's
/// post-solve stream state are both bit-identical to the legacy scalar
/// solver: the unit runs on a buffered clone of `rng`, then `rng` is
/// advanced by exactly the number of draws the solve consumed.
///
/// ```
/// use intdecomp::solvers::{self, sq::SimulatedQuenching, IsingSolver};
/// use intdecomp::util::rng::Rng;
///
/// let m = solvers::QuadModel::random(5, &mut Rng::new(9));
/// let sq = SimulatedQuenching { sweeps: 6, ..Default::default() };
/// let plan = sq.lockstep_plan(&m, &m.stats()).unwrap();
/// let (mut a, mut b) = (Rng::new(7), Rng::new(7));
/// let x1 = solvers::replica::solve_one(&m, &plan, &mut a);
/// let x2 = sq.solve(&m, &mut b); // the trait driver routes here
/// assert_eq!(x1, x2);
/// assert_eq!(a.next_u64(), b.next_u64()); // streams stay in sync
/// ```
pub fn solve_one(
    model: &QuadModel,
    plan: &SweepPlan,
    rng: &mut Rng,
) -> Vec<i8> {
    let mut src = BufferedRng::new(rng.clone());
    let out = run_block(model, plan, std::slice::from_mut(&mut src));
    // Advance the caller's stream by exactly the consumed draws so its
    // post-solve state matches the legacy scalar path bit-for-bit.  The
    // replay is O(draws) raw generator steps — a few percent of the
    // solve's own cost, and only on this single-unit path; the fan-out
    // paths own their forked streams and never replay.
    for _ in 0..src.served {
        rng.next_u64();
    }
    out.into_iter()
        .next()
        .expect("a single-unit block always yields one result")
        .0
}

/// Dispatch one block of replica units to its lockstep kernel.
fn run_block(
    model: &QuadModel,
    plan: &SweepPlan,
    rngs: &mut [BufferedRng],
) -> Vec<(Vec<i8>, f64)> {
    match *plan {
        SweepPlan::Metropolis { sweeps, beta0, ratio } => {
            metropolis_block(model, sweeps, beta0, ratio, rngs)
        }
        SweepPlan::Sqa { slices, sweeps, gamma0, pt, beta_slice } => {
            sqa_block(model, slices, sweeps, gamma0, pt, beta_slice, rngs)
        }
    }
}

/// Lockstep Metropolis kernel (SA and SQ): one panel row per unit.
///
/// Per unit, the proposal order (sweep-major, site-ascending), the
/// conditional uniform draw (only when ΔE > 0), the incremental energy
/// and the best-so-far tracking replicate the legacy scalar solver
/// exactly; the lockstep structure only changes *when* each replica's
/// independent arithmetic happens, never its values.
fn metropolis_block(
    model: &QuadModel,
    sweeps: usize,
    beta0: f64,
    ratio: f64,
    rngs: &mut [BufferedRng],
) -> Vec<(Vec<i8>, f64)> {
    let n = model.n;
    let rows = rngs.len();
    if n == 0 {
        // Degenerate zero-site model: the legacy solver returns the
        // empty configuration without consuming any draws.
        return rngs.iter().map(|_| (Vec::new(), model.energy(&[]))).collect();
    }
    let mut spins = Vec::with_capacity(rows * n);
    for rng in rngs.iter_mut() {
        for _ in 0..n {
            spins.push(rng.spin());
        }
    }
    let mut panel = Panel::new(model, spins);
    let mut e: Vec<f64> = (0..rows).map(|r| model.energy(panel.row(r))).collect();
    let mut best = panel.spins.clone();
    let mut best_e = e.clone();
    let mut beta = beta0;
    for _ in 0..sweeps {
        for i in 0..n {
            for r in 0..rows {
                let de = panel.delta_e(r, i);
                if de <= 0.0 || rngs[r].f64() < (-beta * de).exp() {
                    panel.flip(model, r, i);
                    e[r] += de;
                    if e[r] < best_e[r] {
                        best_e[r] = e[r];
                        best[r * n..(r + 1) * n]
                            .copy_from_slice(panel.row(r));
                    }
                }
            }
        }
        beta *= ratio;
    }
    (0..rows)
        .map(|r| {
            let x = best[r * n..(r + 1) * n].to_vec();
            let e = model.energy(&x);
            (x, e)
        })
        .collect()
}

/// Lockstep SQA kernel: `slices` coupled panel rows per unit.
///
/// Within a unit the legacy slice-major proposal order is preserved
/// (slices of one restart share a stream and couple through `J_perp`);
/// the lockstep dimension is the *unit* axis, swept innermost at fixed
/// `(slice, site)` so every unit reuses the same `J[i,·]` row.
fn sqa_block(
    model: &QuadModel,
    slices: usize,
    sweeps: usize,
    gamma0: f64,
    pt: f64,
    beta_slice: f64,
    rngs: &mut [BufferedRng],
) -> Vec<(Vec<i8>, f64)> {
    let n = model.n;
    let p = slices;
    let units = rngs.len();
    if n == 0 {
        return rngs.iter().map(|_| (Vec::new(), model.energy(&[]))).collect();
    }
    let mut spins = Vec::with_capacity(units * p * n);
    for rng in rngs.iter_mut() {
        for _ in 0..p * n {
            spins.push(rng.spin());
        }
    }
    let mut panel = Panel::new(model, spins);
    for sweep in 0..sweeps {
        let s = (sweep + 1) as f64 / sweeps as f64;
        let gamma = gamma0 * (1.0 - s);
        // Replica coupling; clamped to keep exp() sane at gamma -> 0.
        let tanh_arg = (gamma / pt).max(1e-12);
        let j_perp = -0.5 * pt * tanh_arg.tanh().ln();
        for slice in 0..p {
            let up = (slice + 1) % p;
            let down = (slice + p - 1) % p;
            for i in 0..n {
                for (u, rng) in rngs.iter_mut().enumerate() {
                    let row = u * p + slice;
                    // Classical ΔE within the slice (scaled by 1/P in
                    // the Trotter action) + replica-coupling ΔE.
                    let de_classical =
                        panel.delta_e(row, i) / p as f64;
                    let xi = panel.spins[row * n + i] as f64;
                    let neigh = (panel.spins[(u * p + up) * n + i]
                        + panel.spins[(u * p + down) * n + i])
                        as f64;
                    let de_perp = 2.0 * j_perp * xi * neigh;
                    let de = de_classical + de_perp;
                    if de <= 0.0
                        || rng.f64()
                            < (-de * beta_slice * p as f64).exp()
                    {
                        panel.flip(model, row, i);
                    }
                }
            }
        }
    }
    // Per unit: best slice by classical energy, then polish to a local
    // minimum (the QPU readout analogue of the projective measurement).
    (0..units)
        .map(|u| {
            let mut best = panel.row(u * p).to_vec();
            let mut best_e = model.energy(&best);
            for slice in 1..p {
                let x = panel.row(u * p + slice);
                let e = model.energy(x);
                if e < best_e {
                    best_e = e;
                    best = x.to_vec();
                }
            }
            greedy_descent(model, &mut best);
            let e = model.energy(&best);
            (best, e)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::{
        random_model, reference, sa::SimulatedAnnealing, IsingSolver,
    };

    #[test]
    fn buffered_rng_is_stream_transparent() {
        let mut scalar = Rng::new(77);
        let mut buffered = BufferedRng::new(Rng::new(77));
        for step in 0..200 {
            if step % 3 == 0 {
                assert_eq!(buffered.spin(), scalar.spin());
            } else {
                assert_eq!(buffered.f64(), scalar.f64());
            }
        }
        assert_eq!(buffered.served, 200);
    }

    #[test]
    fn unit_block_is_shape_only_and_bounded() {
        assert_eq!(unit_block(1), 1);
        assert_eq!(unit_block(8), 1);
        assert_eq!(unit_block(10), 2);
        assert_eq!(unit_block(32), 4);
        assert_eq!(unit_block(1000), 16);
    }

    #[test]
    fn metropolis_block_matches_reference_per_replica() {
        let mut rng = Rng::new(400);
        let m = random_model(&mut rng, 11);
        let sa = SimulatedAnnealing { sweeps: 12, ..Default::default() };
        let plan = sa.lockstep_plan(&m, &m.stats()).unwrap();
        let streams: Vec<Rng> = (0..5u64).map(|i| Rng::new(900 + i)).collect();
        let got = run_replicas(&m, &plan, streams, 1);
        for (i, (x, e)) in got.iter().enumerate() {
            let want = reference::sa(&sa, &m, &mut Rng::new(900 + i as u64));
            assert_eq!(x, &want, "replica {i} diverged from reference");
            assert_eq!(*e, m.energy(x));
        }
    }

    #[test]
    fn run_replicas_is_invariant_to_worker_count() {
        let mut rng = Rng::new(401);
        let m = random_model(&mut rng, 9);
        let sa = SimulatedAnnealing { sweeps: 8, ..Default::default() };
        let plan = sa.lockstep_plan(&m, &m.stats()).unwrap();
        let mk = || (0..20u64).map(|i| Rng::new(i)).collect::<Vec<_>>();
        let a = run_replicas(&m, &plan, mk(), 1);
        let b = run_replicas(&m, &plan, mk(), 6);
        assert_eq!(a, b);
    }

    #[test]
    fn solve_one_advances_caller_stream_exactly() {
        let mut rng = Rng::new(402);
        let m = random_model(&mut rng, 7);
        let sa = SimulatedAnnealing { sweeps: 6, ..Default::default() };
        let plan = sa.lockstep_plan(&m, &m.stats()).unwrap();
        let mut engine_rng = Rng::new(55);
        let mut legacy_rng = Rng::new(55);
        let x_engine = solve_one(&m, &plan, &mut engine_rng);
        let x_legacy = reference::sa(&sa, &m, &mut legacy_rng);
        assert_eq!(x_engine, x_legacy);
        assert_eq!(engine_rng.next_u64(), legacy_rng.next_u64());
    }
}
