//! Ising solvers — the back-end minimisers of the quadratic surrogate.
//!
//! The surrogate model is a pseudo-Boolean quadratic over spins x ∈ {-1,+1}^n:
//!
//! ```text
//!   E(x) = Σ_{i<j} J_ij x_i x_j + Σ_i h_i x_i + c
//! ```
//!
//! Three stochastic solvers (paper "Ising solvers" section) plus an exact
//! enumerator used as a test oracle:
//!
//! * [`sa::SimulatedAnnealing`] — Metropolis with a geometric β schedule
//!   derived from effective-field bounds, using the same 2.9 / 0.4 hot /
//!   cold scaling factors the paper cites for the Ocean defaults.
//! * [`sqa::SimulatedQuantumAnnealing`] — path-integral Monte Carlo of the
//!   transverse-field Ising model; stands in for the D-Wave QPU
//!   (DESIGN.md §2 hardware substitution).
//! * [`sq::SimulatedQuenching`] — SA with the temperature pinned at 0.1
//!   (the paper's SQ variant: no global exploration).
//! * [`exhaustive::Exhaustive`] — exact 2^n minimisation via Gray code.
//!
//! On top of the single-solve interface sit two fan-out helpers that run
//! restarts on forked RNG streams across the persistent worker pool:
//! [`solve_best_parallel`] (best of k restarts) and [`solve_batch`] (the
//! top-k *distinct* restart minima, feeding the engine's batched
//! acquisition).
//!
//! Since ISSUE 4 the stochastic solvers execute on the replica-major
//! lockstep engine ([`replica`]): all restarts of one `solve_batch` call
//! (and all SQA Trotter slices) are rows of a replicas×n spin panel swept
//! in lockstep, so each coupling row `J[i,·]` is loaded once per proposal
//! site and applied to every replica.  Each replica consumes its forked
//! RNG stream in exactly the legacy per-chain order, so per-replica
//! output is bit-identical to the serial reference implementations kept
//! in [`reference`] (pinned by `rust/tests/replica_engine.rs`).

pub mod exhaustive;
pub mod reference;
pub mod replica;
pub mod sa;
pub mod sq;
pub mod sqa;

use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;

/// Dense symmetric quadratic model over ±1 spins.
///
/// ```
/// use intdecomp::solvers::QuadModel;
///
/// let mut m = QuadModel::new(2);
/// m.h = vec![0.5, -1.0];
/// m.set_pair(0, 1, 2.0);
/// m.c = 3.0;
/// assert_eq!(m.energy(&[1, -1]), 3.0 + 0.5 + 1.0 - 2.0);
/// ```
#[derive(Clone, Debug)]
pub struct QuadModel {
    /// Number of spins.
    pub n: usize,
    /// Pair couplings, symmetric with zero diagonal; the energy counts each
    /// unordered pair once (J\[i\]\[j\] stored in both triangles, summed as
    /// i<j).
    pub j: Vec<f64>,
    /// Linear fields.
    pub h: Vec<f64>,
    /// Constant offset.
    pub c: f64,
}

impl QuadModel {
    /// Zero model over `n` spins (all couplings, fields and offset 0).
    pub fn new(n: usize) -> Self {
        QuadModel { n, j: vec![0.0; n * n], h: vec![0.0; n], c: 0.0 }
    }

    /// Coupling of pair (i, k) (symmetric storage).
    #[inline]
    pub fn j_at(&self, i: usize, k: usize) -> f64 {
        self.j[i * self.n + k]
    }

    /// Set the coupling of unordered pair (i, k).
    pub fn set_pair(&mut self, i: usize, k: usize, v: f64) {
        assert!(i != k);
        self.j[i * self.n + k] = v;
        self.j[k * self.n + i] = v;
    }

    /// Full energy of a configuration.
    pub fn energy(&self, x: &[i8]) -> f64 {
        debug_assert_eq!(x.len(), self.n);
        let mut e = self.c;
        for i in 0..self.n {
            let xi = x[i] as f64;
            e += self.h[i] * xi;
            let row = &self.j[i * self.n..(i + 1) * self.n];
            for k in (i + 1)..self.n {
                e += row[k] * xi * x[k] as f64;
            }
        }
        e
    }

    /// Local field at site i: dE of flipping x_i is `-2 x_i field_i(x)`...
    /// precisely `ΔE_i = -2 x_i (h_i + Σ_k J_ik x_k)`.
    #[inline]
    pub fn local_field(&self, x: &[i8], i: usize) -> f64 {
        let row = &self.j[i * self.n..(i + 1) * self.n];
        let mut f = self.h[i];
        for (k, &xk) in x.iter().enumerate() {
            f += row[k] * xk as f64;
        }
        f
    }

    /// Energy change if spin i is flipped.
    #[inline]
    pub fn delta_e(&self, x: &[i8], i: usize) -> f64 {
        -2.0 * x[i] as f64 * self.local_field(x, i)
    }

    /// Smallest nonzero coupling magnitude among all |h_i| and |J_ik| —
    /// the neal-style "minimum effective field" that sets the *cold* end
    /// of the SA schedule (the smallest energy scale that must freeze).
    /// Using the per-site field bound here instead leaves SA finishing
    /// hot on BOCS-surrogate-shaped models (EXPERIMENTS.md §Perf note).
    ///
    /// Convenience wrapper over the fused [`QuadModel::stats`] scan;
    /// schedule-building hot paths should call `stats` once and reuse it.
    pub fn min_nonzero_gap(&self) -> f64 {
        self.stats().min_gap
    }

    /// Per-site maximum effective field magnitudes (|h_i| + Σ_k |J_ik|),
    /// used to derive default temperature schedules (neal-style).
    ///
    /// Convenience wrapper over the fused [`QuadModel::stats`] scan;
    /// schedule-building hot paths should call `stats` once and reuse it.
    pub fn field_bounds(&self) -> (f64, f64) {
        let s = self.stats();
        (s.max_field, s.min_field)
    }

    /// All schedule-relevant model statistics in one fused O(n²) pass:
    /// the per-site effective-field bounds and the minimum nonzero
    /// energy gap.  The values are bit-identical to the legacy separate
    /// [`QuadModel::field_bounds`] / [`QuadModel::min_nonzero_gap`]
    /// scans (same accumulation order); hoisting the scan to once per
    /// model per solve call is what removes the per-restart O(n²)
    /// schedule recomputation the serial solvers used to pay.
    pub fn stats(&self) -> ModelStats {
        let mut max_f: f64 = 0.0;
        let mut min_f = f64::INFINITY;
        let mut gap = f64::INFINITY;
        for &h in &self.h {
            if h != 0.0 {
                gap = gap.min(h.abs());
            }
        }
        for i in 0..self.n {
            let row = &self.j[i * self.n..(i + 1) * self.n];
            let mut f = self.h[i].abs();
            for &v in row {
                f += v.abs();
            }
            for &j in &row[(i + 1)..] {
                if j != 0.0 {
                    gap = gap.min(j.abs());
                }
            }
            if f > 0.0 {
                max_f = max_f.max(f);
                min_f = min_f.min(f);
            }
        }
        if !gap.is_finite() {
            gap = 1.0;
        }
        if !min_f.is_finite() {
            min_f = 1.0;
            max_f = 1.0;
        }
        ModelStats {
            max_field: max_f.max(1e-12),
            min_field: min_f.max(1e-12),
            min_gap: gap,
        }
    }

    /// Random dense model with standard-normal fields, couplings and
    /// offset — the bench / test instance generator.  Stream order is
    /// fixed (per site: `h_i`, then its upper-triangle couplings; the
    /// offset last), so a seeded [`Rng`] always yields the same model.
    ///
    /// ```
    /// use intdecomp::solvers::QuadModel;
    /// use intdecomp::util::rng::Rng;
    ///
    /// let m = QuadModel::random(8, &mut Rng::new(1));
    /// assert_eq!(m.n, 8);
    /// assert_eq!(m.j_at(2, 5), m.j_at(5, 2));
    /// ```
    pub fn random(n: usize, rng: &mut Rng) -> Self {
        let mut m = QuadModel::new(n);
        for i in 0..n {
            m.h[i] = rng.normal();
            for k in (i + 1)..n {
                m.set_pair(i, k, rng.normal());
            }
        }
        m.c = rng.normal();
        m
    }
}

/// Schedule-relevant statistics of one [`QuadModel`], computed by the
/// fused [`QuadModel::stats`] scan and shared by every replica of a
/// solve call (the legacy solvers recomputed the underlying O(n²) scans
/// inside every restart).
#[derive(Clone, Copy, Debug)]
pub struct ModelStats {
    /// Largest per-site effective field |h_i| + Σ_k |J_ik| (≥ 1e-12).
    pub max_field: f64,
    /// Smallest positive per-site effective field (≥ 1e-12).
    pub min_field: f64,
    /// Smallest nonzero |h_i| / |J_ik| magnitude (1.0 for a zero model).
    pub min_gap: f64,
}

/// Common interface: minimise the model from a random start.
pub trait IsingSolver: Send + Sync {
    /// One solve attempt; returns the best configuration found.
    fn solve(&self, model: &QuadModel, rng: &mut Rng) -> Vec<i8>;

    /// Short identifier for reports.
    fn name(&self) -> &'static str;

    /// Lockstep sweep plan for the replica-major engine: solvers that
    /// can run as rows of a spin panel return their schedule here
    /// (derived from the hoisted per-model [`ModelStats`]), and
    /// [`solve_batch`] / [`solve_best_parallel`] then execute all
    /// restarts in lockstep via [`replica::run_replicas`].  `None` (the
    /// default) keeps the per-chain [`IsingSolver::solve`] fan-out —
    /// the exact enumerator, for instance, has no sweep structure.
    fn lockstep_plan(
        &self,
        model: &QuadModel,
        stats: &ModelStats,
    ) -> Option<replica::SweepPlan> {
        let _ = (model, stats);
        None
    }

    /// Best of `restarts` independent attempts (the paper re-optimises the
    /// surrogate 10 times per iteration), threading one RNG sequentially
    /// through the restarts.  The per-model schedule scan is hoisted out
    /// of the restart loop; each restart's stream consumption and output
    /// are bit-identical to calling [`IsingSolver::solve`] in a loop.
    fn solve_best(
        &self,
        model: &QuadModel,
        rng: &mut Rng,
        restarts: usize,
    ) -> (Vec<i8>, f64) {
        let stats = model.stats();
        let plan = self.lockstep_plan(model, &stats);
        let mut best_x = Vec::new();
        let mut best_e = f64::INFINITY;
        for _ in 0..restarts.max(1) {
            let x = match &plan {
                Some(p) => replica::solve_one(model, p, rng),
                None => self.solve(model, rng),
            };
            let e = model.energy(&x);
            if e < best_e {
                best_e = e;
                best_x = x;
            }
        }
        (best_x, best_e)
    }
}

/// Best of `restarts` attempts with per-restart RNG streams, fanned across
/// `workers` threads of the persistent pool
/// ([`crate::util::threadpool::parallel_map`]).
///
/// Unlike [`IsingSolver::solve_best`], which threads one RNG sequentially
/// through the restarts (so each restart's stream depends on how much
/// entropy the previous ones consumed), every restart here gets an
/// independent child stream forked from `rng`'s current state and the
/// restart index only.  The result is therefore bit-identical for *any*
/// `workers` value — 1 included — which is what makes the engine's
/// parallel path reproducible.  Ties are broken toward the lowest restart
/// index, matching the serial first-strictly-better rule.
///
/// `rng` is advanced by exactly `restarts` draws regardless of `workers`.
///
/// ```
/// use intdecomp::solvers::{self, sa::SimulatedAnnealing};
/// use intdecomp::util::rng::Rng;
///
/// let mut m = solvers::QuadModel::new(2);
/// m.h = vec![1.0, -2.0];
/// let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
/// let serial =
///     solvers::solve_best_parallel(&sa, &m, &mut Rng::new(1), 4, 1);
/// let fanned =
///     solvers::solve_best_parallel(&sa, &m, &mut Rng::new(1), 4, 4);
/// assert_eq!(serial, fanned); // bit-identical for any worker count
/// assert_eq!(serial.1, m.energy(&serial.0));
/// ```
pub fn solve_best_parallel(
    solver: &dyn IsingSolver,
    model: &QuadModel,
    rng: &mut Rng,
    restarts: usize,
    workers: usize,
) -> (Vec<i8>, f64) {
    solve_batch(solver, model, rng, restarts, 1, workers)
        .pop()
        .expect("restarts >= 1 always yields a candidate")
}

/// Batched acquisition back-end: the `k` best *distinct* configurations
/// found by `restarts` independent solver attempts, fanned across
/// `workers` threads of the persistent pool.
///
/// This is the FMQA-style batched-acquisition primitive (arXiv:2209.01016):
/// one surrogate fit per iteration feeds the solver fan-out, and instead
/// of keeping only the single best restart, the top `k` distinct local
/// minima are all returned for concurrent black-box evaluation.
///
/// Semantics:
///
/// * candidates come back sorted by energy, best first;
/// * duplicate configurations are folded (only the first, i.e. the
///   lowest-restart-index copy, survives), so the result may hold fewer
///   than `k` entries when the restarts found fewer distinct minima;
/// * ties in energy are broken toward the lowest restart index;
/// * each restart runs on its own RNG stream forked from `rng`'s current
///   state and the restart index, so the result is bit-identical for any
///   `workers` value, and `rng` is advanced by exactly `restarts` draws.
///
/// With `k == 1` this degenerates to [`solve_best_parallel`].
///
/// ```
/// use intdecomp::solvers::{self, sa::SimulatedAnnealing};
/// use intdecomp::util::rng::Rng;
///
/// let mut m = solvers::QuadModel::new(3);
/// m.h = vec![0.5, -1.0, 2.0];
/// let sa = SimulatedAnnealing { sweeps: 10, ..Default::default() };
/// let top =
///     solvers::solve_batch(&sa, &m, &mut Rng::new(7), 8, 3, 2);
/// assert!(!top.is_empty() && top.len() <= 3);
/// // Best first; every candidate distinct, energies consistent.
/// for pair in top.windows(2) {
///     assert!(pair[0].1 <= pair[1].1);
///     assert_ne!(pair[0].0, pair[1].0);
/// }
/// for (x, e) in &top {
///     assert_eq!(*e, m.energy(x));
/// }
/// ```
pub fn solve_batch(
    solver: &dyn IsingSolver,
    model: &QuadModel,
    rng: &mut Rng,
    restarts: usize,
    k: usize,
    workers: usize,
) -> Vec<(Vec<i8>, f64)> {
    let restarts = restarts.max(1);
    let k = k.max(1);
    let streams: Vec<Rng> =
        (0..restarts).map(|i| rng.fork(i as u64)).collect();
    // One O(n²) schedule scan per call, shared by every replica (the
    // legacy path recomputed it inside every restart).
    let stats = model.stats();
    let results = match solver.lockstep_plan(model, &stats) {
        // Replica-major lockstep engine: all restarts swept as rows of
        // one spin panel, fanned over the pool in replica blocks.
        Some(plan) => replica::run_replicas(model, &plan, streams, workers),
        // Solvers without a lockstep kernel keep the per-chain fan-out.
        None => parallel_map(streams, workers, |mut child| {
            let x = solver.solve(model, &mut child);
            let e = model.energy(&x);
            (x, e)
        }),
    };
    // Stable sort with NaN explicitly ordered last: on non-NaN values
    // `partial_cmp` is total and treats -0.0 == +0.0, so IEEE-equal
    // energies keep restart order (the serial first-strictly-better
    // tie-break, matching the old `e < best_e` scan exactly), the
    // comparator is a valid total order (no sort panic), and a NaN
    // energy from a degenerate surrogate can never rank as best.
    let mut ranked = results;
    ranked.sort_by(|a, b| match (a.1.is_nan(), b.1.is_nan()) {
        (false, false) => a.1.partial_cmp(&b.1).unwrap(),
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
    });
    let mut out: Vec<(Vec<i8>, f64)> = Vec::with_capacity(k);
    for (x, e) in ranked {
        if out.iter().any(|(seen, _)| *seen == x) {
            continue;
        }
        out.push((x, e));
        if out.len() == k {
            break;
        }
    }
    out
}

/// Incrementally maintained local fields `f_i = h_i + Σ_k J_ik x_k` for
/// Metropolis sweeps: O(n) refresh per accepted flip instead of an O(n)
/// scan per *proposed* flip (≈2× on the SA/SQ/SQA inner loops —
/// EXPERIMENTS.md §Perf).
pub struct LocalFields {
    /// Current field value per site.
    pub f: Vec<f64>,
}

impl LocalFields {
    /// Fields of configuration `x` under `model` (O(n²) full refresh).
    pub fn new(model: &QuadModel, x: &[i8]) -> Self {
        let f = (0..model.n).map(|i| model.local_field(x, i)).collect();
        LocalFields { f }
    }

    /// ΔE of flipping spin i under the current fields.
    #[inline]
    pub fn delta_e(&self, x: &[i8], i: usize) -> f64 {
        -2.0 * x[i] as f64 * self.f[i]
    }

    /// Commit the flip of spin i: update x and all fields it touches.
    #[inline]
    pub fn flip(&mut self, model: &QuadModel, x: &mut [i8], i: usize) {
        let two_xi = 2.0 * x[i] as f64; // old value
        x[i] = -x[i];
        let row = &model.j[i * model.n..(i + 1) * model.n];
        for (fk, &jik) in self.f.iter_mut().zip(row) {
            *fk -= two_xi * jik;
        }
    }
}

/// Greedy single-spin descent to a local minimum (used as a polish step
/// and by tests).
pub fn greedy_descent(model: &QuadModel, x: &mut Vec<i8>) {
    loop {
        let mut improved = false;
        for i in 0..model.n {
            if model.delta_e(x, i) < 0.0 {
                x[i] = -x[i];
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
}

/// Construct solver by name ("sa", "sq", "sqa", "exhaustive").
pub fn by_name(name: &str) -> Option<Box<dyn IsingSolver>> {
    match name {
        "sa" => Some(Box::new(sa::SimulatedAnnealing::default())),
        "sq" => Some(Box::new(sq::SimulatedQuenching::default())),
        "sqa" | "qa" => {
            Some(Box::new(sqa::SimulatedQuantumAnnealing::default()))
        }
        "exhaustive" => Some(Box::new(exhaustive::Exhaustive)),
        _ => None,
    }
}

#[cfg(test)]
pub(crate) fn random_model(rng: &mut Rng, n: usize) -> QuadModel {
    QuadModel::random(n, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_known_values() {
        let mut m = QuadModel::new(2);
        m.h = vec![0.5, -1.0];
        m.set_pair(0, 1, 2.0);
        m.c = 3.0;
        // x = (+1, +1): 3 + 0.5 - 1 + 2 = 4.5
        assert!((m.energy(&[1, 1]) - 4.5).abs() < 1e-12);
        // x = (+1, -1): 3 + 0.5 + 1 - 2 = 2.5
        assert!((m.energy(&[1, -1]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn delta_e_matches_energy_difference() {
        let mut rng = Rng::new(200);
        let m = random_model(&mut rng, 10);
        for _ in 0..50 {
            let x = rng.spins(10);
            let i = rng.below(10);
            let mut xf = x.clone();
            xf[i] = -xf[i];
            let de = m.delta_e(&x, i);
            let want = m.energy(&xf) - m.energy(&x);
            assert!((de - want).abs() < 1e-9);
        }
    }

    #[test]
    fn greedy_descent_reaches_local_min() {
        let mut rng = Rng::new(201);
        let m = random_model(&mut rng, 12);
        let mut x = rng.spins(12);
        greedy_descent(&m, &mut x);
        for i in 0..12 {
            assert!(m.delta_e(&x, i) >= 0.0);
        }
    }

    #[test]
    fn field_bounds_positive() {
        let mut rng = Rng::new(202);
        let m = random_model(&mut rng, 8);
        let (max_f, min_f) = m.field_bounds();
        assert!(max_f >= min_f);
        assert!(min_f > 0.0);
    }

    #[test]
    fn solve_best_parallel_is_worker_count_invariant() {
        let mut rng = Rng::new(210);
        let m = random_model(&mut rng, 12);
        let solver = sa::SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let (x1, e1) = solve_best_parallel(&solver, &m, &mut Rng::new(4), 8, 1);
        let (x4, e4) = solve_best_parallel(&solver, &m, &mut Rng::new(4), 8, 4);
        assert_eq!(x1, x4);
        assert_eq!(e1, e4);
        assert!((m.energy(&x1) - e1).abs() < 1e-12);
    }

    #[test]
    fn solve_best_parallel_monotone_in_restarts() {
        // The first child stream of a k-restart call coincides with the
        // single-restart call's stream, so more restarts can only help.
        let mut rng = Rng::new(211);
        let m = random_model(&mut rng, 10);
        let solver = sa::SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let (_, e1) = solve_best_parallel(&solver, &m, &mut Rng::new(3), 1, 2);
        let (_, e10) = solve_best_parallel(&solver, &m, &mut Rng::new(3), 10, 2);
        assert!(e10 <= e1 + 1e-12);
    }

    #[test]
    fn solve_best_parallel_advances_rng_deterministically() {
        let m = {
            let mut rng = Rng::new(212);
            random_model(&mut rng, 8)
        };
        let solver = sa::SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let _ = solve_best_parallel(&solver, &m, &mut a, 6, 1);
        let _ = solve_best_parallel(&solver, &m, &mut b, 6, 3);
        // Caller-side stream state is independent of the worker count.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn solve_batch_candidates_are_distinct_and_sorted() {
        let mut rng = Rng::new(213);
        let m = random_model(&mut rng, 10);
        let solver =
            sa::SimulatedAnnealing { sweeps: 10, ..Default::default() };
        let top = solve_batch(&solver, &m, &mut Rng::new(9), 12, 5, 3);
        assert!(!top.is_empty() && top.len() <= 5);
        for w in top.windows(2) {
            assert!(w[0].1 <= w[1].1, "not sorted by energy");
            assert_ne!(w[0].0, w[1].0);
        }
        // All pairwise distinct, not just neighbours.
        for i in 0..top.len() {
            for j in (i + 1)..top.len() {
                assert_ne!(top[i].0, top[j].0, "duplicate candidate");
            }
            assert!((m.energy(&top[i].0) - top[i].1).abs() < 1e-12);
        }
    }

    #[test]
    fn solve_batch_is_worker_count_invariant() {
        let mut rng = Rng::new(214);
        let m = random_model(&mut rng, 9);
        let solver =
            sa::SimulatedAnnealing { sweeps: 8, ..Default::default() };
        let a = solve_batch(&solver, &m, &mut Rng::new(2), 10, 4, 1);
        let b = solve_batch(&solver, &m, &mut Rng::new(2), 10, 4, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn solve_batch_k1_matches_solve_best_parallel() {
        let mut rng = Rng::new(215);
        let m = random_model(&mut rng, 8);
        let solver =
            sa::SimulatedAnnealing { sweeps: 6, ..Default::default() };
        let batch = solve_batch(&solver, &m, &mut Rng::new(4), 7, 1, 2);
        let (bx, be) =
            solve_best_parallel(&solver, &m, &mut Rng::new(4), 7, 2);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].0, bx);
        assert_eq!(batch[0].1, be);
    }

    #[test]
    fn by_name_resolves_all() {
        for name in ["sa", "sq", "sqa", "qa", "exhaustive"] {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("bogus").is_none());
    }
}
