//! Exact QUBO/Ising minimiser by Gray-code enumeration — the oracle the
//! stochastic solvers are validated against (practical up to n ≈ 22).

use super::{IsingSolver, QuadModel};
use crate::util::rng::Rng;

/// Exact minimiser: Gray-code scan of all 2^n configurations.
#[derive(Clone, Copy, Debug, Default)]
pub struct Exhaustive;

impl IsingSolver for Exhaustive {
    fn solve(&self, model: &QuadModel, _rng: &mut Rng) -> Vec<i8> {
        let n = model.n;
        assert!(n <= 26, "exhaustive solve is 2^n");
        let mut x = vec![1i8; n];
        let mut e = model.energy(&x);
        let mut best = x.clone();
        let mut best_e = e;
        for g in 1u64..(1u64 << n) {
            let bit = g.trailing_zeros() as usize;
            e += model.delta_e(&x, bit);
            x[bit] = -x[bit];
            if e < best_e {
                best_e = e;
                best.copy_from_slice(&x);
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "exhaustive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::random_model;

    #[test]
    fn matches_naive_enumeration() {
        let mut rng = Rng::new(330);
        for _ in 0..5 {
            let m = random_model(&mut rng, 8);
            let x = Exhaustive.solve(&m, &mut rng);
            let got = m.energy(&x);
            // Naive O(2^n * n^2) check.
            let mut want = f64::INFINITY;
            for bits in 0..(1u32 << 8) {
                let cand: Vec<i8> = (0..8)
                    .map(|i| if (bits >> i) & 1 == 1 { 1 } else { -1 })
                    .collect();
                want = want.min(m.energy(&cand));
            }
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn incremental_energy_stays_consistent() {
        let mut rng = Rng::new(331);
        let m = random_model(&mut rng, 6);
        let x = Exhaustive.solve(&m, &mut rng);
        assert_eq!(x.len(), 6);
        assert!(x.iter().all(|&s| s == 1 || s == -1));
    }
}
