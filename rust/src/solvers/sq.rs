//! Simulated quenching (paper SQ): Metropolis at a fixed low temperature
//! (T = 0.1), i.e. SA with the schedule collapsed.  Deliberately bad at
//! global exploration — the paper's finding is that this does *not* hurt
//! BBO, because the surrogate landscape is simple.

use super::{IsingSolver, QuadModel};
use crate::util::rng::Rng;

/// Fixed-temperature Metropolis (the paper's SQ variant).
#[derive(Clone, Debug)]
pub struct SimulatedQuenching {
    /// Full sweeps over all spins.
    pub sweeps: usize,
    /// Constant temperature (paper: 0.1).
    pub temperature: f64,
}

impl Default for SimulatedQuenching {
    fn default() -> Self {
        SimulatedQuenching { sweeps: 100, temperature: 0.1 }
    }
}

impl IsingSolver for SimulatedQuenching {
    fn solve(&self, model: &QuadModel, rng: &mut Rng) -> Vec<i8> {
        let n = model.n;
        let beta = 1.0 / self.temperature.max(1e-12);
        let mut x = rng.spins(n);
        let mut e = model.energy(&x);
        let mut best = x.clone();
        let mut best_e = e;
        let mut fields = super::LocalFields::new(model, &x);
        for _ in 0..self.sweeps {
            for i in 0..n {
                let de = fields.delta_e(&x, i);
                if de <= 0.0 || rng.f64() < (-beta * de).exp() {
                    fields.flip(model, &mut x, i);
                    e += de;
                    if e < best_e {
                        best_e = e;
                        best.copy_from_slice(&x);
                    }
                }
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "sq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::random_model;

    #[test]
    fn reaches_a_local_minimum_energy() {
        let mut rng = Rng::new(310);
        let m = random_model(&mut rng, 12);
        let sq = SimulatedQuenching::default();
        let x = sq.solve(&m, &mut rng);
        // At T=0.1 with normal-scale couplings the result should be at or
        // near a local minimum: no flip lowers energy by much.
        for i in 0..12 {
            assert!(m.delta_e(&x, i) > -0.8, "far from local min");
        }
    }

    #[test]
    fn quench_quality_not_worse_than_random() {
        let mut rng = Rng::new(311);
        let m = random_model(&mut rng, 16);
        let sq = SimulatedQuenching::default();
        let (_, e) = sq.solve_best(&m, &mut rng, 5);
        let mut rand_best = f64::INFINITY;
        for _ in 0..5 {
            rand_best = rand_best.min(m.energy(&rng.spins(16)));
        }
        assert!(e <= rand_best);
    }
}
