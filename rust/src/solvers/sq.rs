//! Simulated quenching (paper SQ): Metropolis at a fixed low temperature
//! (T = 0.1), i.e. SA with the schedule collapsed.  Deliberately bad at
//! global exploration — the paper's finding is that this does *not* hurt
//! BBO, because the surrogate landscape is simple.
//!
//! Since ISSUE 4 this type is a thin schedule driver over the
//! replica-major engine ([`super::replica`]): SQ is the lockstep
//! Metropolis kernel with the β ratio pinned at 1.  Output is
//! bit-identical to the legacy scalar chain ([`super::reference::sq`])
//! on the same stream.

use super::{replica, IsingSolver, ModelStats, QuadModel};
use crate::util::rng::Rng;

/// Fixed-temperature Metropolis (the paper's SQ variant).
#[derive(Clone, Debug)]
pub struct SimulatedQuenching {
    /// Full sweeps over all spins.
    pub sweeps: usize,
    /// Constant temperature (paper: 0.1).
    pub temperature: f64,
}

impl Default for SimulatedQuenching {
    fn default() -> Self {
        SimulatedQuenching { sweeps: 100, temperature: 0.1 }
    }
}

impl IsingSolver for SimulatedQuenching {
    fn solve(&self, model: &QuadModel, rng: &mut Rng) -> Vec<i8> {
        let plan = self
            .lockstep_plan(model, &model.stats())
            .expect("SQ always has a lockstep plan");
        replica::solve_one(model, &plan, rng)
    }

    fn name(&self) -> &'static str {
        "sq"
    }

    fn lockstep_plan(
        &self,
        _model: &QuadModel,
        _stats: &ModelStats,
    ) -> Option<replica::SweepPlan> {
        // A fixed temperature is the geometric ramp with ratio 1
        // (β·1.0 is exact in IEEE arithmetic, so the collapsed
        // schedule shares the SA kernel bit-for-bit).
        Some(replica::SweepPlan::Metropolis {
            sweeps: self.sweeps,
            beta0: 1.0 / self.temperature.max(1e-12),
            ratio: 1.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::random_model;

    #[test]
    fn reaches_a_local_minimum_energy() {
        let mut rng = Rng::new(310);
        let m = random_model(&mut rng, 12);
        let sq = SimulatedQuenching::default();
        let x = sq.solve(&m, &mut rng);
        // At T=0.1 with normal-scale couplings the result should be at or
        // near a local minimum: no flip lowers energy by much.
        for i in 0..12 {
            assert!(m.delta_e(&x, i) > -0.8, "far from local min");
        }
    }

    #[test]
    fn quench_quality_not_worse_than_random() {
        let mut rng = Rng::new(311);
        let m = random_model(&mut rng, 16);
        let sq = SimulatedQuenching::default();
        let (_, e) = sq.solve_best(&m, &mut rng, 5);
        let mut rand_best = f64::INFINITY;
        for _ in 0..5 {
            rand_best = rand_best.min(m.energy(&rng.spins(16)));
        }
        assert!(e <= rand_best);
    }
}
