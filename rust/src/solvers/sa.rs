//! Simulated annealing (Kirkpatrick et al. 1983), configured like the
//! D-Wave Ocean `neal` defaults the paper uses: the initial / final
//! temperatures come from the estimated maximum / minimum effective fields
//! scaled by 2.9 and 0.4 respectively, with a geometric β schedule and
//! Metropolis single-spin updates.
//!
//! Since ISSUE 4 this type is a thin schedule driver over the
//! replica-major engine ([`super::replica`]): it derives the β ramp from
//! the hoisted [`super::ModelStats`] scan and hands the sweeps to the
//! shared lockstep Metropolis kernel.  Output is bit-identical to the
//! legacy scalar chain ([`super::reference::sa`]) on the same stream.

use super::{replica, IsingSolver, ModelStats, QuadModel};
use crate::util::rng::Rng;

/// Metropolis simulated annealing with the neal-style geometric
/// schedule (the paper's default back-end).
#[derive(Clone, Debug)]
pub struct SimulatedAnnealing {
    /// Full sweeps over all spins.
    pub sweeps: usize,
    /// Hot-side temperature scaling (Ocean default ≈ 2.9).
    pub hot_factor: f64,
    /// Cold-side temperature scaling (Ocean default ≈ 0.4).
    pub cold_factor: f64,
}

impl Default for SimulatedAnnealing {
    fn default() -> Self {
        SimulatedAnnealing { sweeps: 100, hot_factor: 2.9, cold_factor: 0.4 }
    }
}

impl SimulatedAnnealing {
    /// β schedule endpoints from the model's effective-field estimates
    /// (neal convention): T_hot = hot_factor * max per-site field (every
    /// move initially plausible), T_cold = cold_factor * the *smallest
    /// nonzero coupling* (the finest energy scale must freeze by the end
    /// — using the per-site bound here leaves SA finishing hot on
    /// surrogate-shaped models).
    pub fn beta_range(&self, model: &QuadModel) -> (f64, f64) {
        self.beta_range_from(&model.stats())
    }

    /// β schedule endpoints from an already-computed [`ModelStats`] —
    /// the hoisted form used by the lockstep plan, so the O(n²) scan
    /// runs once per solve call instead of once per restart.
    pub fn beta_range_from(&self, stats: &ModelStats) -> (f64, f64) {
        // ΔE of a flip is at most 2*max_field, at least 2*min_gap.
        let beta_hot = 1.0 / (self.hot_factor * 2.0 * stats.max_field);
        let beta_cold =
            1.0 / (self.cold_factor * 2.0 * stats.min_gap).max(1e-12);
        (beta_hot, beta_cold.max(beta_hot * (1.0 + 1e-9)))
    }
}

impl IsingSolver for SimulatedAnnealing {
    fn solve(&self, model: &QuadModel, rng: &mut Rng) -> Vec<i8> {
        let plan = self
            .lockstep_plan(model, &model.stats())
            .expect("SA always has a lockstep plan");
        replica::solve_one(model, &plan, rng)
    }

    fn name(&self) -> &'static str {
        "sa"
    }

    fn lockstep_plan(
        &self,
        _model: &QuadModel,
        stats: &ModelStats,
    ) -> Option<replica::SweepPlan> {
        let (beta_hot, beta_cold) = self.beta_range_from(stats);
        let ratio = (beta_cold / beta_hot)
            .powf(1.0 / (self.sweeps.max(2) - 1) as f64);
        Some(replica::SweepPlan::Metropolis {
            sweeps: self.sweeps,
            beta0: beta_hot,
            ratio,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::{exhaustive::Exhaustive, random_model};

    #[test]
    fn finds_global_minimum_on_small_models() {
        let mut rng = Rng::new(300);
        let sa = SimulatedAnnealing::default();
        let mut hits = 0;
        for trial in 0..10 {
            let m = random_model(&mut rng, 12);
            let exact = Exhaustive.solve(&m, &mut rng);
            let exact_e = m.energy(&exact);
            let (_, e) = sa.solve_best(&m, &mut rng, 10);
            if (e - exact_e).abs() < 1e-9 {
                hits += 1;
            } else {
                assert!(e >= exact_e - 1e-9, "trial {trial}: beat exact?");
            }
        }
        assert!(hits >= 8, "SA found the optimum only {hits}/10 times");
    }

    #[test]
    fn beta_schedule_is_increasing() {
        let mut rng = Rng::new(301);
        let m = random_model(&mut rng, 8);
        let sa = SimulatedAnnealing::default();
        let (hot, cold) = sa.beta_range(&m);
        assert!(cold > hot);
    }

    #[test]
    fn ferromagnet_ground_state() {
        // All-equal couplings J < 0 -> aligned ground state.
        let n = 16;
        let mut m = QuadModel::new(n);
        for i in 0..n {
            for k in (i + 1)..n {
                m.set_pair(i, k, -1.0);
            }
        }
        let mut rng = Rng::new(302);
        let sa = SimulatedAnnealing::default();
        let (x, _) = sa.solve_best(&m, &mut rng, 5);
        assert!(x.iter().all(|&s| s == x[0]), "not aligned: {x:?}");
    }

    #[test]
    fn solve_best_monotone_in_restarts() {
        let mut rng = Rng::new(303);
        let m = random_model(&mut rng, 14);
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let (_, e1) = sa.solve_best(&m, &mut Rng::new(1), 1);
        let (_, e10) = sa.solve_best(&m, &mut Rng::new(1), 10);
        assert!(e10 <= e1 + 1e-12);
    }
}
