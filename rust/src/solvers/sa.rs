//! Simulated annealing (Kirkpatrick et al. 1983), configured like the
//! D-Wave Ocean `neal` defaults the paper uses: the initial / final
//! temperatures come from the estimated maximum / minimum effective fields
//! scaled by 2.9 and 0.4 respectively, with a geometric β schedule and
//! Metropolis single-spin updates.

use super::{IsingSolver, QuadModel};
use crate::util::rng::Rng;

/// Metropolis simulated annealing with the neal-style geometric
/// schedule (the paper's default back-end).
#[derive(Clone, Debug)]
pub struct SimulatedAnnealing {
    /// Full sweeps over all spins.
    pub sweeps: usize,
    /// Hot-side temperature scaling (Ocean default ≈ 2.9).
    pub hot_factor: f64,
    /// Cold-side temperature scaling (Ocean default ≈ 0.4).
    pub cold_factor: f64,
}

impl Default for SimulatedAnnealing {
    fn default() -> Self {
        SimulatedAnnealing { sweeps: 100, hot_factor: 2.9, cold_factor: 0.4 }
    }
}

impl SimulatedAnnealing {
    /// β schedule endpoints from the model's effective-field estimates
    /// (neal convention): T_hot = hot_factor * max per-site field (every
    /// move initially plausible), T_cold = cold_factor * the *smallest
    /// nonzero coupling* (the finest energy scale must freeze by the end
    /// — using the per-site bound here leaves SA finishing hot on
    /// surrogate-shaped models).
    pub fn beta_range(&self, model: &QuadModel) -> (f64, f64) {
        let (max_f, _) = model.field_bounds();
        let min_gap = model.min_nonzero_gap();
        // ΔE of a flip is at most 2*max_field, at least 2*min_gap.
        let beta_hot = 1.0 / (self.hot_factor * 2.0 * max_f);
        let beta_cold =
            1.0 / (self.cold_factor * 2.0 * min_gap).max(1e-12);
        (beta_hot, beta_cold.max(beta_hot * (1.0 + 1e-9)))
    }
}

impl IsingSolver for SimulatedAnnealing {
    fn solve(&self, model: &QuadModel, rng: &mut Rng) -> Vec<i8> {
        let n = model.n;
        let mut x = rng.spins(n);
        let mut best = x.clone();
        let mut e = model.energy(&x);
        let mut best_e = e;
        let mut fields = super::LocalFields::new(model, &x);

        let (beta_hot, beta_cold) = self.beta_range(model);
        let ratio = (beta_cold / beta_hot).powf(
            1.0 / (self.sweeps.max(2) - 1) as f64,
        );
        let mut beta = beta_hot;

        for _ in 0..self.sweeps {
            for i in 0..n {
                let de = fields.delta_e(&x, i);
                if de <= 0.0 || rng.f64() < (-beta * de).exp() {
                    fields.flip(model, &mut x, i);
                    e += de;
                    if e < best_e {
                        best_e = e;
                        best.copy_from_slice(&x);
                    }
                }
            }
            beta *= ratio;
        }
        best
    }

    fn name(&self) -> &'static str {
        "sa"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::{exhaustive::Exhaustive, random_model};

    #[test]
    fn finds_global_minimum_on_small_models() {
        let mut rng = Rng::new(300);
        let sa = SimulatedAnnealing::default();
        let mut hits = 0;
        for trial in 0..10 {
            let m = random_model(&mut rng, 12);
            let exact = Exhaustive.solve(&m, &mut rng);
            let exact_e = m.energy(&exact);
            let (_, e) = sa.solve_best(&m, &mut rng, 10);
            if (e - exact_e).abs() < 1e-9 {
                hits += 1;
            } else {
                assert!(e >= exact_e - 1e-9, "trial {trial}: beat exact?");
            }
        }
        assert!(hits >= 8, "SA found the optimum only {hits}/10 times");
    }

    #[test]
    fn beta_schedule_is_increasing() {
        let mut rng = Rng::new(301);
        let m = random_model(&mut rng, 8);
        let sa = SimulatedAnnealing::default();
        let (hot, cold) = sa.beta_range(&m);
        assert!(cold > hot);
    }

    #[test]
    fn ferromagnet_ground_state() {
        // All-equal couplings J < 0 -> aligned ground state.
        let n = 16;
        let mut m = QuadModel::new(n);
        for i in 0..n {
            for k in (i + 1)..n {
                m.set_pair(i, k, -1.0);
            }
        }
        let mut rng = Rng::new(302);
        let sa = SimulatedAnnealing::default();
        let (x, _) = sa.solve_best(&m, &mut rng, 5);
        assert!(x.iter().all(|&s| s == x[0]), "not aligned: {x:?}");
    }

    #[test]
    fn solve_best_monotone_in_restarts() {
        let mut rng = Rng::new(303);
        let m = random_model(&mut rng, 14);
        let sa = SimulatedAnnealing { sweeps: 5, ..Default::default() };
        let (_, e1) = sa.solve_best(&m, &mut Rng::new(1), 1);
        let (_, e10) = sa.solve_best(&m, &mut Rng::new(1), 10);
        assert!(e10 <= e1 + 1e-12);
    }
}
