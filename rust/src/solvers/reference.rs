//! Legacy serial solver implementations — the executable specification
//! of the replica engine's RNG-stream contract.
//!
//! These are the pre-ISSUE-4 scalar chain loops, kept verbatim: one
//! configuration, one incrementally maintained [`super::LocalFields`],
//! one RNG consumed in proposal order (the Metropolis uniform is drawn
//! *only* when ΔE > 0).  The replica-major engine
//! ([`super::replica`]) is pinned bit-identical to these per replica on
//! the same stream by `rust/tests/replica_engine.rs`; any change to the
//! engine's stream consumption or float op order shows up there as a
//! spin-vector diff against this module.
//!
//! They are reference kernels, not production paths — the trait solvers
//! ([`super::sa`], [`super::sq`], [`super::sqa`]) all route through the
//! lockstep engine.

use super::{greedy_descent, LocalFields, QuadModel};
use crate::util::rng::Rng;

/// Legacy scalar-chain solve by solver name ("sa" / "sq" / anything
/// else = "sqa"), using each solver's `Default` configuration — the
/// single dispatch point for the benches' `per-chain` comparator rows,
/// so `cargo bench` and `intdecomp bench` cannot drift apart.
pub fn solve_by_name(name: &str, model: &QuadModel, rng: &mut Rng) -> Vec<i8> {
    match name {
        "sa" => sa(&super::sa::SimulatedAnnealing::default(), model, rng),
        "sq" => sq(&super::sq::SimulatedQuenching::default(), model, rng),
        _ => sqa(
            &super::sqa::SimulatedQuantumAnnealing::default(),
            model,
            rng,
        ),
    }
}

/// Legacy scalar simulated-annealing chain (the pre-ISSUE-4
/// [`super::sa::SimulatedAnnealing`] solve body, verbatim).
pub fn sa(
    solver: &super::sa::SimulatedAnnealing,
    model: &QuadModel,
    rng: &mut Rng,
) -> Vec<i8> {
    let n = model.n;
    let mut x = rng.spins(n);
    let mut best = x.clone();
    let mut e = model.energy(&x);
    let mut best_e = e;
    let mut fields = LocalFields::new(model, &x);

    let (beta_hot, beta_cold) = solver.beta_range(model);
    let ratio = (beta_cold / beta_hot)
        .powf(1.0 / (solver.sweeps.max(2) - 1) as f64);
    let mut beta = beta_hot;

    for _ in 0..solver.sweeps {
        for i in 0..n {
            let de = fields.delta_e(&x, i);
            if de <= 0.0 || rng.f64() < (-beta * de).exp() {
                fields.flip(model, &mut x, i);
                e += de;
                if e < best_e {
                    best_e = e;
                    best.copy_from_slice(&x);
                }
            }
        }
        beta *= ratio;
    }
    best
}

/// Legacy scalar simulated-quenching chain (the pre-ISSUE-4
/// [`super::sq::SimulatedQuenching`] solve body, verbatim).
pub fn sq(
    solver: &super::sq::SimulatedQuenching,
    model: &QuadModel,
    rng: &mut Rng,
) -> Vec<i8> {
    let n = model.n;
    let beta = 1.0 / solver.temperature.max(1e-12);
    let mut x = rng.spins(n);
    let mut e = model.energy(&x);
    let mut best = x.clone();
    let mut best_e = e;
    let mut fields = LocalFields::new(model, &x);
    for _ in 0..solver.sweeps {
        for i in 0..n {
            let de = fields.delta_e(&x, i);
            if de <= 0.0 || rng.f64() < (-beta * de).exp() {
                fields.flip(model, &mut x, i);
                e += de;
                if e < best_e {
                    best_e = e;
                    best.copy_from_slice(&x);
                }
            }
        }
    }
    best
}

/// Legacy scalar path-integral SQA run (the pre-ISSUE-4
/// [`super::sqa::SimulatedQuantumAnnealing`] solve body, verbatim):
/// all P Trotter slices of one restart share `rng`, swept slice-major.
pub fn sqa(
    solver: &super::sqa::SimulatedQuantumAnnealing,
    model: &QuadModel,
    rng: &mut Rng,
) -> Vec<i8> {
    let n = model.n;
    let p = solver.slices.max(2);
    let (max_f, _) = model.field_bounds();
    let t = solver.temperature_factor * 2.0 * max_f;
    let pt = p as f64 * t;
    let beta_slice = 1.0 / pt.max(1e-12);
    let gamma0 = solver.gamma0_factor * 2.0 * max_f;

    // Replica spins, slice-major, with incrementally maintained
    // classical local fields per slice.
    let mut x: Vec<Vec<i8>> = (0..p).map(|_| rng.spins(n)).collect();
    let mut fields: Vec<LocalFields> =
        x.iter().map(|xs| LocalFields::new(model, xs)).collect();

    for sweep in 0..solver.sweeps {
        let s = (sweep + 1) as f64 / solver.sweeps as f64;
        let gamma = gamma0 * (1.0 - s);
        // Replica coupling; clamped to keep exp() sane at gamma -> 0.
        let tanh_arg = (gamma / pt).max(1e-12);
        let j_perp = -0.5 * pt * tanh_arg.tanh().ln();

        for slice in 0..p {
            let up = (slice + 1) % p;
            let down = (slice + p - 1) % p;
            for i in 0..n {
                // Classical ΔE within the slice (scaled by 1/P in the
                // Trotter action) + replica-coupling ΔE.
                let de_classical =
                    fields[slice].delta_e(&x[slice], i) / p as f64;
                let xi = x[slice][i] as f64;
                let neigh = (x[up][i] + x[down][i]) as f64;
                let de_perp = 2.0 * j_perp * xi * neigh;
                let de = de_classical + de_perp;
                if de <= 0.0
                    || rng.f64() < (-de * beta_slice * p as f64).exp()
                {
                    fields[slice].flip(model, &mut x[slice], i);
                }
            }
        }
    }

    // Best replica by classical energy, then polish to a local min.
    let mut best = x[0].clone();
    let mut best_e = model.energy(&best);
    for slice in x.iter().skip(1) {
        let e = model.energy(slice);
        if e < best_e {
            best_e = e;
            best = slice.clone();
        }
    }
    greedy_descent(model, &mut best);
    best
}
