//! Simulated quantum annealing: path-integral Monte Carlo of the
//! transverse-field Ising model — the classical stand-in for the paper's
//! D-Wave QPU runs (DESIGN.md §2).
//!
//! The quantum Hamiltonian `H(s) = -A(s) Σ σ^x_i + B(s) H_problem` is
//! Trotterised into P coupled replicas of the classical model; the
//! replica-coupling strength
//!
//! ```text
//!   J_perp(s) = -(P T / 2) ln tanh( Γ(s) / (P T) )
//! ```
//!
//! grows as the transverse field Γ(s) = Γ0 (1 - s) is annealed to zero,
//! gradually freezing the replicas into a common classical configuration
//! (Kadowaki & Nishimori 1998; Martoňák et al. 2002).  The answer is the
//! lowest-energy replica at the end of the schedule.

use super::{greedy_descent, IsingSolver, QuadModel};
use crate::util::rng::Rng;

/// Path-integral Monte Carlo of the transverse-field Ising model.
#[derive(Clone, Debug)]
pub struct SimulatedQuantumAnnealing {
    /// Trotter slices P.
    pub slices: usize,
    /// Monte Carlo sweeps over (site × slice).
    pub sweeps: usize,
    /// Initial transverse field in units of the max effective field.
    pub gamma0_factor: f64,
    /// PIMC temperature in units of the max effective field.
    pub temperature_factor: f64,
}

impl Default for SimulatedQuantumAnnealing {
    fn default() -> Self {
        SimulatedQuantumAnnealing {
            slices: 16,
            sweeps: 100,
            gamma0_factor: 1.5,
            temperature_factor: 0.05,
        }
    }
}

impl IsingSolver for SimulatedQuantumAnnealing {
    fn solve(&self, model: &QuadModel, rng: &mut Rng) -> Vec<i8> {
        let n = model.n;
        let p = self.slices.max(2);
        let (max_f, _) = model.field_bounds();
        let t = self.temperature_factor * 2.0 * max_f;
        let pt = p as f64 * t;
        let beta_slice = 1.0 / pt.max(1e-12);
        let gamma0 = self.gamma0_factor * 2.0 * max_f;

        // Replica spins, slice-major, with incrementally maintained
        // classical local fields per slice (EXPERIMENTS.md §Perf).
        let mut x: Vec<Vec<i8>> = (0..p).map(|_| rng.spins(n)).collect();
        let mut fields: Vec<super::LocalFields> =
            x.iter().map(|xs| super::LocalFields::new(model, xs)).collect();

        for sweep in 0..self.sweeps {
            let s = (sweep + 1) as f64 / self.sweeps as f64;
            let gamma = gamma0 * (1.0 - s);
            // Replica coupling; clamped to keep exp() sane at gamma -> 0.
            let tanh_arg = (gamma / pt).max(1e-12);
            let j_perp = -0.5 * pt * tanh_arg.tanh().ln();

            for slice in 0..p {
                let up = (slice + 1) % p;
                let down = (slice + p - 1) % p;
                for i in 0..n {
                    // Classical ΔE within the slice (scaled by 1/P in the
                    // Trotter action) + replica-coupling ΔE.
                    let de_classical =
                        fields[slice].delta_e(&x[slice], i) / p as f64;
                    let xi = x[slice][i] as f64;
                    let neigh =
                        (x[up][i] + x[down][i]) as f64;
                    let de_perp = 2.0 * j_perp * xi * neigh;
                    let de = de_classical + de_perp;
                    if de <= 0.0 || rng.f64() < (-de * beta_slice * p as f64).exp()
                    {
                        fields[slice].flip(model, &mut x[slice], i);
                    }
                }
            }
        }

        // Best replica by classical energy, then polish to a local min
        // (the QPU readout analogue of the final projective measurement).
        let mut best = x[0].clone();
        let mut best_e = model.energy(&best);
        for slice in x.iter().skip(1) {
            let e = model.energy(slice);
            if e < best_e {
                best_e = e;
                best = slice.clone();
            }
        }
        greedy_descent(model, &mut best);
        best
    }

    fn name(&self) -> &'static str {
        "sqa"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::{exhaustive::Exhaustive, random_model};

    #[test]
    fn finds_global_minimum_on_small_models() {
        let mut rng = Rng::new(320);
        let sqa = SimulatedQuantumAnnealing::default();
        let mut hits = 0;
        for _ in 0..10 {
            let m = random_model(&mut rng, 10);
            let exact = Exhaustive.solve(&m, &mut rng);
            let exact_e = m.energy(&exact);
            let (_, e) = sqa.solve_best(&m, &mut rng, 10);
            assert!(e >= exact_e - 1e-9);
            if (e - exact_e).abs() < 1e-9 {
                hits += 1;
            }
        }
        assert!(hits >= 8, "SQA found the optimum only {hits}/10 times");
    }

    #[test]
    fn antiferromagnetic_pair() {
        let mut m = QuadModel::new(2);
        m.set_pair(0, 1, 5.0); // opposite spins preferred
        let mut rng = Rng::new(321);
        let sqa = SimulatedQuantumAnnealing::default();
        let x = sqa.solve(&m, &mut rng);
        assert_eq!(x[0], -x[1]);
    }

    #[test]
    fn output_is_valid_spin_vector() {
        let mut rng = Rng::new(322);
        let m = random_model(&mut rng, 24);
        let sqa = SimulatedQuantumAnnealing {
            slices: 8,
            sweeps: 20,
            ..Default::default()
        };
        let x = sqa.solve(&m, &mut rng);
        assert_eq!(x.len(), 24);
        assert!(x.iter().all(|&s| s == 1 || s == -1));
    }
}
