//! Simulated quantum annealing: path-integral Monte Carlo of the
//! transverse-field Ising model — the classical stand-in for the paper's
//! D-Wave QPU runs (DESIGN.md §2).
//!
//! The quantum Hamiltonian `H(s) = -A(s) Σ σ^x_i + B(s) H_problem` is
//! Trotterised into P coupled replicas of the classical model; the
//! replica-coupling strength
//!
//! ```text
//!   J_perp(s) = -(P T / 2) ln tanh( Γ(s) / (P T) )
//! ```
//!
//! grows as the transverse field Γ(s) = Γ0 (1 - s) is annealed to zero,
//! gradually freezing the replicas into a common classical configuration
//! (Kadowaki & Nishimori 1998; Martoňák et al. 2002).  The answer is the
//! lowest-energy replica at the end of the schedule.
//!
//! Since ISSUE 4 this type is a thin schedule driver over the
//! replica-major engine ([`super::replica`]): one restart's P Trotter
//! slices occupy P consecutive rows of the lockstep spin panel, and
//! multi-restart calls sweep all restarts at a fixed (slice, site) so
//! each coupling row is shared across the whole block.  Output is
//! bit-identical to the legacy scalar run ([`super::reference::sqa`])
//! on the same stream.

use super::{replica, IsingSolver, ModelStats, QuadModel};
use crate::util::rng::Rng;

/// Path-integral Monte Carlo of the transverse-field Ising model.
#[derive(Clone, Debug)]
pub struct SimulatedQuantumAnnealing {
    /// Trotter slices P.
    pub slices: usize,
    /// Monte Carlo sweeps over (site × slice).
    pub sweeps: usize,
    /// Initial transverse field in units of the max effective field.
    pub gamma0_factor: f64,
    /// PIMC temperature in units of the max effective field.
    pub temperature_factor: f64,
}

impl Default for SimulatedQuantumAnnealing {
    fn default() -> Self {
        SimulatedQuantumAnnealing {
            slices: 16,
            sweeps: 100,
            gamma0_factor: 1.5,
            temperature_factor: 0.05,
        }
    }
}

impl IsingSolver for SimulatedQuantumAnnealing {
    fn solve(&self, model: &QuadModel, rng: &mut Rng) -> Vec<i8> {
        let plan = self
            .lockstep_plan(model, &model.stats())
            .expect("SQA always has a lockstep plan");
        replica::solve_one(model, &plan, rng)
    }

    fn name(&self) -> &'static str {
        "sqa"
    }

    fn lockstep_plan(
        &self,
        _model: &QuadModel,
        stats: &ModelStats,
    ) -> Option<replica::SweepPlan> {
        let p = self.slices.max(2);
        let t = self.temperature_factor * 2.0 * stats.max_field;
        let pt = p as f64 * t;
        Some(replica::SweepPlan::Sqa {
            slices: p,
            sweeps: self.sweeps,
            gamma0: self.gamma0_factor * 2.0 * stats.max_field,
            pt,
            beta_slice: 1.0 / pt.max(1e-12),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::{exhaustive::Exhaustive, random_model};

    #[test]
    fn finds_global_minimum_on_small_models() {
        let mut rng = Rng::new(320);
        let sqa = SimulatedQuantumAnnealing::default();
        let mut hits = 0;
        for _ in 0..10 {
            let m = random_model(&mut rng, 10);
            let exact = Exhaustive.solve(&m, &mut rng);
            let exact_e = m.energy(&exact);
            let (_, e) = sqa.solve_best(&m, &mut rng, 10);
            assert!(e >= exact_e - 1e-9);
            if (e - exact_e).abs() < 1e-9 {
                hits += 1;
            }
        }
        assert!(hits >= 8, "SQA found the optimum only {hits}/10 times");
    }

    #[test]
    fn antiferromagnetic_pair() {
        let mut m = QuadModel::new(2);
        m.set_pair(0, 1, 5.0); // opposite spins preferred
        let mut rng = Rng::new(321);
        let sqa = SimulatedQuantumAnnealing::default();
        let x = sqa.solve(&m, &mut rng);
        assert_eq!(x[0], -x[1]);
    }

    #[test]
    fn output_is_valid_spin_vector() {
        let mut rng = Rng::new(322);
        let m = random_model(&mut rng, 24);
        let sqa = SimulatedQuantumAnnealing {
            slices: 8,
            sweeps: 20,
            ..Default::default()
        };
        let x = sqa.solve(&m, &mut rng);
        assert_eq!(x.len(), 24);
        assert!(x.iter().all(|&s| s == 1 || s == -1));
    }
}
